"""Single-cell dry-run walkthrough: lower + compile one (arch × shape)
on the production 256-chip mesh and print the roofline terms.

This is the interactive version of `python -m repro.launch.dryrun`;
see EXPERIMENTS.md §Dry-run for the full 40-cell table.

Run:  PYTHONPATH=src python examples/dryrun_demo.py --arch llama3.2-1b
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    rec = run_cell(args.arch, args.shape, args.multipod, force=True,
                   tag="-demo")
    if rec["status"] != "ok":
        print(rec.get("error"))
        return
    chips = rec["chips"]
    comp = rec["flops_per_device"] / PEAK_FLOPS_BF16
    mem = rec["bytes_per_device"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / ICI_BW
    print(f"\n{args.arch} × {args.shape} on {chips} chips:")
    print(f"  compiled in {rec['compile_s']:.1f}s "
          f"(HLO {rec['hlo_bytes']/1e6:.1f} MB)")
    if "memory" in rec:
        m = rec["memory"]
        print(f"  per-device memory: args {m.get('argument_size_in_bytes',0)/1e9:.2f} GB, "
              f"temps {m.get('temp_size_in_bytes',0)/1e9:.2f} GB")
    print(f"  roofline terms: compute {comp*1e3:.1f} ms | memory {mem*1e3:.1f} ms "
          f"| collective {coll*1e3:.1f} ms")
    dom = max((comp, 'compute'), (mem, 'memory'), (coll, 'collective'))[1]
    print(f"  dominant: {dom}")


if __name__ == "__main__":
    main()
