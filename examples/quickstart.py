"""Quickstart: the P²M layer end-to-end in ~a minute on CPU.

1. fit the behavioral pixel model (SPICE surrogate → degree-3 polynomial),
2. build the paper's in-pixel first layer (k=s=5, c_o=8, 8-bit ADC),
3. run the train form (conv(g) → BN → ReLU) and the deploy form
   (folded weights → quantized shifted-ReLU ADC, Pallas kernel),
4. print the analytics the paper reports: bandwidth reduction and EDP.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core import (
    FirstLayerGeom,
    P2MConvConfig,
    bandwidth_reduction,
    default_pixel_model,
    deploy_params,
)
from repro.core.p2m_conv import (
    apply_p2m_conv_deploy,
    apply_p2m_conv_train,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.energy import (
    BASELINE_C_ENERGY, BASELINE_DELAY, N_PIX_BASELINE_C, N_PIX_P2M,
    P2M_DELAY, P2M_ENERGY, evaluate_model,
)
from repro.models.mobilenetv2 import MNV2Config, layer_census


def main():
    # 1. pixel model
    model = default_pixel_model()
    print(f"pixel model: degree ({model.degree_w},{model.degree_x}) "
          f"polynomial, fit RMSE {model.fit_rmse:.2e}")
    print(f"  g(0.5, 0.5) = {float(model(0.5, 0.5)):.4f} "
          f"(ideal product would be 0.25 — the circuit is super-linear "
          f"at mid-range, exactly what the co-design training absorbs)")

    # 2-3. the paper's first layer on a (tiny) frame
    cfg = P2MConvConfig()
    key = jax.random.PRNGKey(0)
    params = init_p2m_conv(key, cfg)
    state = init_p2m_state(cfg)
    frames = jax.random.uniform(jax.random.PRNGKey(1), (2, 80, 80, 3))

    train_out, state = apply_p2m_conv_train(params, state, frames, cfg, model,
                                            train=True)
    print(f"train form: {frames.shape} -> {train_out.shape} "
          f"(stride-5 non-overlapping, 8 channels)")

    dep = deploy_params(params, state, cfg)
    deploy_out = apply_p2m_conv_deploy(dep, frames, cfg, model, quantize=True)
    counts = deploy_out / cfg.adc.v_lsb
    print(f"deploy form: folded BN → shifted-ReLU ADC; outputs are exact "
          f"{cfg.n_bits}-bit counts (max={int(counts.max())}) — "
          f"fused implicit-im2col path (Pallas on TPU, XLA twin here)")

    # 4. the paper's analytics
    br = bandwidth_reduction(FirstLayerGeom())
    p2m_rep = evaluate_model(layer_census(MNV2Config(variant="p2m")),
                             N_PIX_P2M, P2M_ENERGY, P2M_DELAY)
    base_rep = evaluate_model(layer_census(MNV2Config(variant="baseline")),
                              N_PIX_BASELINE_C, BASELINE_C_ENERGY, BASELINE_DELAY)
    print(f"bandwidth reduction (Eq.2, Table 1): {br:.2f}x (paper: ~21x)")
    print(f"EDP advantage: {base_rep.edp_sequential / p2m_rep.edp_sequential:.1f}x "
          f"sequential (paper 16.76x), "
          f"{base_rep.edp_conservative / p2m_rep.edp_conservative:.1f}x "
          f"conservative (paper ~11x)")


if __name__ == "__main__":
    main()
