"""Streaming-video P²M detection demo (CPU): delta-gated multi-tick
streams through the StreamEngine, routed by the FrontDoor next to an LM
co-tenant and single-shot vision frames (DESIGN.md §9).

Each request is a whole synthetic moving-object stream occupying one
engine slot across ticks: per tick the deploy-folded P²M stem either
re-runs (frame delta crossed the gate threshold) or reuses the cached
activations of its reference frame; the CenterNet-lite head decodes
boxes and greedy-IoU association maintains per-stream tracks.  The
bandwidth numbers printed are *measured* — bits that actually crossed
the sensor boundary under event-style readout — next to the paper's
closed-form dense figure.

With --mesh, the stream microbatch (images, cached stems, rerun mask)
shards over the data mesh built from all visible devices.

Run:  PYTHONPATH=src python examples/stream_detect_p2m.py --streams 6
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.bandwidth import bandwidth_reduction
from repro.data import SyntheticVWW
from repro.launch.mesh import make_debug_mesh
from repro.launch.serve import FrontDoor
from repro.models.families import get_family
from repro.models.mobilenetv2 import (MNV2Config, head_out_channels,
                                      init_mnv2)
from repro.serving import Request, ServeEngine, VisionEngine, VisionRequest
from repro.video import (
    DeltaGateConfig,
    DetectConfig,
    StreamEngine,
    StreamRequest,
    SyntheticVideo,
    init_detect_head,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=6)
    ap.add_argument("--frames", type=int, default=12)
    ap.add_argument("--image-size", type=int, default=40)
    ap.add_argument("--max-streams", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=0.0,
                    help="delta-gate threshold (mean |d| pixels; 0 = "
                         "lossless event gating)")
    ap.add_argument("--hold", type=int, default=2,
                    help="object positions advance every HOLD frames")
    ap.add_argument("--lm-requests", type=int, default=2)
    ap.add_argument("--vision-requests", type=int, default=4)
    ap.add_argument("--mesh", action="store_true",
                    help="shard the stream microbatch over all devices")
    args = ap.parse_args()

    cfg = MNV2Config(variant="p2m", image_size=args.image_size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    # low score threshold: the head is untrained (like the serving demo's
    # "accuracy vs labels" line) — the point is the streaming machinery
    dcfg = DetectConfig(score_thresh=0.08)
    det = init_detect_head(
        jax.random.PRNGKey(1),
        head_out_channels(cfg), dcfg)
    mesh = make_debug_mesh() if args.mesh else None

    stream_engine = StreamEngine(
        params, bn, cfg, det, det_cfg=dcfg,
        gate=DeltaGateConfig(threshold=args.threshold),
        max_streams=args.max_streams, mesh=mesh)
    vision_engine = VisionEngine(params, bn, cfg, max_batch=4)

    lm_cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    lm_params, _ = get_family(lm_cfg).init(jax.random.PRNGKey(2), lm_cfg)
    lm = ServeEngine(lm_params, lm_cfg, max_batch=2, max_len=64,
                     prefill_chunk=4)

    rng = np.random.default_rng(0)
    reqs = []
    videos = {}
    for uid in range(args.streams):
        vid = SyntheticVideo(image_size=args.image_size,
                             n_frames=args.frames, seed=uid, hold=args.hold)
        videos[uid] = vid
        reqs.append(StreamRequest(uid=uid, frames=vid.frames(),
                                  gt_boxes=vid.gt_boxes(),
                                  arrival_tick=uid // 2))
    frames1 = SyntheticVWW(image_size=args.image_size,
                           batch=max(args.vision_requests, 1)).batch_at(0)
    for uid in range(args.vision_requests):
        reqs.append(VisionRequest(uid=1000 + uid,
                                  image=frames1["images"][uid],
                                  arrival_tick=uid))
    for uid in range(args.lm_requests):
        prompt = rng.integers(0, lm_cfg.vocab, 6).tolist()
        reqs.append(Request(uid=2000 + uid, prompt=prompt, max_new_tokens=8,
                            arrival_tick=2 * uid))

    door = FrontDoor(stream=stream_engine, vision=vision_engine, lm=lm)
    merged = door.run(reqs)
    streams = [r for n, r in merged if n == "stream"]

    dev = f"{len(mesh.devices.flat)}-device mesh" if mesh else "single device"
    print(f"front door served {len(streams)} video streams + "
          f"{len([1 for n, _ in merged if n == 'vision'])} frames + "
          f"{len([1 for n, _ in merged if n == 'lm'])} LM requests "
          f"on {dev} in {door.tick} front-door ticks\n")
    for r in streams:
        n_tracks = len({tid for frame in r.tracks for tid, _, _ in frame})
        print(f"  stream {r.uid}: {r.frames_done} frames over "
              f"{r.serve_ticks} ticks (queued {r.queue_ticks}), "
              f"stem-skip {r.skip_rate:.2f}, "
              f"{r.bits_per_frame:.0f} bits/frame vs "
              f"{r.dense_frame_bits} dense "
              f"({r.reduction_vs_dense:.2f}x measured), "
              f"{n_tracks} tracks (untrained head), "
              f"frame latency {r.frame_latency_us / 1e3:.1f} ms")
    s = stream_engine.stream_summary()
    print(f"\naggregate: stem-skip {s['stem_skip_rate']:.2f}, "
          f"{s['bits_per_frame']:.0f} bits/frame "
          f"({s['measured_reduction_vs_dense']:.2f}x measured reduction "
          f"vs dense readout)")
    print(f"paper Eq. 2 closed form (this geometry, dense single frame): "
          f"{bandwidth_reduction(stream_engine.geom):.2f}x vs raw sensor")


if __name__ == "__main__":
    main()
