"""End-to-end driver (paper §5): train baseline and P²M-custom
MobileNetV2 on the synthetic VWW proxy, evaluate, then post-training
quantize the in-pixel layer and sweep output bit-precision (Fig. 7a).

Reduced geometry (80² images, width 0.25) so a few hundred steps run in
minutes on CPU; the model/geometry scale to the paper's 560² via flags.

Run:  PYTHONPATH=src python examples/train_vww_p2m.py --steps 300
      PYTHONPATH=src python examples/train_vww_p2m.py --steps 300 --sweep
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core.bn_fold import deploy_params
from repro.core.quant import QuantSpec, quantize_deploy
from repro.data import SyntheticVWW
from repro.models.mobilenetv2 import MNV2Config, apply_mnv2, init_mnv2
from repro.optim import sgd, step_decay
from repro.train.vision import make_vww_eval, make_vww_train_step


def train(cfg, steps, lr, seed=0, log_every=50):
    ds = SyntheticVWW(image_size=cfg.image_size, batch=32, seed=seed)
    params, bn = init_mnv2(jax.random.PRNGKey(seed), cfg)
    # paper recipe: SGD momentum 0.9, step decay ×0.2
    opt = sgd(step_decay(lr, boundaries=(int(steps * 0.6), int(steps * 0.85))),
              momentum=0.9)
    state = {"params": params, "bn": bn, "opt": opt.init(params),
             "step": jnp.asarray(0, jnp.int32)}
    step_fn = jax.jit(make_vww_train_step(cfg, opt))
    for i in range(steps):
        state, m = step_fn(state, ds.batch_at(i))
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i+1}: loss={float(m['loss']):.4f} "
                  f"acc={float(m['acc']):.3f}")
    return state


def evaluate(cfg, state, n_batches=4, p2m_deploy=None):
    ev = make_vww_eval(cfg)
    accs = []
    for b in range(n_batches):
        batch = SyntheticVWW(image_size=cfg.image_size, batch=128,
                             seed=10_000 + b).batch_at(0)
        accs.append(ev(state["params"], state["bn"], batch,
                       p2m_deploy=p2m_deploy))
    return sum(accs) / len(accs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--image-size", type=int, default=80)
    ap.add_argument("--width", type=float, default=0.25)
    # the paper's 560² LRs are 0.03 / 0.003; the reduced 80² proxy needs a
    # hotter stem (stride-5 ⇒ 16² resolution) — defaults tuned for it
    ap.add_argument("--lr", type=float, default=0.03)
    ap.add_argument("--lr-p2m", type=float, default=0.05)
    ap.add_argument("--sweep", action="store_true",
                    help="Fig. 7a: output bit-precision sweep after training")
    args = ap.parse_args()

    base_cfg = MNV2Config(variant="baseline", image_size=args.image_size,
                          width=args.width, head_channels=64)
    p2m_cfg = MNV2Config(variant="p2m", image_size=args.image_size,
                         width=args.width, head_channels=64)

    print("== baseline MobileNetV2 ==")
    base_state = train(base_cfg, args.steps, args.lr)
    base_acc = evaluate(base_cfg, base_state)
    print(f"baseline eval accuracy: {base_acc:.3f}")

    print("== P²M-custom MobileNetV2 (in-pixel first layer) ==")
    p2m_state = train(p2m_cfg, args.steps, args.lr_p2m)
    p2m_acc = evaluate(p2m_cfg, p2m_state)
    print(f"P²M eval accuracy: {p2m_acc:.3f} "
          f"(drop vs baseline: {base_acc - p2m_acc:+.3f}; paper: 1.47% at 560²)")

    # fold + deploy (what the manufactured sensor computes)
    dep = deploy_params(p2m_state["params"]["stem"], p2m_state["bn"]["stem"],
                        p2m_cfg.p2m)
    dep8 = quantize_deploy(dep, QuantSpec(w_bits=8, out_bits=8))
    dep_acc = evaluate(p2m_cfg, p2m_state, p2m_deploy=dep8)
    print(f"deployed (folded BN, 8-bit weights + 8-bit ADC): {dep_acc:.3f} "
          f"(paper: 8-bit PTQ is accuracy-neutral)")

    if args.sweep:
        print("== Fig. 7a sweep: ADC output bits ==")
        for bits in (16, 8, 6, 4):
            from repro.models.mobilenetv2 import MNV2Config as C
            from repro.core.p2m_conv import P2MConvConfig
            cfgq = MNV2Config(variant="p2m", image_size=args.image_size,
                              width=args.width, head_channels=64,
                              p2m=P2MConvConfig(n_bits=bits))
            depq = quantize_deploy(dep, QuantSpec(w_bits=8, out_bits=bits))
            acc = evaluate(cfgq, p2m_state, p2m_deploy=depq)
            print(f"  N_b={bits}: acc={acc:.3f}")


if __name__ == "__main__":
    main()
