"""Batched vision serving demo on the P²M-MobileNetV2 (CPU), driven
through the multi-engine front door with an LM co-tenant.

Replays a bursty variable-arrival trace of synthetic VWW frames through
the VisionEngine — requests microbatch through the deploy-folded (BN
folded + 8-bit PTQ) P²M stem and backbone, free slots are zero-padded,
and per-request latency splits into queueing delay vs launch wall-clock
(DESIGN.md §7.2/§8) — while a handful of LM requests ride the same
FrontDoor, demonstrating mixed-modality routing and merged completion.

With --mesh, the vision microbatch is sharded over the data mesh built
from all visible devices (run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 to see 8-way DP on
CPU).  With --replicas N, the vision side becomes an N-replica
`ReplicaPool` behind least-loaded dispatch (DESIGN.md §11) — combined
with --mesh each replica gets its own disjoint submesh, i.e.
data-parallel *within* a replica, replica-parallel across the pool —
and --lm-tick-cost C makes the front door event-driven: the LM engine
fires once per C door ticks while vision fires every tick.

With --trace out.json, a deterministic tick-domain `Tracer` rides the
door (DESIGN.md §13) and the run exports a Chrome/Perfetto trace —
open it at ui.perfetto.dev to see every request's queue/serve spans
against the engine-tick tracks.  The run always ends with a metrics
registry snapshot: the counters, tick-histograms, and component views
every layer published during the replay.

Run:  PYTHONPATH=src python examples/serve_vww_p2m.py --requests 24
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_vww_p2m.py --requests 24 \
          --mesh --replicas 2 --lm-tick-cost 4 --trace door.json
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.obs import Tracer, default_registry
from repro.configs.p2m_vww import SERVE_MAX_BATCH, SERVE_MAX_QUEUE
from repro.data import SyntheticVWW
from repro.launch.mesh import make_debug_mesh, make_submeshes
from repro.launch.serve import FrontDoor
from repro.serving import (
    ReplicaPool,
    Request,
    ServeEngine,
    VisionEngine,
    VisionRequest,
)
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, init_mnv2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--lm-requests", type=int, default=4)
    ap.add_argument("--image-size", type=int, default=80)
    ap.add_argument("--max-batch", type=int, default=SERVE_MAX_BATCH)
    ap.add_argument("--max-queue", type=int, default=SERVE_MAX_QUEUE)
    ap.add_argument("--mesh", action="store_true",
                    help="shard the vision microbatch over all devices")
    ap.add_argument("--replicas", type=int, default=1,
                    help="vision replicas in a least-loaded ReplicaPool "
                         "(with --mesh: one disjoint submesh per replica)")
    ap.add_argument("--lm-tick-cost", type=int, default=1,
                    help="front-door ticks per LM engine tick (>1 makes "
                         "the door event-driven, DESIGN.md §11)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export a Perfetto tick-domain trace of the "
                         "replay to this path (DESIGN.md §13)")
    args = ap.parse_args()

    cfg = MNV2Config(variant="p2m", image_size=args.image_size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    batch = SyntheticVWW(image_size=args.image_size,
                         batch=args.requests).batch_at(0)

    if args.replicas > 1:
        meshes = (make_submeshes(args.replicas) if args.mesh
                  else [None] * args.replicas)
        engine = ReplicaPool(*(
            VisionEngine(params, bn, cfg, max_batch=args.max_batch,
                         max_queue=args.max_queue, mesh=m) for m in meshes))
    else:
        mesh = make_debug_mesh() if args.mesh else None
        engine = VisionEngine(params, bn, cfg, max_batch=args.max_batch,
                              max_queue=args.max_queue, mesh=mesh)

    # bursty arrivals: clumps of frames every few ticks
    rng = np.random.default_rng(0)
    tick, reqs = 0, []
    for uid in range(args.requests):
        if uid and uid % 5 == 0:
            tick += int(rng.integers(1, 4))
        reqs.append(VisionRequest(uid=uid, image=batch["images"][uid],
                                  arrival_tick=tick))

    # LM co-tenant: a few short prompts share the front door
    lm_cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    lm_fam = get_family(lm_cfg)
    lm_params, _ = lm_fam.init(jax.random.PRNGKey(1), lm_cfg)
    lm = ServeEngine(lm_params, lm_cfg, max_batch=2, max_len=64,
                     prefill_chunk=4, tick_cost=args.lm_tick_cost)
    for uid in range(args.lm_requests):
        prompt = rng.integers(0, lm_cfg.vocab, 6).tolist()
        reqs.append(Request(uid=1000 + uid, prompt=prompt, max_new_tokens=8,
                            arrival_tick=2 * uid))

    tracer = Tracer() if args.trace else None
    door = FrontDoor(tracer=tracer, vision=engine, lm=lm)
    merged = door.run(reqs)
    done = [r for n, r in merged if n == "vision"]
    lm_done = [r for n, r in merged if n == "lm"]

    correct = sum(r.label == int(batch["labels"][r.uid]) for r in done)
    n_dev = len(jax.devices()) if args.mesh else 1
    dev = (f"{args.replicas}x {n_dev // args.replicas}-device replicas"
           if args.replicas > 1 else
           f"{n_dev}-device mesh" if args.mesh else "single device")
    print(f"served {len(done)}/{args.requests} frames on {dev} "
          f"(accuracy vs labels {correct / len(done):.2f} — untrained net) "
          f"+ {len(lm_done)} LM requests")
    for r in done[: args.max_batch + 2]:
        print(f"  uid={r.uid:3d} arrived@{r.arrival_tick:<3d} "
              f"served@{r.served_tick:<3d} queue={r.queue_ticks} ticks  "
              f"launch={r.batch_wall_us / 1e3:.1f} ms  label={r.label}")
    s = engine.latency_summary()
    print(f"launches={s['launches']} utilization={s['utilization']:.2f} "
          f"mean_queue={s['mean_queue_ticks']:.2f} ticks "
          f"mean_launch={s['mean_launch_us'] / 1e3:.1f} ms "
          f"evictions={s['evictions']}")

    if tracer is not None:
        tracer.export(args.trace)
        print(f"trace: {len(tracer.trace_events())} events -> {args.trace} "
              "(open at ui.perfetto.dev)")
    snap = default_registry().snapshot()
    print("\nmetrics registry snapshot (DESIGN.md §13.2):")
    print(json.dumps(snap, indent=2, sort_keys=True, default=str))


if __name__ == "__main__":
    main()
