"""Batched vision serving demo on the P²M-MobileNetV2 (CPU).

Replays a bursty variable-arrival trace of synthetic VWW frames through
the VisionEngine: requests microbatch through the deploy-folded (BN
folded + 8-bit PTQ) P²M stem and backbone, free slots are zero-padded,
and per-request latency splits into queueing delay vs launch wall-clock
(DESIGN.md §7.2).

Run:  PYTHONPATH=src python examples/serve_vww_p2m.py --requests 24
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs.p2m_vww import SERVE_MAX_BATCH, SERVE_MAX_QUEUE
from repro.data import SyntheticVWW
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.serving import VisionEngine, VisionRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--image-size", type=int, default=80)
    ap.add_argument("--max-batch", type=int, default=SERVE_MAX_BATCH)
    ap.add_argument("--max-queue", type=int, default=SERVE_MAX_QUEUE)
    args = ap.parse_args()

    cfg = MNV2Config(variant="p2m", image_size=args.image_size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    batch = SyntheticVWW(image_size=args.image_size,
                         batch=args.requests).batch_at(0)

    # bursty arrivals: clumps of frames every few ticks
    rng = np.random.default_rng(0)
    tick, reqs = 0, []
    for uid in range(args.requests):
        if uid and uid % 5 == 0:
            tick += int(rng.integers(1, 4))
        reqs.append(VisionRequest(uid=uid, image=batch["images"][uid],
                                  arrival_tick=tick))

    engine = VisionEngine(params, bn, cfg, max_batch=args.max_batch,
                          max_queue=args.max_queue)
    done = engine.run(reqs)

    correct = sum(r.label == int(batch["labels"][r.uid]) for r in done)
    print(f"served {len(done)}/{args.requests} "
          f"(accuracy vs labels {correct / len(done):.2f} — untrained net)")
    for r in done[: args.max_batch + 2]:
        print(f"  uid={r.uid:3d} arrived@{r.arrival_tick:<3d} "
              f"served@{r.served_tick:<3d} queue={r.queue_ticks} ticks  "
              f"launch={r.batch_wall_us / 1e3:.1f} ms  label={r.label}")
    s = engine.latency_summary()
    print(f"launches={s['launches']} utilization={s['utilization']:.2f} "
          f"mean_queue={s['mean_queue_ticks']:.2f} ticks "
          f"mean_launch={s['mean_launch_us'] / 1e3:.1f} ms "
          f"evictions={s['evictions']}")


if __name__ == "__main__":
    main()
