"""Continuous-batching serving demo on a reduced LM (CPU).

Shows the ServeEngine's slot lifecycle: 12 requests share 4 decode
slots; requests join as slots free up; outputs match per-request greedy
decode exactly (tested in tests/test_serving.py).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="text-family arch id (reduced config)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, max_batch=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(4, 16))).tolist()
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.new_tokens))
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"{args.arch}: served {len(done)} requests / {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s on CPU, {args.slots} slots)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt len {len(r.prompt)} → {r.output[:10]}…")


if __name__ == "__main__":
    main()
