"""Mixed-traffic serving demo: LM continuous batching + vision frames
through the multi-engine front door (CPU).

The ServeEngine's slot lifecycle is unchanged — requests share decode
slots, join as slots free up, and outputs match per-request greedy
decode exactly (tests/test_serving.py) — but submission now goes through
the FrontDoor (repro.launch.serve), which routes each request to its
engine by type and merges the completion streams.  LM prefill runs the
chunked fast path (--prefill-chunk tokens per tick in one compiled
launch).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import SyntheticVWW
from repro.launch.serve import FrontDoor
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.serving import Request, ServeEngine, VisionEngine, VisionRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="text-family arch id (reduced config)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--vision-requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    lm = ServeEngine(params, cfg, max_batch=args.slots, max_len=256,
                     prefill_chunk=args.prefill_chunk)

    vcfg = MNV2Config(variant="p2m", image_size=40, width=0.25,
                      head_channels=64)
    vparams, vbn = init_mnv2(jax.random.PRNGKey(1), vcfg)
    vision = VisionEngine(vparams, vbn, vcfg, max_batch=4)

    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(4, 16))).tolist()
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.new_tokens))
    frames = SyntheticVWW(image_size=40,
                          batch=args.vision_requests).batch_at(0)["images"]
    for uid in range(args.vision_requests):
        reqs.append(VisionRequest(uid=uid, image=frames[uid],
                                  arrival_tick=2 * uid))  # trickle of frames

    door = FrontDoor(lm=lm, vision=vision)
    t0 = time.perf_counter()
    done = door.run(reqs)
    dt = time.perf_counter() - t0

    lm_done = [r for n, r in done if n == "lm"]
    v_done = [r for n, r in done if n == "vision"]
    toks = sum(len(r.output) for r in lm_done)
    print(f"{args.arch} + p2m-vww via front door: {len(lm_done)} LM requests "
          f"/ {toks} tokens + {len(v_done)} frames in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU, {args.slots} slots, "
          f"prefill chunk {args.prefill_chunk})")
    for r in lm_done[:3]:
        print(f"  lm  req {r.uid}: prompt len {len(r.prompt)} "
              f"(prefill+decode {r.serve_ticks} ticks) → {r.output[:10]}…")
    for r in v_done[:3]:
        print(f"  img req {r.uid}: served@{r.served_tick} "
              f"queue={r.queue_ticks} ticks label={r.label}")
    for name, s in door.latency_summary().items():
        print(f"  {name}: launches={s['launches']} "
              f"mean_queue={s['mean_queue_ticks']:.2f} ticks "
              f"mean_launch={s['mean_launch_us'] / 1e3:.1f} ms")


if __name__ == "__main__":
    main()
