"""LR schedules.  ``step_decay`` is the paper's recipe (×0.2 at epoch
35 and every 45 thereafter → expressed in steps by the caller)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(base_lr: float, boundaries: tuple[int, ...], factor: float = 0.2):
    """Paper §5.1: LR decays by `factor` at each boundary step."""

    def fn(step):
        lr = jnp.asarray(base_lr, jnp.float32)
        for b in boundaries:
            lr = jnp.where(step >= b, lr * factor, lr)
        return lr

    return fn


def cosine_warmup(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return fn
