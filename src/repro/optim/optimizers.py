"""Optimizers (functional, optax-like minimal surface).

SGD+momentum is the paper's training recipe (§5.1, momentum 0.9);
AdamW is the LM-pretraining default.  Optimizer state mirrors the param
tree (so it inherits the params' shardings — FSDP shards optimizer
state for free), with global-norm clipping and decoupled weight decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params, step) -> (params, state)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgd(lr_fn: Callable, momentum: float = 0.9, clip_norm: float | None = None,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * (m + weight_decay
                          * p.astype(jnp.float32))).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(lr_fn: Callable, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float | None = 1.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        mh_scale = 1.0 / (1.0 - b1**t)
        vh_scale = 1.0 / (1.0 - b2**t)

        def upd(p, m_, v_):
            u = (m_ * mh_scale) / (jnp.sqrt(v_ * vh_scale) + eps)
            pf = p.astype(jnp.float32)
            return (pf - lr * (u + weight_decay * pf)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)
