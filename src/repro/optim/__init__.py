from repro.optim.optimizers import Optimizer, adamw, sgd
from repro.optim.schedules import constant, cosine_warmup, step_decay

__all__ = ["Optimizer", "adamw", "sgd", "constant", "cosine_warmup", "step_decay"]
