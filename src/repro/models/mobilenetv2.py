"""MobileNetV2 for VWW (paper §5.1) — baseline and P²M-custom variants.

Baseline: standard MobileNetV2 (first conv 32ch, last bottleneck 320ch)
supporting full-resolution 560×560 input, with the last inverted-residual
block's channels reduced 3× (paper: to avoid overfitting on 2 classes).

P²M-custom: the first conv layer is replaced by the in-pixel P²M layer
(k=5, s=5, c_o=8, 8-bit ADC output — Table 1); the downstream block
schedule is unchanged, so the stack runs at the P²M output resolution
(112² for a 560² frame, vs 280² after the baseline's stride-2 stem) —
which is exactly where the paper's 7.15× MAdds reduction comes from.

Everything is functional: ``init_mnv2`` → params/state trees,
``apply_mnv2`` → logits.  ``layer_census`` returns the ConvSpec list the
EDP/MAdds analytics consume (paper Table 2 / Fig. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.energy import ConvSpec
from repro.core.p2m_conv import (
    P2MConvConfig,
    apply_p2m_conv_deploy,
    apply_p2m_conv_train,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.pixel_model import PixelModel

# (expansion t, out channels c, repeats n, first-block stride s)
MNV2_BLOCKS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


@dataclasses.dataclass(frozen=True)
class MNV2Config:
    variant: str = "baseline"  # "baseline" | "p2m"
    image_size: int = 560
    num_classes: int = 2
    width: float = 1.0
    head_channels: int = 1280
    last_block_div: int = 3  # paper: reduce last block channels 3×
    first_channels: int = 32
    p2m: P2MConvConfig = dataclasses.field(default_factory=P2MConvConfig)

    def block_schedule(self):
        blocks = []
        for idx, (t, c, n, s) in enumerate(MNV2_BLOCKS):
            c = int(round(c * self.width))
            if idx == len(MNV2_BLOCKS) - 1 and self.last_block_div > 1:
                c = max(8, c // self.last_block_div)
            blocks.append((t, c, n, s))
        return blocks


def smoke_config() -> MNV2Config:
    """Tiny reduced config for CPU smoke tests."""
    return MNV2Config(image_size=40, width=0.25, head_channels=64)


def head_out_channels(cfg: MNV2Config) -> int:
    """Channel width of the pre-pool head conv — the backbone's output
    feature dim (what `apply_mnv2_backbone` returns, and the
    ``in_channels`` a detection head on it must take).  The head never
    narrows below its configured width (the standard MNv2 convention:
    the width multiplier only widens it past 1.0)."""
    return int(round(cfg.head_channels * max(1.0, cfg.width)))


# ------------------------------------------------------------------ layers


def _conv_init(key, k, cin, cout, groups=1):
    fan_in = k * k * cin // groups
    return jax.random.normal(key, (k, k, cin // groups, cout), jnp.float32) * (
        2.0 / fan_in
    ) ** 0.5


def _bn_init(c):
    return {
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
    }


def _bn_state(c):
    return {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _bn(x, p, s, train: bool, momentum=0.9, eps=1e-5):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
        new_s = {
            "mean": momentum * s["mean"] + (1 - momentum) * mean,
            "var": momentum * s["var"] + (1 - momentum) * var,
        }
    else:
        mean, var, new_s = s["mean"], s["var"], s
    y = (x - mean) / jnp.sqrt(var + eps) * p["gamma"] + p["beta"]
    return y, new_s


def _relu6(x):
    return jnp.clip(x, 0.0, 6.0)


# ------------------------------------------------------------------ init


def init_mnv2(key: jax.Array, cfg: MNV2Config) -> tuple[dict, dict]:
    """Returns (params, state)."""
    keys = iter(jax.random.split(key, 256))
    params: dict[str, Any] = {}
    state: dict[str, Any] = {}

    if cfg.variant == "p2m":
        params["stem"] = init_p2m_conv(next(keys), cfg.p2m)
        state["stem"] = init_p2m_state(cfg.p2m)
        cin = cfg.p2m.out_channels
    else:
        c0 = int(round(cfg.first_channels * cfg.width))
        params["stem"] = {"w": _conv_init(next(keys), 3, 3, c0), "bn": _bn_init(c0)}
        state["stem"] = {"bn": _bn_state(c0)}
        cin = c0

    bidx = 0
    for t, c, n, s in cfg.block_schedule():
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = cin * t
            blk: dict[str, Any] = {}
            bst: dict[str, Any] = {}
            if t != 1:
                blk["expand"] = {"w": _conv_init(next(keys), 1, cin, hidden), "bn": _bn_init(hidden)}
                bst["expand"] = {"bn": _bn_state(hidden)}
            blk["dw"] = {
                "w": _conv_init(next(keys), 3, hidden, hidden, groups=hidden),
                "bn": _bn_init(hidden),
            }
            bst["dw"] = {"bn": _bn_state(hidden)}
            blk["project"] = {"w": _conv_init(next(keys), 1, hidden, c), "bn": _bn_init(c)}
            bst["project"] = {"bn": _bn_state(c)}
            params[f"block{bidx}"] = blk
            state[f"block{bidx}"] = bst
            bidx += 1
            cin = c

    ch = head_out_channels(cfg)
    params["head"] = {"w": _conv_init(next(keys), 1, cin, ch), "bn": _bn_init(ch)}
    state["head"] = {"bn": _bn_state(ch)}
    params["fc"] = {
        "w": jax.random.normal(next(keys), (ch, cfg.num_classes), jnp.float32) * 0.01,
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return params, state


# ------------------------------------------------------------------ apply


def apply_mnv2_stem(
    params: dict,
    state: dict,
    images: jax.Array,
    cfg: MNV2Config,
    pixel_model: PixelModel | None = None,
    *,
    train: bool = False,
    p2m_deploy: dict | None = None,
    p2m_impl: str | None = None,
) -> tuple[jax.Array, dict]:
    """First layer only: what the sensor executes for the P²M variant.

    (B, H, W, 3) → (B, Ho, Wo, C) stem activations, plus the new stem
    state.  Split out of :func:`apply_mnv2` so the streaming-video
    subsystem (`repro.video`, DESIGN.md §9) can cache these activations
    per stream and skip re-running the in-pixel layer on temporally
    redundant frames — the stem output is exactly the tensor that leaves
    the sensor, so its recompute rate is also the readout bandwidth.

    ``p2m_impl`` selects the conv path (`core.p2m_conv._resolve_impl`);
    the serving engines pass ``"patches"`` here when degrading to the
    reference conv after repeated kernel faults (DESIGN.md §10).
    """
    new_state: dict[str, Any] = {}
    if cfg.variant == "p2m":
        if p2m_deploy is not None:
            x = apply_p2m_conv_deploy(p2m_deploy, images, cfg.p2m, pixel_model,
                                      impl=p2m_impl)
            new_state["stem"] = state["stem"]
        else:
            x, st = apply_p2m_conv_train(
                params["stem"], state["stem"], images, cfg.p2m, pixel_model,
                train=train, impl=p2m_impl
            )
            new_state["stem"] = st
    else:
        x = _conv(images, params["stem"]["w"], stride=2)
        x, bn_st = _bn(x, params["stem"]["bn"], state["stem"]["bn"], train)
        x = _relu6(x)
        new_state["stem"] = {"bn": bn_st}
    return x, new_state


def apply_mnv2_backbone(
    params: dict,
    state: dict,
    x: jax.Array,
    cfg: MNV2Config,
    *,
    train: bool = False,
) -> tuple[jax.Array, dict]:
    """Inverted-residual stack + head conv on stem activations.

    (B, Ho, Wo, C_stem) → (B, h, w, head_channels) feature map (pre
    global-pool), plus the new block/head state.  The classification
    head pools this; the video detection head (`video/detect.py`) reads
    it at full spatial resolution.
    """
    new_state: dict[str, Any] = {}
    bidx = 0
    cin = x.shape[-1]
    for t, c, n, s in cfg.block_schedule():
        for i in range(n):
            stride = s if i == 0 else 1
            blk = params[f"block{bidx}"]
            bst = state[f"block{bidx}"]
            nst: dict[str, Any] = {}
            y = x
            if t != 1:
                y = _conv(y, blk["expand"]["w"])
                y, st_ = _bn(y, blk["expand"]["bn"], bst["expand"]["bn"], train)
                nst["expand"] = {"bn": st_}
                y = _relu6(y)
            y = _conv(y, blk["dw"]["w"], stride=stride, groups=y.shape[-1])
            y, st_ = _bn(y, blk["dw"]["bn"], bst["dw"]["bn"], train)
            nst["dw"] = {"bn": st_}
            y = _relu6(y)
            y = _conv(y, blk["project"]["w"])
            y, st_ = _bn(y, blk["project"]["bn"], bst["project"]["bn"], train)
            nst["project"] = {"bn": st_}
            if stride == 1 and cin == c:
                y = y + x
            x = y
            new_state[f"block{bidx}"] = nst
            bidx += 1
            cin = c

    x = _conv(x, params["head"]["w"])
    x, st_ = _bn(x, params["head"]["bn"], state["head"]["bn"], train)
    new_state["head"] = {"bn": st_}
    x = _relu6(x)
    return x, new_state


def apply_mnv2(
    params: dict,
    state: dict,
    images: jax.Array,
    cfg: MNV2Config,
    pixel_model: PixelModel | None = None,
    *,
    train: bool = False,
    p2m_deploy: dict | None = None,
    p2m_impl: str | None = None,
) -> tuple[jax.Array, dict]:
    """(B, H, W, 3) → (B, num_classes) logits, plus new state."""
    x, stem_state = apply_mnv2_stem(
        params, state, images, cfg, pixel_model, train=train,
        p2m_deploy=p2m_deploy, p2m_impl=p2m_impl,
    )
    x, new_state = apply_mnv2_backbone(params, state, x, cfg, train=train)
    new_state = {**stem_state, **new_state}
    x = x.mean(axis=(1, 2))
    logits = x @ params["fc"]["w"] + params["fc"]["b"]
    # (no "fc" entry in the state tree: the head is stateless, and the
    # output state must mirror the input structure exactly so one
    # sharding tree serves jit in_shardings and out_shardings alike)
    return logits, new_state


# ------------------------------------------------------------------ census


def layer_census(cfg: MNV2Config, *, include_in_pixel: bool = False) -> list[ConvSpec]:
    """ConvSpec list for MAdds / delay / peak-memory analytics.

    For the P²M variant the in-pixel first layer is excluded by default
    (it runs in the sensor, not the SoC) — ``include_in_pixel=True`` adds
    it back for ablations.
    """
    census: list[ConvSpec] = []
    i = cfg.image_size

    if cfg.variant == "p2m":
        hw = cfg.p2m.out_spatial(i)
        if include_in_pixel:
            census.append(
                ConvSpec(cfg.p2m.kernel, 3, cfg.p2m.out_channels, hw, hw)
            )
        cin = cfg.p2m.out_channels
    else:
        hw = (i + 1) // 2
        c0 = int(round(cfg.first_channels * cfg.width))
        census.append(ConvSpec(3, 3, c0, hw, hw))
        cin = c0

    for t, c, n, s in cfg.block_schedule():
        for idx in range(n):
            stride = s if idx == 0 else 1
            hidden = cin * t
            if t != 1:
                census.append(ConvSpec(1, cin, hidden, hw, hw))
            out_hw = -(-hw // stride)
            census.append(ConvSpec(3, hidden, hidden, out_hw, out_hw, groups=hidden))
            census.append(ConvSpec(1, hidden, c, out_hw, out_hw))
            hw = out_hw
            cin = c

    ch = head_out_channels(cfg)
    census.append(ConvSpec(1, cin, ch, hw, hw))
    census.append(ConvSpec(1, ch, cfg.num_classes, 1, 1))
    return census


def peak_activation_bytes(cfg: MNV2Config, *, fused_blocks: bool) -> int:
    """Peak activation memory, int8 elements (VWW-challenge accounting).

    ``fused_blocks=False``: every conv output is a materialized buffer and
    the peak is the largest single tensor — the t× expansion buffers
    dominate.  This reproduces the paper's *baseline* column exactly
    (7.53 / 1.2 / 0.311 MB = the 96-channel expansion at stage-2 res).

    ``fused_blocks=True``: inverted-residual blocks stream per-channel
    (TFLite-micro style) so expansions are never materialized; the peak is
    the largest (block input + block output) pair.  This reproduces the
    paper's *P²M-custom* column exactly (0.30 / 0.049 / 0.013 MB =
    8ch input + 16ch output at the P²M resolution).  The paper's Table 2
    mixes these two conventions across its columns — defensible (the P²M
    model targets fused MCU kernels; the baseline doesn't fit an MCU under
    either convention) but worth making explicit.  See EXPERIMENTS.md.
    """
    peak = 0
    i = cfg.image_size
    if cfg.variant == "p2m":
        hw = cfg.p2m.out_spatial(i)
        cin = cfg.p2m.out_channels
        peak = max(peak, hw * hw * cin)
    else:
        hw = (i + 1) // 2
        cin = int(round(cfg.first_channels * cfg.width))
        peak = (
            max(peak, i * i * 3, hw * hw * cin)
            if not fused_blocks
            else max(peak, i * i * 3 + hw * hw * cin)
        )

    for t, c, n, s in cfg.block_schedule():
        for idx in range(n):
            stride = s if idx == 0 else 1
            hidden = cin * t
            out_hw = -(-hw // stride)
            if fused_blocks:
                peak = max(peak, hw * hw * cin + out_hw * out_hw * c)
            else:
                peak = max(peak, hw * hw * hidden, out_hw * out_hw * hidden,
                           out_hw * out_hw * c)
            hw = out_hw
            cin = c
    ch = head_out_channels(cfg)
    peak = max(peak, hw * hw * cin + hw * hw * ch if fused_blocks else hw * hw * ch)
    return peak
