"""Llama-3.2-Vision-style VLM backbone: a decoder LM with gated
cross-attention layers to image-patch embeddings every Nth layer.

Per the brief the vision frontend is a **stub** — ``input_specs`` supply
precomputed patch embeddings (B, N_img, d_model).  The framework's P²M
integration point (`core.frontend.P2MFrontend`) can replace that stub
with the in-pixel compressive embedder (see DESIGN.md §5).

Layer stack: groups of (period−1 self layers + 1 gated cross layer),
scanned over groups with an inner scan over the self layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import dense_attention, gqa_repeat
from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, make, split_tree
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    attention_block,
    cached_attention,
    embed_tokens,
    init_attention,
    init_embedding,
    init_kv_cache,
    init_mlp,
    init_norm,
    lm_head,
)
from repro.parallel import shard


def _n_groups(cfg: ModelConfig) -> tuple[int, int]:
    period = cfg.cross_attn_period
    assert period > 1 and cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period, period - 1  # (groups, self layers per group)


def init_vlm(key: jax.Array, cfg: ModelConfig) -> tuple[dict, dict]:
    kg = KeyGen(key)
    n_groups, n_self = _n_groups(cfg)
    GS = (n_groups, n_self)
    G = (n_groups,)
    self_layers = {
        "attn_norm": init_norm(cfg, GS, ("layers", "layers")),
        "attn": init_attention(kg, cfg, GS),
        "mlp_norm": init_norm(cfg, GS, ("layers", "layers")),
        "mlp": init_mlp(kg, cfg, GS),
    }
    cross_layers = {
        "norm": init_norm(cfg, G),
        "attn": init_attention(kg, cfg, G),
        "gate_attn": make(None, G, ("layers",), init="zeros"),
        "mlp_norm": init_norm(cfg, G),
        "mlp": init_mlp(kg, cfg, G),
        "gate_mlp": make(None, G, ("layers",), init="zeros"),
    }
    tree: dict[str, Any] = {
        "embed": init_embedding(kg, cfg),
        "self": self_layers,
        "cross": cross_layers,
    }
    return split_tree(tree)


def _fix_axes_for_double_stack(axes: dict) -> dict:
    return axes  # self layers carry two leading stack dims, both unsharded


def _cross_kv(p: dict, image_embeds: jax.Array, cfg: ModelConfig):
    """Project image embeddings to this cross layer's K/V (no RoPE)."""
    b, n, _ = image_embeds.shape
    hd = cfg.resolved_head_dim
    k = (image_embeds @ p["wk"]).reshape(b, n, cfg.n_kv_heads, hd)
    v = (image_embeds @ p["wv"]).reshape(b, n, cfg.n_kv_heads, hd)
    return k, v


def _cross_block(p: dict, x, image_embeds, cfg: ModelConfig,
                 kv: tuple | None = None):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h = apply_norm(p["norm"], x, cfg)
    q = (h @ p["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    if kv is None:
        k, v = _cross_kv(p["attn"], image_embeds, cfg)
    else:
        k, v = kv
    n_img = k.shape[1]
    kr = gqa_repeat(k, cfg.n_heads)
    vr = gqa_repeat(v, cfg.n_heads)
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.zeros((b, n_img), jnp.int32)
    out = dense_attention(q, kr, vr, qpos, kpos, causal=False)
    out = out.reshape(b, s, cfg.q_dim) @ p["attn"]["wo"]
    x = x + (jnp.tanh(p["gate_attn"]) * out).astype(x.dtype)
    h = apply_norm(p["mlp_norm"], x, cfg)
    x = x + (jnp.tanh(p["gate_mlp"]) * apply_mlp(p["mlp"], h)).astype(x.dtype)
    return shard(x, "batch", "seq", "embed_act")


def forward(params: dict, tokens: jax.Array, image_embeds: jax.Array,
            cfg: ModelConfig, positions: jax.Array | None = None):
    """tokens (B, S) + image_embeds (B, N_img, d) → (logits, aux=0)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed_tokens(params["embed"], tokens, cfg)
    image_embeds = shard(image_embeds.astype(x.dtype), "batch", None, "embed_act")

    def self_layer(x, lp):
        h = apply_norm(lp["attn_norm"], x, cfg)
        x = x + attention_block(lp["attn"], h, positions, cfg)
        h = apply_norm(lp["mlp_norm"], x, cfg)
        return shard(x + apply_mlp(lp["mlp"], h), "batch", "seq", "embed_act")

    self_fn = self_layer
    cross_fn = lambda cp, x: _cross_block(cp, x, image_embeds, cfg)
    if cfg.remat:
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        self_fn = jax.checkpoint(self_layer, policy=policy)
        cross_fn = jax.checkpoint(cross_fn, policy=policy)

    def group_fn(x, gp):
        sp, cp = gp
        x, _ = jax.lax.scan(lambda c, lp: (self_fn(c, lp), None), x, sp)
        return cross_fn(cp, x), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, gp: group_fn(c, gp),
                            x, (params["self"], params["cross"]))
    else:
        n_groups, _ = _n_groups(cfg)
        for g in range(n_groups):
            sp = jax.tree.map(lambda a: a[g], params["self"])
            cp = jax.tree.map(lambda a: a[g], params["cross"])
            x, _ = group_fn(x, (sp, cp))
    return lm_head(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ decode


def init_vlm_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                   abstract=False):
    """Self-attn KV cache (n_layers_self stacked) + precomputed cross K/V."""
    n_groups, n_self = _n_groups(cfg)
    hd = cfg.resolved_head_dim
    self_cache = init_kv_cache(cfg, batch, max_len, n_groups * n_self,
                               abstract=abstract)
    cross = {
        "k": make(None, (n_groups, batch, cfg.n_image_tokens, cfg.n_kv_heads, hd),
                  ("layers", "cache_batch", None, "cache_heads", None),
                  init="zeros", dtype=cfg.dtype, abstract=abstract),
        "v": make(None, (n_groups, batch, cfg.n_image_tokens, cfg.n_kv_heads, hd),
                  ("layers", "cache_batch", None, "cache_heads", None),
                  init="zeros", dtype=cfg.dtype, abstract=abstract),
    }
    return split_tree({"self": self_cache, "cross": cross})


def prefill_cross_kv(params: dict, image_embeds: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V once per request (encoder side)."""
    n_groups, _ = _n_groups(cfg)
    ks, vs = [], []
    for g in range(n_groups):
        cp = jax.tree.map(lambda a: a[g], params["cross"])
        k, v = _cross_kv(cp["attn"], image_embeds, cfg)
        ks.append(k)
        vs.append(v)
    return {"k": jnp.stack(ks), "v": jnp.stack(vs)}


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig):
    x = embed_tokens(params["embed"], tokens, cfg)
    n_groups, n_self = _n_groups(cfg)
    ck, cv = cache["self"]["k"], cache["self"]["v"]
    nks, nvs = [], []
    for g in range(n_groups):
        for i in range(n_self):
            li = g * n_self + i
            lp = jax.tree.map(lambda a: a[g][i], params["self"])
            h = apply_norm(lp["attn_norm"], x, cfg)
            att, nk, nv = cached_attention(lp["attn"], h, ck[li], cv[li], pos, cfg)
            x = x + att
            h = apply_norm(lp["mlp_norm"], x, cfg)
            x = x + apply_mlp(lp["mlp"], h)
            nks.append(nk)
            nvs.append(nv)
        cp = jax.tree.map(lambda a: a[g], params["cross"])
        kv = (cache["cross"]["k"][g], cache["cross"]["v"][g])
        x = _cross_block(cp, x, None, cfg, kv=kv)
    new_cache = {
        "self": {"k": jnp.stack(nks), "v": jnp.stack(nvs)},
        "cross": cache["cross"],
    }
    return lm_head(params["embed"], x, cfg), new_cache
