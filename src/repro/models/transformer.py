"""Decoder-only LM: dense (qwen/llama/stablelm) and MoE (qwen3-moe,
mixtral) families.  Layers run under ``lax.scan`` over stacked params
with per-layer remat — the production configuration for 16-80 layer
stacks (small HLO, checkpointed activations).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, split_tree
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    attention_block,
    cached_attention,
    embed_tokens,
    init_attention,
    init_embedding,
    init_kv_cache,
    init_mlp,
    init_norm,
    lm_head,
)
from repro.models.moe import apply_moe, init_moe
from repro.parallel import shard

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def init_lm(key: jax.Array, cfg: ModelConfig) -> tuple[dict, dict]:
    """Returns (params, logical_axes) trees.  Run under ``jax.eval_shape``
    to get abstract shapes without allocation (dry-run path)."""
    kg = KeyGen(key)
    L = (cfg.n_layers,)
    layers: dict[str, Any] = {
        "attn_norm": init_norm(cfg, L),
        "attn": init_attention(kg, cfg, L),
        "mlp_norm": init_norm(cfg, L),
    }
    if cfg.family == "moe":
        layers["moe"] = init_moe(kg, cfg, L)
    else:
        layers["mlp"] = init_mlp(kg, cfg, L)
    tree = {"embed": init_embedding(kg, cfg), "layers": layers}
    return split_tree(tree)


def _layer(x, lp, positions, cfg: ModelConfig, *, impl: str):
    h = apply_norm(lp["attn_norm"], x, cfg)
    x = x + attention_block(lp["attn"], h, positions, cfg,
                            window=cfg.sliding_window, impl=impl)
    h = apply_norm(lp["mlp_norm"], x, cfg)
    if "moe" in lp:
        y, aux = apply_moe(lp["moe"], h, cfg)
    else:
        y, aux = apply_mlp(lp["mlp"], h), jnp.zeros((), jnp.float32)
    x = shard(x + y, "batch", "seq", "embed_act")
    return x, aux


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            positions: jax.Array | None = None, *, impl: str = "flash"):
    """tokens: (B, S) → (logits (B, S, V) fp32, aux_loss scalar)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed_tokens(params["embed"], tokens, cfg)

    layer_fn = functools.partial(_layer, positions=positions, cfg=cfg, impl=impl)
    if cfg.remat:
        layer_fn = jax.checkpoint(layer_fn, policy=REMAT_POLICY)

    if cfg.scan_layers:
        def body(carry, lp):
            x, aux = layer_fn(carry[0], lp)
            return (x, carry[1] + aux), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = layer_fn(x, lp)
            aux = aux + a

    return lm_head(params["embed"], x, cfg), aux


# ------------------------------------------------------------------ decode


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, abstract=False):
    cache = init_kv_cache(cfg, batch, max_len, cfg.n_layers,
                          abstract=abstract, window=cfg.sliding_window)
    return split_tree(cache)


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig):
    """One-token decode.  tokens: (B, 1); pos: (B,) absolute positions.

    Returns (logits (B, 1, V), new_cache).
    """
    x = embed_tokens(params["embed"], tokens, cfg)

    def body(x, xs):
        lp, ck, cv = xs
        h = apply_norm(lp["attn_norm"], x, cfg)
        att, nk, nv = cached_attention(lp["attn"], h, ck, cv, pos, cfg,
                                       window=cfg.sliding_window)
        x = x + att
        h = apply_norm(lp["mlp_norm"], x, cfg)
        if "moe" in lp:
            y, _ = apply_moe(lp["moe"], h, cfg)
        else:
            y = apply_mlp(lp["mlp"], h)
        return x + y, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": nk, "v": nv}
    return lm_head(params["embed"], x, cfg), new_cache
