"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with
data-dependent per-channel decay.

Time-mix: token-shift ddlerp (static μ + low-rank data-dependent mix),
projections r/k/v/g, data-dependent decay ``w_t = exp(−exp(ω_t))`` with
``ω_t = ω₀ + tanh(x @ A) @ B``, matrix-valued WKV state per head
(dk × dv), "bonus" u on the diagonal term, per-head GroupNorm, output
gating.  Channel-mix: token-shifted squared-ReLU FFN with sigmoid
receptance.

Training uses a **chunked-parallel WKV** (GLA-style): intra-chunk is a
masked matmul against cumulative decays; inter-chunk state flows through
a ``lax.scan``.  Sub-chunks of 16 keep ``exp(ΔL)`` within fp32 range
(log-decay clamped ≥ −5/token ⇒ |ΔL| ≤ 80 < 88).  Decode carries O(1)
state: (token-shift vector, WKV matrix) per layer — this is why rwkv6-3b
runs the 500k-context cell with a constant-size cache.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_wkv import ops as wkv_ops
from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, split_tree, make
from repro.models.layers import (
    apply_norm,
    embed_tokens,
    init_embedding,
    init_norm,
    lm_head,
)
from repro.parallel import shard

LOG_DECAY_MIN = -5.0  # clamp on per-token log decay (numerical guard)
WKV_CHUNK = 16


def _mix_params(kg: KeyGen, cfg: ModelConfig, L: tuple, n_streams: int) -> dict:
    d, r = cfg.d_model, cfg.rwkv_lora_dim
    return {
        "mu": make(None, L + (n_streams, d), ("layers", None, "embed_act"),
                   init="zeros"),
        "lora_a": make(kg(), L + (d, n_streams * r), ("layers", "embed", None),
                       dtype=cfg.dtype),
        "lora_b": make(kg(), L + (n_streams, r, d), ("layers", None, None, "embed"),
                       scale=0.01, dtype=cfg.dtype),
    }


def init_rwkv(key: jax.Array, cfg: ModelConfig) -> tuple[dict, dict]:
    kg = KeyGen(key)
    L = (cfg.n_layers,)
    d, hd = cfg.d_model, cfg.rwkv_head_dim
    h = cfg.n_rwkv_heads
    r = cfg.rwkv_lora_dim
    dt = cfg.dtype
    layers: dict[str, Any] = {
        "att_norm": init_norm(cfg, L),
        "ffn_norm": init_norm(cfg, L),
        "att": {
            "mix": _mix_params(kg, cfg, L, 5),  # r,k,v,g,w streams
            "wr": make(kg(), L + (d, d), ("layers", "embed", "heads"), dtype=dt),
            "wk": make(kg(), L + (d, d), ("layers", "embed", "heads"), dtype=dt),
            "wv": make(kg(), L + (d, d), ("layers", "embed", "heads"), dtype=dt),
            "wg": make(kg(), L + (d, d), ("layers", "embed", "heads"), dtype=dt),
            "wo": make(kg(), L + (d, d), ("layers", "heads", "embed"), dtype=dt),
            "w0": make(None, L + (h, hd), ("layers", "state", None),
                       init="constant", scale=-0.6),
            "w_lora_a": make(kg(), L + (d, r), ("layers", "embed", None), dtype=dt),
            "w_lora_b": make(kg(), L + (r, d), ("layers", None, "heads"),
                             scale=0.01, dtype=dt),
            "u": make(None, L + (h, hd), ("layers", "state", None),
                      init="constant", scale=0.5),
            "gn_scale": make(None, L + (h, hd), ("layers", "state", None), init="ones"),
            "gn_bias": make(None, L + (h, hd), ("layers", "state", None), init="zeros"),
        },
        "ffn": {
            "mix": _mix_params(kg, cfg, L, 2),  # r,k streams
            "wk": make(kg(), L + (d, cfg.d_ff), ("layers", "embed", "mlp"), dtype=dt),
            "wv": make(kg(), L + (cfg.d_ff, d), ("layers", "mlp", "embed"), dtype=dt),
            "wr": make(kg(), L + (d, d), ("layers", "embed", "heads"), dtype=dt),
        },
    }
    tree = {"embed": init_embedding(kg, cfg), "layers": layers}
    return split_tree(tree)


def _token_shift(x, prev):
    """Shift right by one: (B, S, d) with prev (B, d) as token −1."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p: dict, x, xx, cfg: ModelConfig):
    """Data-dependent lerp between x and shifted xx → one stream per μ row."""
    n = p["mu"].shape[0]
    r = cfg.rwkv_lora_dim
    dx = xx - x
    lo = jnp.tanh(x @ p["lora_a"])  # (B, S, n·r)
    lo = lo.reshape(x.shape[0], x.shape[1], n, r)
    adj = jnp.einsum("bsnr,nrd->bsnd", lo, p["lora_b"])
    mix = p["mu"][None, None] + adj  # (B, S, n, d)
    return x[:, :, None, :] + dx[:, :, None, :] * mix  # (B, S, n, d)


# ------------------------------------------------------------------ WKV


def wkv_naive(r, k, v, lw, u, state):
    """Per-token scan reference.  r/k/v/lw: (B, S, H, D); state (B, H, D, D).

    Returns (y (B,S,H,D), final state).  lw = log decay ≤ 0.
    """

    def step(s, inp):
        rt, kt, vt, lwt = inp  # (B, H, D)
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt, s + u[None, :, :, None] * kv)
        s_new = jnp.exp(lwt)[..., None] * s + kv
        return s_new, y

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, lw))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def wkv_chunked(r, k, v, lw, u, state, chunk: int = WKV_CHUNK,
                impl: str = "pallas"):
    """Chunk-parallel WKV (exact vs `wkv_naive` up to fp error — output
    *and* final state, pinned by the property suite in
    `tests/test_rwkv_wkv.py` over random lengths/chunks/initial states).

    Dispatch (``impl`` = `ModelConfig.wkv_impl`): "pallas" runs the fused
    kernel forward with its closed-form chunked VJP
    (`kernels/rwkv_wkv/ops.py`, interpret-mode off-TPU); "xla" the
    chunked ``lax.scan`` twin; "naive" the per-token scan."""
    if impl == "naive":
        return wkv_naive(r, k, v, lw, u, state)
    return wkv_ops.wkv(r, k, v, lw, u, state, chunk=chunk, impl=impl)


def _last_active(x, lengths, prev_tok):
    """Per-row shift state for a masked prefix: row b's last *active*
    position (lengths[b] − 1), keeping the previous shift state when the
    row advanced zero tokens."""
    b, s = x.shape[:2]
    idx = jnp.clip(lengths - 1, 0, s - 1)
    gathered = x[jnp.arange(b), idx]
    return jnp.where((lengths > 0)[:, None], gathered, prev_tok)


def _time_mix(p: dict, x, prev_tok, wkv_state, cfg: ModelConfig, *,
              chunked: bool = True, lengths=None):
    b, s, d = x.shape
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    xx = _token_shift(x, prev_tok)
    streams = _ddlerp(p["mix"], x, xx, cfg)  # (B, S, 5, d)
    xr, xk, xv, xg, xw = [streams[:, :, i] for i in range(5)]
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    omega = p["w0"].reshape(1, 1, h, hd) + (jnp.tanh(xw @ p["w_lora_a"])
                                            @ p["w_lora_b"]).reshape(b, s, h, hd)
    lw = jnp.clip(-jnp.exp(omega.astype(jnp.float32)), LOG_DECAY_MIN, -1e-6)

    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    if lengths is not None:
        # Masked prefix (chunked serving prefill): positions ≥ lengths[b]
        # carry lw = 0 (identity decay) and k = 0 (no kv update), so the
        # WKV state update is exactly the identity there — inactive rows
        # and the tail beyond a row's prompt leave the state untouched.
        active = (jnp.arange(s)[None] < lengths[:, None])[..., None, None]
        lw = jnp.where(active, lw, 0.0)
        kf = jnp.where(active, kf, 0.0)
    u = p["u"].astype(jnp.float32)
    if chunked:
        y, wkv_state = wkv_chunked(rf, kf, vf, lw, u, wkv_state,
                                   impl=cfg.wkv_impl)
    else:
        y, wkv_state = wkv_naive(rf, kf, vf, lw, u, wkv_state)

    # per-head GroupNorm
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y * p["gn_scale"][None, None] + p["gn_bias"][None, None]
    y = y.reshape(b, s, d).astype(x.dtype) * g
    shift = (x[:, -1, :] if lengths is None
             else _last_active(x, lengths, prev_tok))
    return y @ p["wo"], shift, wkv_state


def _channel_mix(p: dict, x, prev_tok, cfg: ModelConfig, *, lengths=None):
    xx = _token_shift(x, prev_tok)
    streams = _ddlerp(p["mix"], x, xx, cfg)
    xr, xk = streams[:, :, 0], streams[:, :, 1]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    kk = shard(kk, "batch", "seq", "mlp_act")
    rr = jax.nn.sigmoid(xr @ p["wr"])
    shift = (x[:, -1, :] if lengths is None
             else _last_active(x, lengths, prev_tok))
    return rr * (kk @ p["wv"]), shift


def init_rwkv_state(cfg: ModelConfig, batch: int, *, abstract=False):
    h, hd, d = cfg.n_rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    L = (cfg.n_layers,)
    tree = {
        "att_shift": make(None, L + (batch, d), ("layers", "cache_batch", "embed_act"),
                          init="zeros", dtype=cfg.dtype, abstract=abstract),
        "ffn_shift": make(None, L + (batch, d), ("layers", "cache_batch", "embed_act"),
                          init="zeros", dtype=cfg.dtype, abstract=abstract),
        "wkv": make(None, L + (batch, h, hd, hd),
                    ("layers", "cache_batch", "state", None, None),
                    init="zeros", dtype=jnp.float32, abstract=abstract),
    }
    return split_tree(tree)


def _layer(x, lp, state, cfg: ModelConfig, *, chunked=True, lengths=None):
    h = apply_norm(lp["att_norm"], x, cfg)
    att, att_shift, wkv = _time_mix(lp["att"], h, state["att_shift"],
                                    state["wkv"], cfg, chunked=chunked,
                                    lengths=lengths)
    x = x + att.astype(x.dtype)
    h = apply_norm(lp["ffn_norm"], x, cfg)
    ffn, ffn_shift = _channel_mix(lp["ffn"], h, state["ffn_shift"], cfg,
                                  lengths=lengths)
    x = shard(x + ffn.astype(x.dtype), "batch", "seq", "embed_act")
    new_state = {"att_shift": att_shift.astype(cfg.dtype),
                 "ffn_shift": ffn_shift.astype(cfg.dtype),
                 "wkv": wkv.astype(jnp.float32)}
    return x, new_state


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            state: dict | None = None, *, chunked: bool = True,
            lengths: jax.Array | None = None):
    """tokens (B,S) → (logits, aux=0, final_state).

    ``lengths`` (B,) masks each row to an active prefix: positions ≥
    lengths[b] are identity on the recurrent state (see `_time_mix`),
    and the token-shift states advance to the last *active* position —
    the masked-prefix contract `prefill_step` serves to the engine."""
    b, s = tokens.shape
    if state is None:
        state, _ = init_rwkv_state(cfg, b)
    x = embed_tokens(params["embed"], tokens, cfg)
    layer_fn = functools.partial(_layer, cfg=cfg, chunked=chunked,
                                 lengths=lengths)
    if cfg.remat:
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def body(carry, xs):
        lp, st = xs
        x, new_st = layer_fn(carry, lp, st)
        return x, new_st

    if cfg.scan_layers:
        x, new_states = jax.lax.scan(body, x, (params["layers"], state))
    else:
        sts = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            st = jax.tree.map(lambda a: a[i], state)
            x, ns = body(x, (lp, st))
            sts.append(ns)
        new_states = jax.tree.map(lambda *a: jnp.stack(a), *sts)
    logits = lm_head(params["embed"], x, cfg)
    return logits, jnp.zeros((), jnp.float32), new_states


def decode_step(params: dict, state: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig):
    """One-token decode with O(1) state (pos unused — state is positionless)."""
    logits, _, new_state = forward(params, tokens, cfg, state, chunked=False)
    return logits, new_state


def prefill_step(params: dict, state: dict, tokens: jax.Array,
                 lengths: jax.Array, cfg: ModelConfig):
    """Fused chunked prefill: advance row b by ``lengths[b] ∈ [0, C]``
    tokens in ONE chunked forward (the family ``prefill`` hook serving's
    `_chunk_step_for` prefers over C masked decode steps — valid because
    rwkv state is positionless).  Rows with lengths[b] = 0 keep their
    state bit-for-bit (identity masking, see `forward`).

    Returns (last_logits (B, V) — each row's logits at its last active
    position — and the advanced state)."""
    b, c = tokens.shape
    logits, _, new_state = forward(params, tokens, cfg, state, chunked=True,
                                   lengths=lengths)
    idx = jnp.clip(lengths - 1, 0, c - 1)
    last = logits[jnp.arange(b), idx]
    return last, new_state
