"""Whisper-style encoder-decoder audio backbone (arXiv:2212.04356).

The conv frame frontend is a **stub** per the brief: ``input_specs``
supply precomputed frame embeddings (B, S_src, d_model) — in a real
deployment that is the 2×conv1d stem (or, with ``--frontend p2m``, the
in-pixel/in-sensor P²M compressive capture).  Encoder: bidirectional
pre-LN transformer + sinusoidal positions.  Decoder: causal self-attn +
cross-attn to the encoder output, learned positions, tied softmax head.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import attend, dense_attention, gqa_repeat
from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, make, split_tree
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    cached_attention,
    init_attention,
    init_kv_cache,
    init_mlp,
    init_norm,
    mask_pad_vocab,
)
from repro.parallel import shard

MAX_DECODER_POSITIONS = 448


def _sinusoid(length: int, d: int):
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-dim * jnp.log(10000.0) / (d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_whisper(key: jax.Array, cfg: ModelConfig) -> tuple[dict, dict]:
    kg = KeyGen(key)
    Le = (cfg.n_encoder_layers,)
    Ld = (cfg.n_layers,)
    d = cfg.d_model
    enc = {
        "attn_norm": init_norm(cfg, Le),
        "attn": init_attention(kg, cfg, Le),
        "mlp_norm": init_norm(cfg, Le),
        "mlp": init_mlp(kg, cfg, Le, gated=False),
    }
    dec = {
        "self_norm": init_norm(cfg, Ld),
        "self_attn": init_attention(kg, cfg, Ld),
        "cross_norm": init_norm(cfg, Ld),
        "cross_attn": init_attention(kg, cfg, Ld),
        "mlp_norm": init_norm(cfg, Ld),
        "mlp": init_mlp(kg, cfg, Ld, gated=False),
    }
    tree: dict[str, Any] = {
        "token_embed": make(kg(), (cfg.padded_vocab, d), ("vocab", "embed"),
                            scale=d**-0.5, dtype=cfg.dtype),
        "pos_embed": make(kg(), (MAX_DECODER_POSITIONS, d), (None, "embed"),
                          scale=0.01, dtype=cfg.dtype),
        "enc": enc,
        "enc_final_norm": init_norm(cfg, ()),
        "dec": dec,
        "dec_final_norm": init_norm(cfg, ()),
    }
    return split_tree(tree)


def encode(params: dict, src_embeds: jax.Array, cfg: ModelConfig):
    """(B, S_src, d) stub frame embeddings → encoder states."""
    b, s, d = src_embeds.shape
    x = src_embeds.astype(cfg.dtype) + _sinusoid(s, d).astype(cfg.dtype)[None]
    x = shard(x, "batch", "seq", "embed_act")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def layer(x, lp):
        h = apply_norm(lp["attn_norm"], x, cfg)
        hd = cfg.resolved_head_dim
        q = (h @ lp["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = (h @ lp["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (h @ lp["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        out = attend(q, gqa_repeat(k, cfg.n_heads), gqa_repeat(v, cfg.n_heads),
                     positions, positions, causal=False)
        x = x + out.reshape(b, s, cfg.q_dim) @ lp["attn"]["wo"]
        h = apply_norm(lp["mlp_norm"], x, cfg)
        return shard(x + apply_mlp(lp["mlp"], h, activation="gelu"),
                     "batch", "seq", "embed_act"), None

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(layer, x, params["enc"])
    return apply_norm(params["enc_final_norm"], x, cfg)


def _decoder_cross(lp, x, enc_k, enc_v, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h = apply_norm(lp["cross_norm"], x, cfg)
    q = (h @ lp["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    n_src = enc_k.shape[1]
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.zeros((b, n_src), jnp.int32)
    out = dense_attention(q, gqa_repeat(enc_k, cfg.n_heads),
                          gqa_repeat(enc_v, cfg.n_heads), qpos, kpos,
                          causal=False)
    return x + out.reshape(b, s, cfg.q_dim) @ lp["cross_attn"]["wo"]


def _cross_kv(lp, enc_out, cfg: ModelConfig):
    b, n, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, n, cfg.n_kv_heads, hd)
    v = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, n, cfg.n_kv_heads, hd)
    return k, v


def forward(params: dict, src_embeds: jax.Array, tokens: jax.Array,
            cfg: ModelConfig):
    """Teacher-forced enc-dec forward → (logits (B, S_dec, V), aux=0)."""
    enc_out = encode(params, src_embeds, cfg)
    b, s = tokens.shape
    x = jnp.take(params["token_embed"], tokens, axis=0)
    x = x + params["pos_embed"][:s][None]
    x = shard(x, "batch", "seq", "embed_act")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def layer(x, lp):
        hd = cfg.resolved_head_dim
        h = apply_norm(lp["self_norm"], x, cfg)
        q = (h @ lp["self_attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = (h @ lp["self_attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (h @ lp["self_attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        out = attend(q, gqa_repeat(k, cfg.n_heads), gqa_repeat(v, cfg.n_heads),
                     positions, positions, causal=True)
        x = x + out.reshape(b, s, cfg.q_dim) @ lp["self_attn"]["wo"]
        x = _decoder_cross(lp, x, *_cross_kv(lp, enc_out, cfg), cfg)
        h = apply_norm(lp["mlp_norm"], x, cfg)
        return shard(x + apply_mlp(lp["mlp"], h, activation="gelu"),
                     "batch", "seq", "embed_act"), None

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(layer, x, params["dec"])
    x = apply_norm(params["dec_final_norm"], x, cfg)
    logits = (x @ params["token_embed"].T).astype(jnp.float32)
    logits = mask_pad_vocab(logits, cfg)
    return shard(logits, "batch", "seq", "vocab_act"), jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ decode


def init_whisper_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                       abstract=False):
    hd = cfg.resolved_head_dim
    n_src = cfg.max_source_positions
    self_cache = init_kv_cache(cfg, batch, max_len, cfg.n_layers,
                               abstract=abstract)
    cross = {
        "k": make(None, (cfg.n_layers, batch, n_src, cfg.n_kv_heads, hd),
                  ("layers", "cache_batch", None, "cache_heads", None),
                  init="zeros", dtype=cfg.dtype, abstract=abstract),
        "v": make(None, (cfg.n_layers, batch, n_src, cfg.n_kv_heads, hd),
                  ("layers", "cache_batch", None, "cache_heads", None),
                  init="zeros", dtype=cfg.dtype, abstract=abstract),
    }
    return split_tree({"self": self_cache, "cross": cross})


def prefill_cross_kv(params: dict, src_embeds: jax.Array, cfg: ModelConfig):
    enc_out = encode(params, src_embeds, cfg)
    ks, vs = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["dec"])
        k, v = _cross_kv(lp, enc_out, cfg)
        ks.append(k)
        vs.append(v)
    return {"k": jnp.stack(ks), "v": jnp.stack(vs)}


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig):
    b = tokens.shape[0]
    x = jnp.take(params["token_embed"], tokens, axis=0)
    pos_clip = jnp.minimum(pos, MAX_DECODER_POSITIONS - 1)
    x = x + params["pos_embed"][pos_clip][:, None, :]

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = apply_norm(lp["self_norm"], x, cfg)
        att, nk, nv = cached_attention(lp["self_attn"], h, ck, cv, pos, cfg,
                                       rope=False)
        x = x + att
        x = _decoder_cross(lp, x, xk, xv, cfg)
        h = apply_norm(lp["mlp_norm"], x, cfg)
        x = x + apply_mlp(lp["mlp"], h, activation="gelu")
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["dec"], cache["self"]["k"], cache["self"]["v"],
         cache["cross"]["k"], cache["cross"]["v"]))
    x = apply_norm(params["dec_final_norm"], x, cfg)
    logits = (x @ params["token_embed"].T).astype(jnp.float32)
    logits = mask_pad_vocab(logits, cfg)
    return logits, {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
