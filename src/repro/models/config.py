"""Shared model configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | rglru | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # None → d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    use_layernorm: bool = False  # stablelm-style LN instead of RMSNorm
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    sliding_window: int | None = None  # SWA / local-attention window
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "xla"  # "xla" | "shard_map" (EP with local combine)
    # VLM (cross-attention image layers)
    cross_attn_period: int = 0  # every Nth layer is cross-attn
    n_image_tokens: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    max_source_positions: int = 0
    # rglru hybrid (recurrentgemma)
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0
    conv_width: int = 4
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64
    chunk_size: int = 128
    # chunked-WKV backend: "pallas" (fused kernel + closed-form VJP,
    # interpret-mode off-TPU), "xla" (chunked lax.scan twin), "naive"
    # (per-token scan) — see kernels/rwkv_wkv and DESIGN.md §12
    wkv_impl: str = "pallas"
    # execution
    dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat: bool = True
    vocab_pad_multiple: int = 128  # pad embedding/logits for clean TP sharding

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m if m else self.vocab

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count_estimate(self) -> int:
        """Analytic N for roofline MODEL_FLOPS = 6·N·D (active params for MoE)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        per_layer_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "moe":
            experts = min(self.top_k, self.n_experts)
            per_layer_mlp = 3 * d * ff * experts + d * self.n_experts  # + router
        elif self.family == "rwkv":
            per_layer_attn = 6 * d * d  # r,k,v,g,o + decay loras (approx)
            per_layer_mlp = 3 * d * ff
        elif self.family == "rglru":
            # averaged over the rec:attn pattern
            rec = 3 * d * self.d_rnn + self.conv_width * self.d_rnn
            n_rec = sum(1 for b in self.block_pattern if b == "rec")
            frac_rec = n_rec / max(1, len(self.block_pattern))
            per_layer_attn = frac_rec * rec + (1 - frac_rec) * per_layer_attn
            per_layer_mlp = 3 * d * ff
        else:
            per_layer_mlp = 3 * d * ff
        n = self.n_layers * (per_layer_attn + per_layer_mlp)
        if self.family == "vlm" and self.cross_attn_period:
            n_cross = self.n_layers // self.cross_attn_period
            n += n_cross * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        if self.family == "encdec":
            n += self.n_encoder_layers * (per_layer_attn + per_layer_mlp)
            n += self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        n += v * d * (1 if self.tie_embeddings else 2)
        return int(n)
