"""Mixture-of-Experts FFN: top-k routing, capacity-based scatter dispatch,
expert-parallel batched GEMMs.

Dispatch avoids the GShard (tokens, E, capacity) one-hot einsum blowup:
tokens are scattered into a per-group (E, C, d) buffer via indexed
``.at[].add`` (positions from a within-group cumsum, so no cross-shard
prefix dependency), experts run as one batched einsum with the expert dim
sharded over "model" (EP) when divisible — otherwise the d_ff dim shards
(TP-inside-experts, the mixtral case) — and results gather back with the
router combine weights.  Overflow beyond capacity drops (standard
capacity-factor semantics); the aux load-balancing loss (Switch) keeps
load flat so drops stay rare.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, make
from repro.parallel import current_plan, shard
from repro.parallel.axes import logical_spec


def init_moe(kg: KeyGen, cfg: ModelConfig, L: tuple) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.dtype
    return {
        "router": make(kg(), L + (d, e), ("layers", "embed", None),
                       dtype=jnp.float32),
        "wi": make(kg(), L + (e, d, ff), ("layers", "expert", "embed", "mlp"), dtype=dt),
        "wg": make(kg(), L + (e, d, ff), ("layers", "expert", "embed", "mlp"), dtype=dt),
        "wo": make(kg(), L + (e, ff, d), ("layers", "expert", "mlp", "embed"), dtype=dt),
    }


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cfg.top_k, -(-c // 8) * 8)  # round up to 8 for layout


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (G, S, d) — G is the (data-sharded) group/batch dim.

    Returns (y, aux_loss).  Dispatches to the shard_map EP path when
    configured and the mesh allows it (see :func:`apply_moe_shard_map`).
    """
    plan = current_plan()
    if cfg.moe_impl == "shard_map" and plan is not None:
        expert_axis = plan.rules.get("expert")
        if (isinstance(expert_axis, str)
                and expert_axis in plan.mesh.shape
                and cfg.n_experts % plan.mesh.shape[expert_axis] == 0):
            return apply_moe_shard_map(p, x, cfg, plan, expert_axis)
    return _apply_moe_xla(p, x, cfg)


def _apply_moe_xla(p: dict, x: jax.Array, cfg: ModelConfig):
    g, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ p["router"])  # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (G, S, K)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E · Σ_e f_e · P_e  (f: token fraction, P: mean prob).
    token_frac = jnp.zeros((g, e), jnp.float32).at[
        jnp.arange(g)[:, None, None], top_idx
    ].add(1.0) / (s * k)
    mean_prob = probs.mean(axis=1)
    aux = e * jnp.mean(jnp.sum(token_frac * mean_prob, axis=-1))

    # Positions within each expert (within-group cumsum — shard-local).
    flat_e = top_idx.reshape(g, s * k)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    cum = jnp.cumsum(oh, axis=1)
    pos = jnp.take_along_axis(cum, flat_e[..., None], axis=-1)[..., 0] - 1
    keep = (pos < c).astype(x.dtype)  # capacity drop mask

    # Scatter tokens into (G, E, C, d) expert buffers.
    x_rep = jnp.repeat(x, k, axis=1)  # (G, S·K, d) — token t occupies slots tk..tk+k-1
    pos_c = jnp.clip(pos, 0, c - 1)

    def scatter_group(xb, eb, pb, kb):
        buf = jnp.zeros((e, c, d), x.dtype)
        return buf.at[eb, pb].add(xb * kb[:, None])

    buf = jax.vmap(scatter_group)(x_rep, flat_e, pos_c, keep)  # (G, E, C, d)
    buf = shard(buf, "batch", "expert", None, "embed_act")

    # Expert SwiGLU, batched over E (EP over "model" when divisible).
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    gate = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    h = jax.nn.silu(gate) * h
    h = shard(h, "batch", "expert", None, "mlp_act")
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    out = shard(out, "batch", "expert", None, "embed_act")

    # Gather back with combine weights.
    def gather_group(ob, eb, pb):
        return ob[eb, pb]  # (S·K, d)

    y_flat = jax.vmap(gather_group)(out, flat_e, pos_c)
    w_comb = (top_vals.reshape(g, s * k) * keep.astype(jnp.float32)).astype(x.dtype)
    y = (y_flat * w_comb[..., None]).reshape(g, s, k, d).sum(axis=2)
    return y, aux * cfg.router_aux_weight


def apply_moe_shard_map(p: dict, x: jax.Array, cfg: ModelConfig, plan,
                        expert_axis: str):
    """Expert-parallel MoE with *local combine* (beyond-paper §Perf).

    The XLA-partitioned path lets SPMD place the combine collective at
    slot granularity: an fp32 (G, S·K, d) all-reduce per layer — 733 GB/
    device/step for qwen3-moe × train_4k.  Here each expert shard keeps
    the whole dispatch/дgemm/combine local to its E/n experts (tokens are
    replicated across the expert axis, which DP already guarantees) and
    contributes a *token-granular partial sum*; one bf16 (G, S, d) psum
    per layer replaces the fp32 slot-granular one — k·(fp32/bf16) = 16×
    less collective volume, with bit-identical capacity/drop semantics
    (positions come from the same global cumsum order, masked per shard).
    """
    g, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)
    mesh = plan.mesh
    n_shards = mesh.shape[expert_axis]
    e_loc = e // n_shards
    batch_axes = plan.rules.get("batch")
    x_spec = logical_spec(x.shape, ("batch", None, None), plan)
    w_spec = P(expert_axis, None, None)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), w_spec, w_spec, P(expert_axis, None, None), x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    def body(router, wi, wg, wo, xl):
        gl, sl, _ = xl.shape
        logits = xl.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_vals, top_idx = jax.lax.top_k(probs, k)
        top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

        token_frac = jnp.zeros((gl, e), jnp.float32).at[
            jnp.arange(gl)[:, None, None], top_idx
        ].add(1.0) / (sl * k)
        aux = e * jnp.mean(jnp.sum(token_frac * probs.mean(axis=1), axis=-1))
        aux = jax.lax.pmean(aux, tuple(a for a in mesh.axis_names
                                       if a != expert_axis))

        base = jax.lax.axis_index(expert_axis) * e_loc
        flat_e = top_idx.reshape(gl, sl * k)
        # positions from the GLOBAL per-expert cumsum (same order as the
        # XLA path), then restrict to this shard's expert range
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        cum = jnp.cumsum(oh, axis=1)
        pos = jnp.take_along_axis(cum, flat_e[..., None], axis=-1)[..., 0] - 1
        local = (flat_e >= base) & (flat_e < base + e_loc)
        keep = (local & (pos < c)).astype(xl.dtype)
        le = jnp.clip(flat_e - base, 0, e_loc - 1)
        pc = jnp.clip(pos, 0, c - 1)

        x_rep = jnp.repeat(xl, k, axis=1)

        def scatter_group(xb, eb, pb, kb):
            return jnp.zeros((e_loc, c, d), xl.dtype).at[eb, pb].add(
                xb * kb[:, None])

        buf = jax.vmap(scatter_group)(x_rep, le, pc, keep)
        h = jnp.einsum("gecd,edf->gecf", buf, wi)
        gate = jnp.einsum("gecd,edf->gecf", buf, wg)
        out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * h, wo)

        y_slot = jax.vmap(lambda ob, eb, pb: ob[eb, pb])(out, le, pc)
        w_comb = (top_vals.reshape(gl, sl * k)
                  * keep.astype(jnp.float32)).astype(xl.dtype)
        y_part = (y_slot * w_comb[..., None]).reshape(gl, sl, k, d).sum(axis=2)
        # ONE token-granular bf16 psum over the expert axis per layer
        y = jax.lax.psum(y_part, expert_axis)
        return y, aux

    y, aux = body(p["router"], p["wi"], p["wg"], p["wo"], x)
    return y, aux * cfg.router_aux_weight
