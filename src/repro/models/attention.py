"""Attention: GQA with RoPE/qk-norm/SWA, flash-chunked training path,
cached decode path, and cross-attention.

Training/prefill uses an online-softmax ("flash") formulation in plain
jnp: an outer scan over query chunks and an inner scan over KV chunks,
so peak score memory is q_chunk × kv_chunk regardless of sequence length
(required for the 32k/500k shapes).  Decode (S_q == 1) uses the dense
path over the (possibly sequence-sharded) KV cache; softmax reductions
over a sharded KV axis become SPMD all-reduces — split-KV decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import shard

NEG_INF = -1e30
PAD_KV_POS = 2**30  # sentinel for empty/padded KV slots — always masked


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) absolute indices."""
    b, s, h, d = x.shape
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _mask(q_pos, kv_pos, *, causal: bool, window: int | None, kv_len=None):
    """(..., Sq, Skv) additive mask from position grids."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1]), jnp.float32)
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    m = jnp.where(kp >= PAD_KV_POS, NEG_INF, m)  # padded/empty slots
    if causal:
        m = jnp.where(kp > qp, NEG_INF, m)
    if window is not None:
        m = jnp.where(kp <= qp - window, NEG_INF, m)
    if kv_len is not None:
        m = jnp.where(kp >= kv_len[..., None, None], NEG_INF, m)
    return m


def dense_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                    kv_len=None, scale=None):
    """Unchunked reference/decode path. q: (B,Sq,H,D); k,v: (B,Skv,H,D)."""
    d = q.shape[-1]
    scale = scale or d**-0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _mask(q_pos[:, None], kv_pos[:, None], causal=causal, window=window,
                 kv_len=kv_len[:, None] if kv_len is not None else None)
    scores = scores + mask
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def flash_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                    kv_len=None, scale=None, q_chunk=1024, kv_chunk=1024):
    """Online-softmax chunked attention (jnp flash).

    Peak intermediate: (B, q_chunk, H, kv_chunk) scores — independent of
    sequence length.  Exact (fp32 running max/denominator).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale or d**-0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # Pad seq dims to chunk multiples (masked out via positions).
    pq = (-sq) % q_chunk
    pkv = (-skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=-1)
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pkv)), constant_values=PAD_KV_POS)
    nq = q.shape[1] // q_chunk
    nkv = k.shape[1] // kv_chunk

    q_c = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    qp_c = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    k_c = k.reshape(b, nkv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nkv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    kp_c = kv_pos.reshape(b, nkv, kv_chunk).transpose(1, 0, 2)

    def q_step(_, qc_inputs):
        qc, qpc = qc_inputs  # (B, qc, H, D), (B, qc)

        def kv_step(carry, kv_inputs):
            m_run, l_run, acc = carry
            kc, vc, kpc = kv_inputs
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpc[:, None], kpc[:, None], causal=causal, window=window,
                        kv_len=kv_len[:, None] if kv_len is not None else None)
            s = s + msk
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m_f, l_f, acc_f), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_c, v_c, kp_c))
        out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3)  # (B, qc, H, D)

    _, outs = jax.lax.scan(q_step, None, (q_c, qp_c))  # (nq, B, qc, H, D)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq].astype(q.dtype)


def gqa_repeat(kv: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, K, D) → (B, S, H, D) by repeating each KV head H/K times."""
    b, s, k, d = kv.shape
    if k == n_heads:
        return kv
    reps = n_heads // k
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, k, reps, d)).reshape(
        b, s, n_heads, d
    )


def attend(q, k, v, q_pos, kv_pos, *, causal=True, window=None, kv_len=None,
           impl="flash", q_chunk=1024, kv_chunk=1024):
    """Dispatch full-attention math; q (B,Sq,H,D), k/v already H heads."""
    q = shard(q, "batch", "seq", "heads_act", None)
    if impl == "dense" or q.shape[1] == 1:
        out = dense_attention(q, k, v, q_pos, kv_pos, causal=causal,
                              window=window, kv_len=kv_len)
    else:
        out = flash_attention(q, k, v, q_pos, kv_pos, causal=causal,
                              window=window, kv_len=kv_len,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    return shard(out, "batch", "seq", "heads_act", None)
