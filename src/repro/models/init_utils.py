"""Parameter construction with attached logical sharding axes.

``make(key, shape, axes)`` returns a :class:`Spec` carrying both the
initialized array and its logical axis names; ``split_tree`` separates a
nested dict of Specs into (params, axes) trees — a single source of truth
for shapes and shardings.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_ABSTRACT = threading.local()


@contextlib.contextmanager
def abstract_init():
    """Inside this context every ``make`` produces ShapeDtypeStructs —
    allocation-free init for dry-runs at production scale."""
    prev = getattr(_ABSTRACT, "on", False)
    _ABSTRACT.on = True
    try:
        yield
    finally:
        _ABSTRACT.on = prev


@dataclasses.dataclass
class Spec:
    value: Any  # jax.Array or ShapeDtypeStruct (abstract init)
    axes: tuple


def make(
    key: jax.Array | None,
    shape: tuple[int, ...],
    axes: tuple,
    *,
    init: str = "normal",
    scale: float | None = None,
    dtype: Any = jnp.float32,
    abstract: bool = False,
) -> Spec:
    """Create an initialized parameter (or an abstract stand-in).

    init: "normal" (fan-in scaled), "zeros", "ones", "uniform" (±scale),
    "constant" (scale everywhere).
    """
    assert len(shape) == len(axes), (shape, axes)
    if abstract or getattr(_ABSTRACT, "on", False):
        return Spec(jax.ShapeDtypeStruct(shape, dtype), axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    elif init == "constant":
        v = jnp.full(shape, scale, dtype)
    elif init == "uniform":
        v = jax.random.uniform(key, shape, dtype, -scale, scale)
    else:  # fan-in normal
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = scale if scale is not None else (1.0 / np.sqrt(fan_in))
        v = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    return Spec(v, axes)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def split_tree(tree: Any) -> tuple[Any, Any]:
    """Nested dict of Specs → (params tree, axes tree)."""
    if _is_spec(tree):
        return tree.value, tree.axes
    params, axes = {}, {}
    for k, v in tree.items():
        params[k], axes[k] = split_tree(v)
    return params, axes


class KeyGen:
    """Deterministic stream of subkeys."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub
