"""Model zoo: the paper's MobileNetV2-VWW models + the 10 assigned
LM-family architectures (dense / MoE / SSM / hybrid / VLM / audio)."""
