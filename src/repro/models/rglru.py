"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks
interleaved 2:1 with local (sliding-window, MQA) attention.

Recurrent block: dual linear branches (signal + gate), short causal
depthwise conv1d, RG-LRU gated diagonal recurrence

    r_t = σ(x W_a + b_a);  i_t = σ(x W_x + b_x)
    a_t = exp(−c · softplus(Λ) · r_t)            (c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

computed with ``jax.lax.associative_scan`` (O(log S) depth — this is the
sub-quadratic path that makes the 500k cell viable), GeGLU MLP after
every block.  Decode carries (conv tail, h) per recurrent layer plus a
rolling window cache per attention layer.

Layer stack: ``n_groups = n_layers // len(pattern)`` scanned groups of
(rec, rec, attn) + an unrolled all-recurrent tail for the remainder
(38 = 12×3 + 2).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, make, split_tree
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    attention_block,
    cached_attention,
    embed_tokens,
    init_attention,
    init_embedding,
    init_kv_cache,
    init_mlp,
    init_norm,
    lm_head,
)
from repro.parallel import shard

RGLRU_C = 8.0


def _init_rec_block(kg: KeyGen, cfg: ModelConfig, L: tuple) -> dict:
    d, dr = cfg.d_model, cfg.d_rnn or cfg.d_model
    dt = cfg.dtype
    return {
        "wx": make(kg(), L + (d, dr), ("layers", "embed", "heads"), dtype=dt),
        "wgate": make(kg(), L + (d, dr), ("layers", "embed", "heads"), dtype=dt),
        "conv": make(kg(), L + (cfg.conv_width, dr), ("layers", "conv", "heads"),
                     scale=0.1, dtype=dt),
        "wa": make(kg(), L + (dr, dr), ("layers", "heads", "heads"), dtype=dt),
        "ba": make(None, L + (dr,), ("layers", "heads"), init="zeros"),
        "wi": make(kg(), L + (dr, dr), ("layers", "heads", "heads"), dtype=dt),
        "bi": make(None, L + (dr,), ("layers", "heads"), init="zeros"),
        "lam": make(None, L + (dr,), ("layers", "heads"), init="constant", scale=0.7),
        "wo": make(kg(), L + (dr, d), ("layers", "heads", "embed"), dtype=dt),
        "norm": init_norm(cfg, L),
        "mlp_norm": init_norm(cfg, L),
        "mlp": init_mlp(kg, cfg, L),
    }


def _init_attn_block(kg: KeyGen, cfg: ModelConfig, L: tuple) -> dict:
    return {
        "norm": init_norm(cfg, L),
        "attn": init_attention(kg, cfg, L),
        "mlp_norm": init_norm(cfg, L),
        "mlp": init_mlp(kg, cfg, L),
    }


def _pattern_split(cfg: ModelConfig) -> tuple[int, int]:
    p = len(cfg.block_pattern)
    return cfg.n_layers // p, cfg.n_layers % p


def init_rglru(key: jax.Array, cfg: ModelConfig) -> tuple[dict, dict]:
    kg = KeyGen(key)
    n_groups, rem = _pattern_split(cfg)
    assert all(b == "rec" for b in cfg.block_pattern[:rem]), "tail must be recurrent"
    G = (n_groups,)
    groups: dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        groups[f"b{i}"] = (_init_rec_block(kg, cfg, G) if kind == "rec"
                           else _init_attn_block(kg, cfg, G))
    tree: dict[str, Any] = {"embed": init_embedding(kg, cfg), "groups": groups}
    if rem:
        tree["tail"] = _init_rec_block(kg, cfg, (rem,))
    return split_tree(tree)


# ------------------------------------------------------------------ RG-LRU


def _rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array):
    """h_t = a_t ⊙ h_{t−1} + b_t over axis 1, given h0 (B, D)."""
    # fold h0 into the first step
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar + br

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bv  # h_t for every t


def _rec_block(p: dict, x, state, cfg: ModelConfig):
    """x: (B, S, d); state: {conv (B, W−1, dr), h (B, dr)} or None."""
    b, s, _ = x.shape
    dr = cfg.d_rnn or cfg.d_model
    w = cfg.conv_width
    h_in = apply_norm(p["norm"], x, cfg)
    xb = h_in @ p["wx"]
    gate = h_in @ p["wgate"]
    xb = shard(xb, "batch", "seq", "heads_act")

    conv_tail = state["conv"] if state is not None else jnp.zeros(
        (b, w - 1, dr), xb.dtype)
    xc = jnp.concatenate([conv_tail.astype(xb.dtype), xb], axis=1)
    # causal depthwise conv1d, width w
    y = sum(xc[:, i : i + s, :] * p["conv"][i][None, None, :] for i in range(w))
    new_conv_tail = xc[:, -(w - 1):, :] if w > 1 else conv_tail

    r = jax.nn.sigmoid(y @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(y @ p["wi"] + p["bi"])
    log_a = (-RGLRU_C * jax.nn.softplus(p["lam"]) * r).astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    bt = (beta * (i * y).astype(jnp.float32))
    h0 = state["h"] if state is not None else jnp.zeros((b, dr), jnp.float32)
    h = _rglru_scan(a, bt, h0)
    new_state = {"conv": new_conv_tail, "h": h[:, -1]}
    out = (jax.nn.gelu(gate) * h.astype(x.dtype)) @ p["wo"]
    x = x + out
    h2 = apply_norm(p["mlp_norm"], x, cfg)
    x = shard(x + apply_mlp(p["mlp"], h2, activation="gelu"),
              "batch", "seq", "embed_act")
    return x, new_state


def _attn_block(p: dict, x, positions, cfg: ModelConfig):
    h = apply_norm(p["norm"], x, cfg)
    x = x + attention_block(p["attn"], h, positions, cfg,
                            window=cfg.sliding_window)
    h = apply_norm(p["mlp_norm"], x, cfg)
    return shard(x + apply_mlp(p["mlp"], h, activation="gelu"),
                 "batch", "seq", "embed_act")


def init_rglru_state(cfg: ModelConfig, batch: int, max_len: int, *,
                     abstract=False):
    """Decode state: rolling attn caches + recurrent (conv, h) per group."""
    n_groups, rem = _pattern_split(cfg)
    dr = cfg.d_rnn or cfg.d_model
    n_rec = sum(1 for b in cfg.block_pattern if b == "rec")
    n_attn = len(cfg.block_pattern) - n_rec
    window = cfg.sliding_window or max_len
    tree: dict[str, Any] = {
        "rec_conv": make(None, (n_groups, n_rec, batch, cfg.conv_width - 1, dr),
                         ("layers", None, "cache_batch", None, "state"),
                         init="zeros", dtype=cfg.dtype, abstract=abstract),
        "rec_h": make(None, (n_groups, n_rec, batch, dr),
                      ("layers", None, "cache_batch", "state"),
                      init="zeros", dtype=jnp.float32, abstract=abstract),
        "attn": init_kv_cache(cfg, batch, min(window, max_len),
                              n_groups * n_attn, abstract=abstract,
                              window=cfg.sliding_window),
    }
    if rem:
        tree["tail_conv"] = make(None, (rem, batch, cfg.conv_width - 1, dr),
                                 ("layers", "cache_batch", None, "state"),
                                 init="zeros", dtype=cfg.dtype, abstract=abstract)
        tree["tail_h"] = make(None, (rem, batch, dr),
                              ("layers", "cache_batch", "state"),
                              init="zeros", dtype=jnp.float32, abstract=abstract)
    return split_tree(tree)


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            positions: jax.Array | None = None):
    """tokens (B, S) → (logits, aux=0)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed_tokens(params["embed"], tokens, cfg)

    def group_fn(x, gp):
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "rec":
                x, _ = _rec_block(gp[f"b{i}"], x, None, cfg)
            else:
                x = _attn_block(gp[f"b{i}"], x, positions, cfg)
        return x

    gfn = group_fn
    if cfg.remat:
        gfn = jax.checkpoint(
            group_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, gp: (gfn(c, gp), None), x, params["groups"])
    else:
        n_groups, _ = _pattern_split(cfg)
        for g in range(n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            x = gfn(x, gp)
    if "tail" in params:
        rem = jax.tree.leaves(params["tail"])[0].shape[0]
        for i in range(rem):
            tp = jax.tree.map(lambda a: a[i], params["tail"])
            x, _ = _rec_block(tp, x, None, cfg)
    return lm_head(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def decode_step(params: dict, state: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig):
    """One-token decode.  tokens (B, 1); pos (B,)."""
    x = embed_tokens(params["embed"], tokens, cfg)
    n_groups, rem = _pattern_split(cfg)
    new_state = jax.tree.map(lambda a: a, state)  # shallow copy

    rec_conv, rec_h = state["rec_conv"], state["rec_h"]
    ck, cv = state["attn"]["k"], state["attn"]["v"]
    nrc, nrh, nck, ncv = [], [], [], []
    for g in range(n_groups):
        gp = jax.tree.map(lambda a: a[g], params["groups"])
        ri = ai = 0
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "rec":
                st = {"conv": rec_conv[g, ri], "h": rec_h[g, ri]}
                x, ns = _rec_block(gp[f"b{i}"], x, st, cfg)
                nrc.append(ns["conv"])
                nrh.append(ns["h"])
                ri += 1
            else:
                li = g * 1 + ai  # one attn layer per group
                p = gp[f"b{i}"]
                h = apply_norm(p["norm"], x, cfg)
                att, nk, nv = cached_attention(p["attn"], h, ck[li], cv[li],
                                               pos, cfg, window=cfg.sliding_window)
                x = x + att
                h = apply_norm(p["mlp_norm"], x, cfg)
                x = x + apply_mlp(p["mlp"], h, activation="gelu")
                nck.append(nk)
                ncv.append(nv)
                ai += 1
    n_rec = sum(1 for b_ in cfg.block_pattern if b_ == "rec")
    new_state["rec_conv"] = jnp.stack(nrc).reshape(rec_conv.shape)
    new_state["rec_h"] = jnp.stack(nrh).reshape(rec_h.shape)
    new_state["attn"] = {"k": jnp.stack(nck), "v": jnp.stack(ncv)}
    if rem:
        ntc, nth = [], []
        for i in range(rem):
            tp = jax.tree.map(lambda a: a[i], params["tail"])
            st = {"conv": state["tail_conv"][i], "h": state["tail_h"][i]}
            x, ns = _rec_block(tp, x, st, cfg)
            ntc.append(ns["conv"])
            nth.append(ns["h"])
        new_state["tail_conv"] = jnp.stack(ntc)
        new_state["tail_h"] = jnp.stack(nth)
    return lm_head(params["embed"], x, cfg), new_state
