"""Shared transformer building blocks (params + forward), GQA/MoE-ready.

Parameter trees are built from `init_utils.make` Specs so every leaf
carries its logical sharding axes.  All per-layer params take a leading
``n_layers`` dim when ``stacked=True`` (consumed by ``lax.scan``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import apply_rope, attend, dense_attention, gqa_repeat
from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, Spec, make
from repro.parallel import shard


# ------------------------------------------------------------------ norms


def rms_norm(x, scale, eps: float):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg: ModelConfig, L: tuple, name_axes=None) -> dict:
    d = cfg.d_model
    if name_axes is None:
        name_axes = ("layers",) * len(L)
    tree = {"scale": make(None, L + (d,), name_axes + ("embed_act",), init="zeros")}
    if cfg.use_layernorm:
        tree["bias"] = make(None, L + (d,), name_axes + ("embed_act",), init="zeros")
    return tree


def apply_norm(p: dict, x, cfg: ModelConfig):
    if "bias" in p:
        return layer_norm(x, 1.0 + p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ------------------------------------------------------------------ MLP


def init_mlp(kg: KeyGen, cfg: ModelConfig, L: tuple, d_ff: int | None = None,
             gated: bool = True) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.dtype
    ls = ("layers",) * len(L)
    tree = {
        "wi": make(kg(), L + (d, ff), ls + ("embed", "mlp"), dtype=dt),
        "wo": make(kg(), L + (ff, d), ls + ("mlp", "embed"), dtype=dt),
    }
    if gated:
        tree["wg"] = make(kg(), L + (d, ff), ls + ("embed", "mlp"), dtype=dt)
    return tree


def apply_mlp(p: dict, x, activation: str = "silu"):
    h = x @ p["wi"]
    if "wg" in p:
        g = x @ p["wg"]
        act = jax.nn.silu(g) if activation == "silu" else jax.nn.gelu(g)
        h = act * h
    else:
        h = jax.nn.gelu(h) if activation == "gelu" else jax.nn.silu(h)
    h = shard(h, "batch", "seq", "mlp_act")
    return h @ p["wo"]


# ------------------------------------------------------------------ attention block


def init_attention(kg: KeyGen, cfg: ModelConfig, L: tuple) -> dict:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.resolved_head_dim
    dt = cfg.dtype
    ls = ("layers",) * len(L)
    tree = {
        "wq": make(kg(), L + (d, qd), ls + ("embed", "heads"), dtype=dt),
        "wk": make(kg(), L + (d, kvd), ls + ("embed", "kv_heads"), dtype=dt),
        "wv": make(kg(), L + (d, kvd), ls + ("embed", "kv_heads"), dtype=dt),
        "wo": make(kg(), L + (qd, d), ls + ("heads", "embed"), dtype=dt),
    }
    if cfg.qkv_bias:
        tree["bq"] = make(None, L + (qd,), ls + ("heads",), init="zeros", dtype=dt)
        tree["bk"] = make(None, L + (kvd,), ls + ("kv_heads",), init="zeros", dtype=dt)
        tree["bv"] = make(None, L + (kvd,), ls + ("kv_heads",), init="zeros", dtype=dt)
    if cfg.qk_norm:
        tree["q_norm"] = make(None, L + (hd,), ls + (None,), init="zeros")
        tree["k_norm"] = make(None, L + (hd,), ls + (None,), init="zeros")
    return tree


def _project_qkv(p: dict, x, cfg: ModelConfig, positions, *, rope: bool = True):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p: dict, x, positions, cfg: ModelConfig, *,
                    window: int | None = None, impl: str = "flash"):
    """Full-sequence self-attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    k = gqa_repeat(k, cfg.n_heads)
    v = gqa_repeat(v, cfg.n_heads)
    out = attend(q, k, v, positions, positions, causal=True, window=window,
                 impl=impl)
    out = out.reshape(b, s, cfg.q_dim)
    return out @ p["wo"]


# ------------------------------------------------------------------ KV cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  *, abstract: bool = False, window: int | None = None) -> dict:
    """Per-layer stacked KV cache.  Sliding-window archs allocate only the
    window (rolling buffer)."""
    hd = cfg.resolved_head_dim
    length = min(max_len, window) if window else max_len
    shape = (n_layers, batch, length, cfg.n_kv_heads, hd)
    axes = ("layers", "cache_batch", "cache_seq", "cache_heads", None)
    mk = lambda: make(None, shape, axes, init="zeros", dtype=cfg.dtype,
                      abstract=abstract)
    return {"k": mk(), "v": mk()}


def cached_attention(p: dict, x, cache_k, cache_v, pos, cfg: ModelConfig, *,
                     window: int | None = None, rope: bool = True):
    """Single-token decode with cache update.

    x: (B, 1, d); cache_k/v: (B, T, K, hd); pos: (B,) current index.
    Returns (out (B,1,d), new_k, new_v).  For rolling (windowed) caches the
    slot is ``pos % T``; positions for RoPE/causality stay absolute.
    """
    b = x.shape[0]
    t = cache_k.shape[1]
    q, k, v = _project_qkv(p, x, cfg, pos[:, None], rope=rope)
    slot = pos % t
    upd = lambda c, new: jax.vmap(
        lambda cb, nb, sb: jax.lax.dynamic_update_slice(cb, nb, (sb, 0, 0))
    )(c, new.astype(c.dtype), slot)
    new_k = upd(cache_k, k)
    new_v = upd(cache_v, v)
    new_k = shard(new_k, "cache_batch", "cache_seq", "cache_heads", None)
    new_v = shard(new_v, "cache_batch", "cache_seq", "cache_heads", None)

    # Absolute positions of cache slots (rolling-aware): slot i holds
    # position  p_i = pos - ((slot - i) mod T)  … valid iff p_i >= 0.
    idx = jnp.arange(t)[None, :]
    kv_pos = pos[:, None] - ((slot[:, None] - idx) % t)
    kv_pos = jnp.where(kv_pos < 0, 2**30, kv_pos)  # empty slots masked

    kr = gqa_repeat(new_k, cfg.n_heads)
    vr = gqa_repeat(new_v, cfg.n_heads)
    out = dense_attention(q, kr, vr, pos[:, None], kv_pos, causal=True,
                          window=window)
    out = out.reshape(b, 1, cfg.q_dim)
    return out @ p["wo"], new_k, new_v


# ------------------------------------------------------------------ embedding / head


def init_embedding(kg: KeyGen, cfg: ModelConfig) -> dict:
    """Embedding table + output head, padded to ``padded_vocab`` so the
    vocab dim shards cleanly under TP (pad logits are masked in lm_head).

    Table init is ``d^-1/4``, not the head-side fan-in ``d^-1/2``: the
    residual branches (attn/mlp ``wo``) emit unit-variance activations at
    init, so a ``d^-1/2`` table buries the token identity at ~1/d of the
    stream variance and early training is signal-starved (the seed-red
    trainer tests measured exactly this — loss barely moved in the first
    tens of steps).  ``d^-1/4`` is the geometric mean of the input-side
    optimum (O(1), competes with the branches) and the head-side optimum
    (O(d^-1/2), unit-variance logits) — the standard compromise for tied
    embeddings without a separate input multiplier."""
    dt = cfg.dtype
    tree: dict[str, Any] = {
        "table": make(kg(), (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                      scale=cfg.d_model**-0.25, dtype=dt),
        "final_norm": init_norm(cfg, (), ()),
    }
    if not cfg.tie_embeddings:
        tree["head"] = make(kg(), (cfg.d_model, cfg.padded_vocab),
                            ("embed", "vocab"), dtype=dt)
    return tree


def embed_tokens(p: dict, tokens, cfg: ModelConfig):
    x = jnp.take(p["table"], tokens, axis=0)
    return shard(x, "batch", "seq", "embed_act")


def mask_pad_vocab(logits, cfg: ModelConfig):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(idx < cfg.vocab, logits, -1e9)


def lm_head(p: dict, x, cfg: ModelConfig):
    x = apply_norm(p["final_norm"], x, cfg)
    w = p["table"].T if cfg.tie_embeddings else p["head"]
    logits = (x @ w).astype(jnp.float32)
    logits = mask_pad_vocab(logits, cfg)
    return shard(logits, "batch", "seq", "vocab_act")
