"""Uniform API over model families — the surface the trainer, the
serving loop, and the dry-run all program against.

Every family exposes:
  init(key, cfg)                     → (params, logical_axes)
  loss(params, batch, cfg)           → (scalar loss, metrics dict)
  init_decode_state(cfg, B, T, abstract) → (state, logical_axes)
  decode(params, state, tokens, pos, cfg) → (logits, new_state)
  batch_keys(cfg)                    → input names the family consumes
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import rglru, rwkv6, transformer, vlm, whisper
from repro.models.config import ModelConfig
from repro.parallel import shard


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None):
    """Mean next-token CE.  logits (B,S,V) fp32; targets (B,S) int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - true
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def _accuracy(logits, targets):
    return (logits.argmax(-1) == targets).mean()


# ------------------------------------------------------------------ losses


def _lm_loss(params, batch, cfg: ModelConfig):
    logits, aux = transformer.forward(params, batch["tokens"], cfg)
    ce = cross_entropy(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux, "acc": _accuracy(logits, batch["targets"])}


def _rwkv_loss(params, batch, cfg: ModelConfig):
    logits, aux, _ = rwkv6.forward(params, batch["tokens"], cfg)
    ce = cross_entropy(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux, "acc": _accuracy(logits, batch["targets"])}


def _rglru_loss(params, batch, cfg: ModelConfig):
    logits, aux = rglru.forward(params, batch["tokens"], cfg)
    ce = cross_entropy(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux, "acc": _accuracy(logits, batch["targets"])}


def _vlm_loss(params, batch, cfg: ModelConfig):
    logits, aux = vlm.forward(params, batch["tokens"], batch["image_embeds"], cfg)
    ce = cross_entropy(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux, "acc": _accuracy(logits, batch["targets"])}


def _encdec_loss(params, batch, cfg: ModelConfig):
    logits, aux = whisper.forward(params, batch["src_embeds"], batch["tokens"], cfg)
    ce = cross_entropy(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux, "acc": _accuracy(logits, batch["targets"])}


# ------------------------------------------------------------------ decode-state adapters


def _lm_decode_state(cfg, batch, max_len, abstract=False):
    return transformer.init_cache(cfg, batch, max_len, abstract=abstract)


def _rwkv_decode_state(cfg, batch, max_len, abstract=False):
    return rwkv6.init_rwkv_state(cfg, batch, abstract=abstract)


def _rglru_decode_state(cfg, batch, max_len, abstract=False):
    return rglru.init_rglru_state(cfg, batch, max_len, abstract=abstract)


def _vlm_decode_state(cfg, batch, max_len, abstract=False):
    return vlm.init_vlm_cache(cfg, batch, max_len, abstract=abstract)


def _encdec_decode_state(cfg, batch, max_len, abstract=False):
    return whisper.init_whisper_cache(cfg, batch, max_len, abstract=abstract)


@dataclasses.dataclass(frozen=True)
class Family:
    init: Callable
    loss: Callable
    decode: Callable
    init_decode_state: Callable
    batch_keys: tuple[str, ...]
    # Optional fused multi-token prefill, (params, state, tokens (B,C),
    # lengths (B,), cfg) → (last_logits (B,V), new_state): advance row b
    # by lengths[b] ∈ [0, C] tokens in one launch, rows at 0 keeping
    # their state bit-for-bit.  Only valid for positionless recurrent
    # families (the hook takes no positions); serving's chunked prefill
    # prefers it over the masked decode-step scan when present.
    prefill: Callable | None = None


FAMILIES: dict[str, Family] = {
    "dense": Family(transformer.init_lm, _lm_loss, transformer.decode_step,
                    _lm_decode_state, ("tokens", "targets")),
    "moe": Family(transformer.init_lm, _lm_loss, transformer.decode_step,
                  _lm_decode_state, ("tokens", "targets")),
    "rwkv": Family(rwkv6.init_rwkv, _rwkv_loss, rwkv6.decode_step,
                   _rwkv_decode_state, ("tokens", "targets"),
                   prefill=rwkv6.prefill_step),
    "rglru": Family(rglru.init_rglru, _rglru_loss, rglru.decode_step,
                    _rglru_decode_state, ("tokens", "targets")),
    "vlm": Family(vlm.init_vlm, _vlm_loss, vlm.decode_step,
                  _vlm_decode_state, ("tokens", "targets", "image_embeds")),
    "encdec": Family(whisper.init_whisper, _encdec_loss, whisper.decode_step,
                     _encdec_decode_state, ("tokens", "targets", "src_embeds")),
}


def get_family(cfg: ModelConfig) -> Family:
    return FAMILIES[cfg.family]


@functools.lru_cache(maxsize=None)
def validate_slot_layout(cfg: ModelConfig) -> None:
    """Serving's slot table assumes **batch at axis 1** of every
    decode-state leaf (`ServeEngine._reset_slot` zeroes ``a[:, i]``, the
    chunked step's ``keep`` select masks axis 1).  Check that against the
    family's *declared* state layout (the logical-axes tree from
    ``init_decode_state(..., abstract=True)``) and fail loudly on
    mismatch — e.g. rglru's grouped ``rec_conv``/``rec_h`` leaves carry
    batch at axis 2, which the slot engines would silently corrupt."""
    family = get_family(cfg)
    _, axes = family.init_decode_state(cfg, 1, 8, abstract=True)
    is_axes = lambda x: isinstance(x, tuple)
    bad = []
    for path, ax in jax.tree_util.tree_flatten_with_path(
            axes, is_leaf=is_axes)[0]:
        if not is_axes(ax) or len(ax) < 2 or ax[1] != "cache_batch":
            bad.append((jax.tree_util.keystr(path), ax))
    if bad:
        detail = ", ".join(f"{p} declares axes {ax}" for p, ax in bad)
        raise ValueError(
            f"family {cfg.family!r} decode state is incompatible with the "
            f"slot engines: every leaf must declare 'cache_batch' at axis "
            f"1, but {detail}. Serving this family needs a state-layout "
            f"adapter, not a silent axis-1 select.")
