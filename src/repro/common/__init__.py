"""Shared utilities: pytree helpers, dtype policies, logging."""
from repro.common.pytree import (
    tree_size,
    tree_bytes,
    tree_map_with_path,
    param_count,
    flatten_with_names,
)
from repro.common.precision import Policy, DEFAULT_POLICY, cast_floating

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_map_with_path",
    "param_count",
    "flatten_with_names",
    "Policy",
    "DEFAULT_POLICY",
    "cast_floating",
]
