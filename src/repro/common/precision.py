"""Mixed-precision policy.

Parameters are stored fp32 (master copy in the optimizer), cast to a
compute dtype (bf16 on TPU) on entry to the forward pass, and reductions
(norm statistics, softmax, losses, ADC accumulation) are kept fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    reduce_dtype: Any = jnp.float32

    def cast_params(self, tree: Any) -> Any:
        return cast_floating(tree, self.compute_dtype)

    def cast_output(self, tree: Any) -> Any:
        return cast_floating(tree, self.param_dtype)


DEFAULT_POLICY = Policy()
FP32_POLICY = Policy(compute_dtype=jnp.float32)


def cast_floating(tree: Any, dtype: Any) -> Any:
    """Cast floating-point leaves to ``dtype``; leave ints/bools alone."""

    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)
