"""Pytree utilities used across the framework.

All parameter containers in this codebase are plain nested dicts of
jnp/np arrays ("param trees").  These helpers provide named flattening
(for sharding-rule matching and checkpoint manifests) and size
accounting (for memory budgeting and roofline napkin math).
"""
from __future__ import annotations

from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def _is_leaf(x: Any) -> bool:
    return not isinstance(x, dict)


def flatten_with_names(tree: Any, prefix: str = "") -> Iterator[tuple[str, Any]]:
    """Yield ``(dotted.path, leaf)`` pairs in deterministic (sorted) order."""
    if _is_leaf(tree):
        yield prefix or "<root>", tree
        return
    for key in sorted(tree.keys()):
        sub = tree[key]
        path = f"{prefix}.{key}" if prefix else str(key)
        yield from flatten_with_names(sub, path)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any, prefix: str = "") -> Any:
    """Like ``jax.tree.map`` but ``fn`` receives the dotted path too."""
    if _is_leaf(tree):
        return fn(prefix or "<root>", tree)
    return {
        key: tree_map_with_path(fn, tree[key], f"{prefix}.{key}" if prefix else str(key))
        for key in tree.keys()
    }


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_bytes(tree: Any) -> int:
    """Total byte footprint across all leaves."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def param_count(tree: Any) -> int:
    """Alias of :func:`tree_size` for readability at call sites."""
    return tree_size(tree)


def assert_trees_all_finite(tree: Any, name: str = "tree") -> None:
    """Raise if any leaf contains NaN/Inf (host-side check, test helper)."""
    for path, leaf in flatten_with_names(tree):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f" and not np.all(np.isfinite(arr)):
            raise AssertionError(f"{name}[{path}] contains non-finite values")
