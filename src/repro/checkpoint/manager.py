"""Checkpointing: npz shards + JSON manifest, async save thread,
content hashing, atomic commit, elastic re-shard on restore.

Layout:  <dir>/step_<N>/
            manifest.json   (paths, shapes, dtypes, sha256, extra state)
            arrays.npz      (flat path→array archive)

Fault-tolerance properties:
* atomic: a checkpoint directory is committed by renaming from a
  ``.tmp`` suffix only after all bytes are flushed, so a crash never
  leaves a half checkpoint that `restore_latest` would pick up;
* verified: restore checks each array's sha256 against the manifest and
  falls back to the previous checkpoint on corruption;
* elastic: restore maps arrays onto the *current* state's shardings via
  ``jax.device_put`` — the saved mesh size is irrelevant, so a job can
  come back on a larger or smaller slice (re-layout happens on load);
* async: ``save`` snapshots to host memory then writes on a worker
  thread; ``wait()`` joins at exit.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.common.pytree import flatten_with_names


def _unflatten(flat: dict[str, Any]) -> dict:
    tree: dict = {}
    for path, value in flat.items():
        parts = path.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save

    def save(self, step: int, state: dict, extra: dict | None = None,
             *, blocking: bool = False) -> None:
        # Snapshot to host synchronously (cheap vs device compute), write async.
        flat = {path: np.asarray(jax.device_get(leaf))
                for path, leaf in flatten_with_names(state)}
        self.wait()

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **flat)
            manifest = {
                "step": step,
                "arrays": {
                    path: {
                        "shape": list(a.shape),
                        "dtype": str(a.dtype),
                        "sha256": hashlib.sha256(a.tobytes()).hexdigest(),
                    }
                    for path, a in flat.items()
                },
                "extra": extra or {},
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------- restore

    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def _load(self, step: int, template: dict) -> tuple[dict, dict] | None:
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        with np.load(path / "arrays.npz") as npz:
            flat = {k: npz[k] for k in npz.files}
        for name, meta in manifest["arrays"].items():
            if name not in flat:
                return None
            if hashlib.sha256(flat[name].tobytes()).hexdigest() != meta["sha256"]:
                return None  # corrupt → caller falls back

        # Elastic re-layout: place each array with the template leaf's
        # sharding (or default device) regardless of the saving mesh.
        template_flat = dict(flatten_with_names(template))
        placed = {}
        for name, arr in flat.items():
            tmpl = template_flat.get(name)
            if tmpl is not None and hasattr(tmpl, "sharding"):
                placed[name] = jax.device_put(arr, tmpl.sharding)
            else:
                placed[name] = jax.device_put(arr)
        return _unflatten(placed), manifest.get("extra", {})

    def restore_latest(self, template: dict) -> tuple[dict, dict] | None:
        """Restore newest valid checkpoint, skipping corrupt ones."""
        self.wait()
        for step in reversed(self.steps()):
            try:
                result = self._load(step, template)
            except Exception:  # unreadable/corrupt archive → try older
                result = None
            if result is not None:
                return result
        return None
