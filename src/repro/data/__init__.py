from repro.data.lm import SyntheticLMDataset
from repro.data.vww_synthetic import SyntheticVWW
from repro.data.pipeline import DataPipeline

__all__ = ["SyntheticLMDataset", "SyntheticVWW", "DataPipeline"]
