"""Host-side data pipeline: checkpointable cursor, background prefetch,
global-array placement for sharded training.

The pipeline's only state is its integer ``step`` cursor (datasets are
addressable by step), so checkpoint/restore and elastic restarts are
exact: save ``pipeline.state_dict()``, restore with ``load_state_dict``.
Prefetch runs the (numpy) generation of the next batches on a thread —
the CPU-side analogue of an input pipeline overlapping the accelerator.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable

import jax
import numpy as np


class DataPipeline:
    def __init__(self, dataset: Any, *, start_step: int = 0,
                 prefetch: int = 2,
                 transform: Callable[[dict], dict] | None = None,
                 sharding_fn: Callable[[str, np.ndarray], Any] | None = None):
        self._dataset = dataset
        self._step = start_step
        self._transform = transform
        self._sharding_fn = sharding_fn
        self._prefetch_n = prefetch
        self._q: queue.Queue | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        if prefetch > 0:
            self._start_prefetch()

    # ------------------------------------------------------------- state

    def state_dict(self) -> dict:
        return {"step": self._step}

    def load_state_dict(self, state: dict) -> None:
        self._restart_at(int(state["step"]))

    def _restart_at(self, step: int) -> None:
        self._shutdown()
        self._step = step
        if self._prefetch_n > 0:
            self._start_prefetch()

    # ------------------------------------------------------------- prefetch

    def _start_prefetch(self) -> None:
        self._stop.clear()
        self._q = queue.Queue(maxsize=self._prefetch_n)
        self._fetch_step = self._step

        def worker():
            while not self._stop.is_set():
                batch = self._make(self._fetch_step)
                self._fetch_step += 1
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.1)
                        break
                    except queue.Full:
                        continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def _shutdown(self) -> None:
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None

    # ------------------------------------------------------------- iterate

    def _make(self, step: int) -> dict:
        batch = self._dataset.batch_at(step)
        if self._transform is not None:
            batch = self._transform(batch)
        return batch

    def _place(self, batch: dict) -> dict:
        if self._sharding_fn is None:
            return batch
        return {k: jax.device_put(v, self._sharding_fn(k, v))
                for k, v in batch.items()}

    def __next__(self) -> dict:
        if self._q is not None:
            batch = self._q.get()
        else:
            batch = self._make(self._step)
        self._step += 1
        return self._place(batch)

    def __iter__(self):
        return self

    def close(self):
        self._shutdown()
