"""Synthetic Visual-Wake-Words proxy (the real VWW is unavailable offline).

Binary "person present" classification with a learnable but non-trivial
visual signal: positives composite a soft vertical "figure" (head +
torso ellipses, randomly placed/scaled/lit); negatives get background
texture only (gradients + stripes + blob distractors).  Both classes
share global illumination and noise statistics so the task is not
solvable from image mean alone.  Deterministic in (seed, step).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _figure_mask(h, w, rng):
    """Soft person-ish silhouette: head circle + torso ellipse."""
    cy = rng.uniform(0.35, 0.65) * h
    cx = rng.uniform(0.25, 0.75) * w
    scale = rng.uniform(0.15, 0.35) * min(h, w)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    head = ((yy - (cy - 1.1 * scale)) ** 2 + (xx - cx) ** 2) / (0.45 * scale) ** 2
    torso = ((yy - cy) ** 2 / (1.4 * scale) ** 2
             + (xx - cx) ** 2 / (0.7 * scale) ** 2)
    mask = np.minimum(head, torso)
    return np.exp(-np.maximum(mask - 1.0, 0.0) * 4.0)  # soft edge


def _background(h, w, rng):
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    g = (rng.uniform(-1, 1) * yy / h + rng.uniform(-1, 1) * xx / w)
    stripes = 0.15 * np.sin(2 * np.pi * (xx * rng.uniform(0.02, 0.1)
                                         + rng.uniform(0, 1)))
    blob = np.zeros((h, w), np.float32)
    for _ in range(rng.integers(0, 4)):
        by, bx = rng.uniform(0, h), rng.uniform(0, w)
        r = rng.uniform(0.05, 0.2) * min(h, w)
        blob += 0.3 * np.exp(-(((yy - by) ** 2 + (xx - bx) ** 2) / r**2))
    return 0.4 + 0.2 * g + stripes + blob


@dataclasses.dataclass(frozen=True)
class SyntheticVWW:
    image_size: int = 80
    batch: int = 32
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        h = w = self.image_size
        images = np.empty((self.batch, h, w, 3), np.float32)
        labels = rng.integers(0, 2, self.batch).astype(np.int32)
        for i in range(self.batch):
            bg = _background(h, w, rng)
            img = np.stack([bg * rng.uniform(0.7, 1.3) for _ in range(3)], -1)
            if labels[i]:
                m = _figure_mask(h, w, rng)
                color = rng.uniform(0.3, 1.0, 3).astype(np.float32)
                img = img * (1 - 0.8 * m[..., None]) + m[..., None] * color
            img += rng.normal(0, 0.03, img.shape)
            images[i] = np.clip(img, 0.0, 1.0)
        return {"images": images, "labels": labels}
