"""Deterministic synthetic LM data with learnable structure.

Sequences follow a noisy affine-recurrence language:
``x_{t+1} = (a·x_t + b + ε_t) mod V`` with per-sequence (a, b) drawn from
a small set — enough signal that a few hundred steps of training visibly
drop the loss, while remaining fully offline and reproducible.  The
generator is stateless in ``(seed, step)`` so restarts resume exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    noise: float = 0.05
    n_rules: int = 8

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Batch for a given global step (checkpoint-friendly addressing)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s, v = self.batch, self.seq_len + 1, self.vocab
        a = rng.integers(1, self.n_rules + 1, (b, 1))
        c = rng.integers(0, self.n_rules, (b, 1))
        x = np.empty((b, s), np.int64)
        x[:, 0] = rng.integers(0, v, b)
        noise_mask = rng.random((b, s)) < self.noise
        noise_tok = rng.integers(0, v, (b, s))
        for t in range(1, s):
            nxt = (a[:, 0] * x[:, t - 1] + c[:, 0]) % v
            x[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {
            "tokens": x[:, :-1].astype(np.int32),
            "targets": x[:, 1:].astype(np.int32),
        }
