"""Behavioral model of the weight-embedded P²M pixel (paper §3.1, Fig. 3).

The paper sweeps the pixel circuit in SPICE (22 nm GF FD-SOI) over weight
(transistor width) and input activation (photodiode current), then fits a
behavioral *curve-fit function* ``g(w, x)`` that replaces every multiply in
the first conv layer during training (paper §4.1).

Two layers of modeling live here:

1. :func:`spice_surrogate` — a stand-in for the (unreleased) SPICE data:
   a monotone, saturating transfer surface qualitatively matching Fig. 3
   (pixel output grows with both ``w`` and ``x``; the product is
   compressive at large ``w·x`` because the source follower leaves
   saturation). Users with real SPICE sweeps feed their samples straight
   into :func:`fit_pixel_model` instead.

2. :class:`PixelModel` — the fitted **degree-(dw,dx) bivariate polynomial**
   ``g(w, x) = Σ_{i=1..dw, j=1..dx} a_ij · w^i · x^j``.

   The polynomial form is the TPU-native adaptation (DESIGN.md §2): the
   receptive-field accumulation ``Σ_r g(w_r, x_r)`` factorizes into
   ``Σ_ij a_ij (X^∘j @ W^∘i)`` — a short sum of MXU matmuls — instead of
   per-element function evaluation.  Terms with ``i = 0`` or ``j = 0`` are
   excluded by construction: ``g(0, x) = 0`` (no weight transistor
   activated ⇒ no contribution) and ``g(w, 0) = 0`` (CDS subtracts the
   reset level, so zero light ⇒ zero differential output).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# Operating ranges (normalized units): transistor driving strength and
# photodiode current are both mapped to [0, 1] by the co-design flow.
W_RANGE = (0.0, 1.0)
X_RANGE = (0.0, 1.0)


def spice_surrogate(w, x, *, v_max: float = 1.0, sat: float = 0.55, sf_leak: float = 0.02):
    """Stand-in for the SPICE-simulated pixel transfer surface (Fig. 3).

    ``v = v_max · (1+sat)·u / (1 + sat·u)`` with ``u = w·x`` — linear in the
    product at small signal, compressive toward ``v_max`` at large signal —
    plus a small source-follower leakage term ``sf_leak·x·w·(1−x)`` that
    bends the surface away from an exact product (this is what makes the
    scatter in Fig. 3(b) deviate from the ideal ``W×I`` line).
    """
    u = w * x
    main = v_max * (1.0 + sat) * u / (1.0 + sat * u)
    return main + sf_leak * x * w * (1.0 - x)


@dataclasses.dataclass(frozen=True)
class PixelModel:
    """Fitted polynomial pixel model ``g(w,x) = Σ a_ij w^i x^j`` (i,j ≥ 1).

    Attributes:
      coeffs: ``(dw, dx)`` array; ``coeffs[i-1, j-1]`` multiplies ``w^i x^j``.
      fit_rmse: residual of the least-squares fit against the source samples.
      read_noise_std: optional Gaussian read-noise (normalized volts) applied
        by callers that simulate analog readout; 0 disables.
    """

    coeffs: np.ndarray
    fit_rmse: float = 0.0
    read_noise_std: float = 0.0

    @property
    def degree_w(self) -> int:
        return self.coeffs.shape[0]

    @property
    def degree_x(self) -> int:
        return self.coeffs.shape[1]

    def __call__(self, w, x):
        """Evaluate ``g(w, x)`` elementwise (broadcasting), in jnp."""
        coeffs = jnp.asarray(self.coeffs, dtype=jnp.result_type(w, x, jnp.float32))
        # Horner in x inside Horner in w: g = Σ_i w^i (Σ_j a_ij x^j)
        acc = jnp.zeros(jnp.broadcast_shapes(jnp.shape(w), jnp.shape(x)),
                        dtype=coeffs.dtype)
        for i in range(self.degree_w, 0, -1):
            inner = jnp.zeros_like(acc)
            for j in range(self.degree_x, 0, -1):
                inner = (inner + coeffs[i - 1, j - 1]) * x
            acc = (acc + inner) * w if i > 1 else acc * w + inner * w
        return acc

    def term(self, i: int, j: int) -> float:
        """Coefficient of ``w^i x^j`` (1-indexed powers)."""
        return float(self.coeffs[i - 1, j - 1])


def _design_matrix(w: np.ndarray, x: np.ndarray, dw: int, dx: int) -> np.ndarray:
    cols = [np.power(w, i) * np.power(x, j) for i in range(1, dw + 1) for j in range(1, dx + 1)]
    return np.stack(cols, axis=-1)


def fit_pixel_model(
    samples_w: np.ndarray | None = None,
    samples_x: np.ndarray | None = None,
    samples_v: np.ndarray | None = None,
    *,
    degree_w: int = 3,
    degree_x: int = 3,
    grid: int = 64,
    read_noise_std: float = 0.0,
    term_mask: np.ndarray | None = None,
) -> PixelModel:
    """Least-squares fit of the polynomial pixel model.

    With no sample arrays, fits against :func:`spice_surrogate` on a
    ``grid × grid`` sweep of the operating range (this is the default
    model used throughout the repo).  With real SPICE sweep data, pass
    ``samples_w/x/v`` as flat arrays.

    ``term_mask`` (dw, dx) bool selects which basis terms participate —
    each active term costs one MXU matmul in the kernel, so pruning
    near-zero terms trades fit error for compute (see EXPERIMENTS.md
    §Perf).  Masked-out coefficients are exactly 0 and the kernels skip
    them.
    """
    if samples_v is None:
        ws = np.linspace(W_RANGE[0], W_RANGE[1], grid)
        xs = np.linspace(X_RANGE[0], X_RANGE[1], grid)
        wg, xg = np.meshgrid(ws, xs, indexing="ij")
        samples_w, samples_x = wg.ravel(), xg.ravel()
        samples_v = np.asarray(spice_surrogate(samples_w, samples_x))
    samples_w = np.asarray(samples_w, dtype=np.float64)
    samples_x = np.asarray(samples_x, dtype=np.float64)
    samples_v = np.asarray(samples_v, dtype=np.float64)

    A = _design_matrix(samples_w, samples_x, degree_w, degree_x)
    if term_mask is not None:
        mask = np.asarray(term_mask, bool).reshape(-1)
        assert mask.shape[0] == A.shape[1]
        sel = np.where(mask)[0]
        coef_sel, _, _, _ = np.linalg.lstsq(A[:, sel], samples_v, rcond=None)
        coef = np.zeros(A.shape[1])
        coef[sel] = coef_sel
    else:
        coef, _, _, _ = np.linalg.lstsq(A, samples_v, rcond=None)
    resid = A @ coef - samples_v
    rmse = float(np.sqrt(np.mean(resid**2)))
    coeffs = coef.reshape(degree_w, degree_x)
    return PixelModel(coeffs=coeffs, fit_rmse=rmse, read_noise_std=read_noise_std)


def prune_pixel_model(model: PixelModel, threshold: float = 0.06,
                      **fit_kwargs) -> PixelModel:
    """Refit keeping only terms with |a_ij| ≥ threshold (re-optimized)."""
    mask = np.abs(model.coeffs) >= threshold
    return fit_pixel_model(degree_w=model.degree_w, degree_x=model.degree_x,
                           term_mask=mask, **fit_kwargs)


# Default fitted model (22 nm GF surrogate), computed once at import of the
# callers that need it.  Cheap: a 64×64 lstsq.
_DEFAULT: PixelModel | None = None


def default_pixel_model() -> PixelModel:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = fit_pixel_model()
    return _DEFAULT


def linear_pixel_model() -> PixelModel:
    """Ideal multiplier ``g(w,x) = w·x`` — the 'no non-ideality' ablation."""
    coeffs = np.zeros((1, 1))
    coeffs[0, 0] = 1.0
    return PixelModel(coeffs=coeffs, fit_rmse=0.0)
