"""BN folding into the P²M layer (paper §4.2, Eq. 1).

At inference BN is affine: ``Y = A·X + B`` with
``A = γ/√(σ²+ε)``, ``B = β − γμ/√(σ²+ε)``.

The paper folds **A into the pixel weights** (deployed transistor width
realizes ``A·θ``) and **B into the ADC counter pre-load** (shifted ReLU).

Caveat the paper glosses over: the pixel transfer ``g`` is *nonlinear in
w*, so ``Σ g(A·θ, x) ≠ A·Σ g(θ, x)`` exactly.  We implement the paper's
fold literally, expose :func:`fold_error` to quantify the approximation,
and (beyond-paper) support *deploy-form training* — training directly in
the folded parameterization — which removes the approximation entirely.
For a degree-1-in-w pixel model the fold is exact; tests cover both.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.p2m_conv import P2MConvConfig, _flat_weights
from repro.core.pixel_model import PixelModel
from repro.kernels.p2m_conv.ops import p2m_matmul_jnp


def bn_affine(gamma, beta, mean, var, eps: float = 1e-5):
    """Return (A, B) of the inference-time BN affine map."""
    inv = 1.0 / jnp.sqrt(var + eps)
    a = gamma * inv
    b = beta - gamma * mean * inv
    return a, b


def deploy_params(params: dict, state: dict, cfg: P2MConvConfig) -> dict:
    """Fold train-form (θ, BN) into deploy-form (w, shift).

    ``w[k, c] = clip(A[c]·θ[k, c], −1, 1)`` — the transistor widths that get
    manufactured; ``shift[c] = B[c]`` — the counter pre-load in volts.
    """
    a, b = bn_affine(
        params["bn_gamma"], params["bn_beta"],
        state["bn_mean"], state["bn_var"], cfg.bn_eps,
    )
    w = _flat_weights(params["theta"], cfg)
    w_fold = jnp.clip(w * a[None, :], -1.0, 1.0)
    return {"w": w_fold, "shift": b, "bn_scale": a}


def fold_error(
    params: dict,
    state: dict,
    cfg: P2MConvConfig,
    model: PixelModel,
    sample_patches,
) -> float:
    """Max |BN(conv_g(θ)) − conv_g(A·θ) − B| over sample patches.

    Zero when g is linear in w (degree_w == 1) and |A·θ| ≤ 1; small but
    nonzero for the degree-3 fit — the residual the paper's fold incurs.
    """
    a, b = bn_affine(
        params["bn_gamma"], params["bn_beta"],
        state["bn_mean"], state["bn_var"], cfg.bn_eps,
    )
    w = _flat_weights(params["theta"], cfg)
    zero = jnp.zeros((cfg.out_channels,), jnp.float32)
    raw = p2m_matmul_jnp(sample_patches, w, zero, model, cfg.adc, mode="raw")
    exact = a[None, :] * raw + b[None, :]
    w_fold = jnp.clip(w * a[None, :], -1.0, 1.0)
    folded = p2m_matmul_jnp(sample_patches, w_fold, b, model, cfg.adc, mode="raw")
    return float(jnp.max(jnp.abs(exact - folded)))


def init_deploy_form(key, cfg: P2MConvConfig):
    """Beyond-paper: initialize directly in deploy parameterization
    (trainable w ∈ [−1,1] and shift), so no fold approximation exists."""
    import jax

    k = cfg.kernel
    fan_in = k * k * cfg.in_channels
    w = jax.random.uniform(
        key, (fan_in, cfg.out_channels), minval=-1.0, maxval=1.0
    ) * (3.0 / fan_in) ** 0.5
    return {"w": w.astype(np.float32), "shift": jnp.zeros((cfg.out_channels,), jnp.float32)}
