"""SS-ADC + digital-CDS model (paper §3.3).

The single-slope ADC digitizes the column-line voltage by counting clock
cycles until a ramp crosses the input.  The digital CDS makes the counter
up-count for the positive-weight sample and down-count for the
negative-weight sample; the paper re-purposes this to get, for free:

* signed accumulation (positive − negative weight contributions),
* a **quantized ReLU** (the latched count is clamped at ≥ 0),
* the BN **shift term** ``B`` (counter pre-loaded to ``round(B/Δ)``
  instead of 0 — the "shifted ReLU" of §4.2).

This module is the digital-exact simulation of that behaviour, plus a
straight-through-estimator (STE) version used during training.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    """N-bit SS-ADC; ``v_lsb`` volts per count; 2^n_bits − 1 full-scale counts."""

    n_bits: int = 8
    v_lsb: float = 1.0 / 255.0  # normalized-volt per count (full scale ≈ 1V)

    @property
    def max_count(self) -> int:
        return (1 << self.n_bits) - 1

    @property
    def full_scale(self) -> float:
        return self.max_count * self.v_lsb


def adc_counts(v, cfg: ADCConfig, preset_counts=0):
    """Integer counter output: ``clip(round(v/Δ) + preset, 0, 2^n − 1)``.

    ``v`` is the CDS differential voltage (positive sample − negative
    sample); ``preset_counts`` carries the BN shift term.  Output dtype is
    int32 — this is exactly what leaves the sensor on the I/O bus.
    """
    counts = jnp.round(v / cfg.v_lsb).astype(jnp.int32) + jnp.asarray(
        preset_counts, dtype=jnp.int32
    )
    return jnp.clip(counts, 0, cfg.max_count)


def adc_dequant(counts, cfg: ADCConfig):
    """Map counts back to normalized volts for downstream digital layers."""
    return counts.astype(jnp.float32) * cfg.v_lsb


def shifted_relu(v, shift, cfg: ADCConfig):
    """Float (training-time) view of the ADC: ``clip(v + shift, 0, fs)``.

    ``shift`` is the BN ``B`` term in volts; saturation at full scale is
    modeled because the counter stops at 2^n − 1.
    """
    return jnp.clip(v + shift, 0.0, cfg.full_scale)


def ste_adc(v, shift, cfg: ADCConfig):
    """Quantization-aware ADC: forward = integer-exact, backward = identity
    through the clip's linear region (straight-through estimator)."""
    soft = shifted_relu(v, shift, cfg)
    preset = jnp.round(jnp.asarray(shift) / cfg.v_lsb).astype(jnp.int32)
    hard = adc_dequant(adc_counts(v, cfg, preset_counts=preset), cfg)
    return soft + jax.lax.stop_gradient(hard - soft)
