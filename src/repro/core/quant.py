"""Post-training quantization for the P²M layer (paper §4.2, §5.2 Fig. 7a).

The paper trains in float, then quantizes (no QAT): first-layer weights
per-channel symmetric to ``w_bits``, output activations to ``N_b`` bits
via the ADC, and the BN parameters (μ, σ, γ, β → the shift term B) to the
same grid as the counter pre-load.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig


def quantize_symmetric(x, bits: int, axis=None):
    """Symmetric linear quantization. Returns (int values, scale).

    ``axis`` selects per-channel scales (reduce over all other axes).
    """
    qmax = float(2 ** (bits - 1) - 1)
    if axis is None:
        scale = jnp.max(jnp.abs(x)) / qmax
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        scale = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q.astype(jnp.int32), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def fake_quant(x, bits: int, axis=None):
    """Quantize-dequantize with straight-through gradient."""
    q, scale = quantize_symmetric(x, bits, axis)
    out = dequantize(q, scale)
    return x + jax.lax.stop_gradient(out - x)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Bit-widths for the deployable P²M layer."""

    w_bits: int = 8
    out_bits: int = 8
    shift_bits: int = 8


def quantize_deploy(deploy: dict, spec: QuantSpec) -> dict:
    """Quantize folded deploy params (weights per-channel, shift to the
    ADC count grid).  Output-activation quantization is the ADC itself
    (``out_bits`` configures its ``ADCConfig``)."""
    wq = fake_quant(deploy["w"], spec.w_bits, axis=1)
    adc = ADCConfig(n_bits=spec.out_bits, v_lsb=1.0 / (2**spec.out_bits - 1))
    shift_counts = jnp.round(deploy["shift"] / adc.v_lsb)
    sq = shift_counts * adc.v_lsb
    out = dict(deploy)
    out["w"] = wq
    out["shift"] = sq
    return out


def adc_for_bits(out_bits: int) -> ADCConfig:
    return ADCConfig(n_bits=out_bits, v_lsb=1.0 / (2**out_bits - 1))
