"""P²M as a drop-in modality frontend (beyond-paper integration).

The paper embeds the first CNN layers in the sensor.  For the assigned
multimodal architectures (llama-3.2-vision, whisper) the same idea slots
in as the *patch/frame embedder*: the sensor ships N_b-bit compressed
feature maps instead of raw 12-bit pixels, and a small linear projection
lifts them to the backbone width.  Select with ``--frontend p2m``.

The backbone dry-runs use the precomputed-embedding stub per the brief;
this module is exercised by the VWW example and the frontend tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.p2m_conv import (
    P2MConvConfig,
    apply_p2m_conv_deploy,
    apply_p2m_conv_train,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.pixel_model import PixelModel, default_pixel_model
from repro.parallel import shard


@dataclasses.dataclass(frozen=True)
class P2MFrontendConfig:
    """In-pixel compressive patch embedder.

    ``pool`` merges a ``pool×pool`` block of P²M outputs into one token, so
    token count = (i/(stride·pool))².
    """

    image_size: int = 560
    conv: P2MConvConfig = dataclasses.field(default_factory=P2MConvConfig)
    d_model: int = 4096
    pool: int = 4

    @property
    def tokens(self) -> int:
        side = self.conv.out_spatial(self.image_size) // self.pool
        return side * side

    @property
    def token_feature_dim(self) -> int:
        return self.conv.out_channels * self.pool * self.pool


def init_p2m_frontend(key: jax.Array, cfg: P2MFrontendConfig) -> dict[str, Any]:
    ckey, pkey = jax.random.split(key)
    fan_in = cfg.token_feature_dim
    return {
        "conv": init_p2m_conv(ckey, cfg.conv),
        "proj": jax.random.normal(pkey, (fan_in, cfg.d_model), jnp.float32)
        * (1.0 / fan_in) ** 0.5,
    }


def init_p2m_frontend_state(cfg: P2MFrontendConfig) -> dict[str, Any]:
    return {"conv": init_p2m_state(cfg.conv)}


def apply_p2m_frontend(
    params: dict,
    state: dict,
    images: jax.Array,
    cfg: P2MFrontendConfig,
    model: PixelModel | None = None,
    *,
    train: bool = False,
    deploy: dict | None = None,
    impl: str | None = None,
):
    """(B, H, W, 3) → (B, tokens, d_model) embeddings, plus new state.

    When ``deploy`` is given, the folded/quantized in-pixel path is used
    (what the manufactured sensor would emit).  ``impl`` selects the conv
    implementation (fused implicit-im2col kernel by default — see
    `core.p2m_conv._resolve_impl`)."""
    model = model or default_pixel_model()
    # Data-parallel by frame, like the rest of the vision stack
    # (DESIGN.md §7.1); a no-op outside a sharding plan.
    images = shard(images, "batch", None, None, None)
    if deploy is not None:
        fmap = apply_p2m_conv_deploy(deploy, images, cfg.conv, model,
                                     impl=impl)
        new_state = state
    else:
        fmap, conv_state = apply_p2m_conv_train(
            params["conv"], state["conv"], images, cfg.conv, model,
            train=train, impl=impl
        )
        new_state = {"conv": conv_state}
    b, h, w, c = fmap.shape
    p = cfg.pool
    x = fmap[:, : (h // p) * p, : (w // p) * p, :]
    x = x.reshape(b, h // p, p, w // p, p, c).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, (h // p) * (w // p), p * p * c)
    # Token embeddings leave with the LM activation layout so the
    # backbone's plan (batch × seq × embed_act rules) applies seamlessly.
    return shard(x @ params["proj"], "batch", "seq", "embed_act"), new_state
