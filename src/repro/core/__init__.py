"""P²M core: the paper's contribution as composable JAX modules.

Layers:
  pixel_model — SPICE-surrogate + polynomial curve fit (g(w, x))
  adc         — SS-ADC / digital-CDS model (quantized shifted ReLU)
  p2m_conv    — the in-pixel convolutional layer (train + deploy forms)
  bn_fold     — BN scale/shift folding into weights + counter pre-load
  quant       — post-training quantization + sweeps
  bandwidth   — Eq. 2-3 bandwidth-reduction model
  energy      — Eq. 4-8 EDP model (Tables 4-5 constants)
  frontend    — P²M as a modality frontend for VLM/audio backbones
"""
from repro.core.adc import ADCConfig, adc_counts, adc_dequant, shifted_relu, ste_adc
from repro.core.bandwidth import FirstLayerGeom, bandwidth_reduction, compression_ratio
from repro.core.bn_fold import bn_affine, deploy_params, fold_error
from repro.core.energy import (
    BASELINE_C_ENERGY,
    BASELINE_DELAY,
    BASELINE_NC_ENERGY,
    ConvSpec,
    DelayConstants,
    EnergyConstants,
    EDPReport,
    P2M_DELAY,
    P2M_ENERGY,
    evaluate_model,
    total_macs,
)
from repro.core.p2m_conv import (
    P2MConvConfig,
    apply_p2m_conv_deploy,
    apply_p2m_conv_train,
    extract_patches,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.pixel_model import (
    PixelModel,
    default_pixel_model,
    fit_pixel_model,
    linear_pixel_model,
    spice_surrogate,
)
from repro.core.quant import QuantSpec, fake_quant, quantize_deploy, quantize_symmetric
