"""Bandwidth-reduction model (paper §4.3, Eq. 2-3).

``BR = (I/O) · (4/3) · (12/N_b)``

* ``I = i² · 3`` RGB input elements, ``O = ((i−k+2p)/s + 1)² · c_o`` output
  elements (Eq. 3),
* ``4/3`` — Bayer RGGB → RGB compression credit,
* ``12/N_b`` — 12-bit native pixel depth vs the quantized ADC output.

Note on the paper's arithmetic: Eq. 2 as printed uses ``O/I`` (a
*compression ratio* < 1); the reduction *factor* quoted in the text
(~21×) is its reciprocal form implemented here.  With Table 1 values
(i=560, k=s=5, p=0, c_o=8, N_b=8) this evaluates to **18.75×**, which the
paper rounds up to "∼21×"; the benchmark records both (see
EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

BAYER_FACTOR = 4.0 / 3.0
SENSOR_BIT_DEPTH = 12


@dataclasses.dataclass(frozen=True)
class FirstLayerGeom:
    """First-layer hyperparameters (paper Table 1 defaults)."""

    image_size: int = 560
    kernel: int = 5
    padding: int = 0
    stride: int = 5
    out_channels: int = 8
    out_bits: int = 8

    @property
    def out_spatial(self) -> int:
        return (self.image_size - self.kernel + 2 * self.padding) // self.stride + 1

    @property
    def input_elems(self) -> int:
        return self.image_size**2 * 3

    @property
    def output_elems(self) -> int:
        return self.out_spatial**2 * self.out_channels


def bandwidth_reduction(geom: FirstLayerGeom) -> float:
    """Reduction factor: input sensor bits / output P²M bits (Eq. 2 recip)."""
    elem_ratio = geom.input_elems / geom.output_elems
    return elem_ratio * BAYER_FACTOR * (SENSOR_BIT_DEPTH / geom.out_bits)


def compression_ratio(geom: FirstLayerGeom) -> float:
    """Eq. 2 exactly as printed (O/I form): the < 1 compression ratio."""
    return 1.0 / bandwidth_reduction(geom)


def paper_table1_geom() -> FirstLayerGeom:
    return FirstLayerGeom()
