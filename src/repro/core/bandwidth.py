"""Bandwidth-reduction model (paper §4.3, Eq. 2-3).

``BR = (I/O) · (4/3) · (12/N_b)``

* ``I = i² · 3`` RGB input elements, ``O = ((i−k+2p)/s + 1)² · c_o`` output
  elements (Eq. 3),
* ``4/3`` — Bayer RGGB → RGB compression credit,
* ``12/N_b`` — 12-bit native pixel depth vs the quantized ADC output.

Note on the paper's arithmetic: Eq. 2 as printed uses ``O/I`` (a
*compression ratio* < 1); the reduction *factor* quoted in the text
(~21×) is its reciprocal form implemented here.  With Table 1 values
(i=560, k=s=5, p=0, c_o=8, N_b=8) this evaluates to **18.75×**, which the
paper rounds up to "∼21×"; the benchmark records both (see
EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

BAYER_FACTOR = 4.0 / 3.0
SENSOR_BIT_DEPTH = 12


@dataclasses.dataclass(frozen=True)
class FirstLayerGeom:
    """First-layer hyperparameters (paper Table 1 defaults).

    Validated on construction: ``out_spatial`` uses floor division, so a
    kernel larger than the padded image would silently produce a
    nonpositive output grid (and a nonsense bandwidth figure) — reject
    those geometries instead.
    """

    image_size: int = 560
    kernel: int = 5
    padding: int = 0
    stride: int = 5
    out_channels: int = 8
    out_bits: int = 8

    def __post_init__(self):
        if self.image_size < 1 or self.kernel < 1:
            raise ValueError(
                f"image_size and kernel must be >= 1, got "
                f"image_size={self.image_size} kernel={self.kernel}")
        if self.padding < 0:
            raise ValueError(f"padding must be >= 0, got {self.padding}")
        if self.kernel > self.image_size + 2 * self.padding:
            raise ValueError(
                f"kernel {self.kernel} exceeds padded image "
                f"{self.image_size} + 2*{self.padding} — out_spatial would "
                "be nonpositive")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")
        if self.out_channels < 1:
            raise ValueError(
                f"out_channels must be >= 1, got {self.out_channels}")
        if self.out_bits < 1:
            raise ValueError(f"out_bits must be >= 1, got {self.out_bits}")

    @property
    def out_spatial(self) -> int:
        return (self.image_size - self.kernel + 2 * self.padding) // self.stride + 1

    @property
    def input_elems(self) -> int:
        return self.image_size**2 * 3

    @property
    def output_elems(self) -> int:
        return self.out_spatial**2 * self.out_channels


def bandwidth_reduction(geom: FirstLayerGeom) -> float:
    """Reduction factor: input sensor bits / output P²M bits (Eq. 2 recip)."""
    elem_ratio = geom.input_elems / geom.output_elems
    return elem_ratio * BAYER_FACTOR * (SENSOR_BIT_DEPTH / geom.out_bits)


def compression_ratio(geom: FirstLayerGeom) -> float:
    """Eq. 2 exactly as printed (O/I form): the < 1 compression ratio."""
    return 1.0 / bandwidth_reduction(geom)


def paper_table1_geom() -> FirstLayerGeom:
    return FirstLayerGeom()


# ------------------------------------------------------------ event readout
#
# Frame-delta (event-style) extension for video streams, after
# Neuromorphic-P2M (arXiv:2301.09111): on a temporally redundant stream
# the sensor only reads out the P²M activation map on frames whose pixel
# delta crossed a threshold; a skipped frame transmits a single
# "no event" flag.  `video/delta.py` drives the measured accounting on a
# live stream (DESIGN.md §9); the closed form below is the static
# counterpart the bench compares it against.  See EXPERIMENTS.md
# §Bandwidth.

SKIP_FLAG_BITS = 1  # the per-frame "no event" token a skipped frame costs


def frame_output_bits(geom: FirstLayerGeom) -> int:
    """Dense per-frame readout: every P²M output element at ADC width."""
    return geom.output_elems * geom.out_bits


def event_readout_bits(geom: FirstLayerGeom, rerun_fraction: float) -> float:
    """Closed-form mean bits/frame when a fraction of frames re-run the
    stem and the rest transmit only the skip flag."""
    if not 0.0 <= rerun_fraction <= 1.0:
        raise ValueError(f"rerun_fraction must be in [0, 1], "
                         f"got {rerun_fraction}")
    return rerun_fraction * frame_output_bits(geom) + SKIP_FLAG_BITS


@dataclasses.dataclass
class StreamBandwidthLedger:
    """Measured per-stream readout accounting: one `record` per tick.

    ``bits`` is what actually crossed the sensor boundary — a skipped
    frame costs :data:`SKIP_FLAG_BITS`, a re-run frame adds the full
    dense readout — so ``reduction_vs_dense`` is a *measured* bandwidth
    reduction on the stream, not the Eq. 2 closed form.
    """

    geom: FirstLayerGeom
    frames: int = 0
    rerun_frames: int = 0
    bits: int = 0

    def record(self, reran: bool) -> int:
        """Account one frame; returns the bits it transmitted."""
        cost = SKIP_FLAG_BITS + (frame_output_bits(self.geom) if reran else 0)
        self.frames += 1
        self.rerun_frames += int(reran)
        self.bits += cost
        return cost

    @property
    def skip_rate(self) -> float:
        return 1.0 - self.rerun_frames / self.frames if self.frames else 0.0

    @property
    def bits_per_frame(self) -> float:
        return self.bits / self.frames if self.frames else 0.0

    @property
    def dense_bits_per_frame(self) -> int:
        return frame_output_bits(self.geom)

    @property
    def reduction_vs_dense(self) -> float:
        """Measured dense/actual bits ratio (> 1 once any frame skips)."""
        bpf = self.bits_per_frame
        return self.dense_bits_per_frame / bpf if bpf else 0.0

    def summary(self) -> dict:
        """The ledger as one dict — the view shape the metrics registry
        snapshots (`StreamEngine` publishes one per live gate,
        DESIGN.md §13.2)."""
        return {
            "frames": self.frames,
            "rerun_frames": self.rerun_frames,
            "bits": self.bits,
            "skip_rate": self.skip_rate,
            "bits_per_frame": self.bits_per_frame,
            "dense_bits_per_frame": self.dense_bits_per_frame,
            "reduction_vs_dense": self.reduction_vs_dense,
        }
