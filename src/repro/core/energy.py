"""Energy-delay-product model (paper §5.3, Eq. 4-8, Tables 4-5).

    E_tot ≈ (e_pix + e_adc)·N_pix  +  e_com·N_pix  +  e_mac·N_mac  [+ e_read·N_read ≈ 0]

    t_conv ≈ ceil(k²·c_i·c_o / ((B_IO/B_W)·N_bank))·t_read
           + ceil(k²·c_i·c_o / N_mult)·h_o·w_o·t_mult              (Eq. 7)

    T_delay ≈ T_sens + T_adc + Σ t_conv        (sequential, Eq. 8)
    T_delay ≈ max(T_sens + T_adc, Σ t_conv)    (conservative overlap)

All constants are the paper's 22 nm values (Tables 4-5).  The model is
deliberately parametric so the benchmark can sweep alternatives.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

# ---------------------------------------------------------------- Table 4/5


@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    """Per-op energies in pJ (22 nm, paper Table 4)."""

    e_pix: float  # per-pixel sensing/readout
    e_adc: float  # per-pixel A/D conversion
    e_com: float = 900.0  # sensor→SoC communication per pixel
    e_mac: float = 1.568  # one MAC on the SoC (45→22 nm scaled)


@dataclasses.dataclass(frozen=True)
class DelayConstants:
    """Paper Table 5."""

    t_sens_s: float  # sensor read delay
    t_adc_s: float  # total ADC delay
    t_mult_s: float = 5.48e-9
    t_read_s: float = 5.48e-9
    b_io: int = 64
    b_w: int = 32
    n_bank: int = 4
    n_mult: int = 175


P2M_ENERGY = EnergyConstants(e_pix=148.0, e_adc=41.9)
BASELINE_C_ENERGY = EnergyConstants(e_pix=312.0, e_adc=86.14)
BASELINE_NC_ENERGY = EnergyConstants(e_pix=312.0, e_adc=80.14)

P2M_DELAY = DelayConstants(t_sens_s=35.84e-3, t_adc_s=0.229e-3)
BASELINE_DELAY = DelayConstants(t_sens_s=39.2e-3, t_adc_s=4.58e-3)

# Sensor-output pixel counts (Table 4, "Sensor output pixel" column).
N_PIX_P2M = 112 * 112 * 8
N_PIX_BASELINE_C = 560 * 560 * 3
N_PIX_BASELINE_NC = 300 * 300 * 3

# ---------------------------------------------------------------- layer census


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One conv layer for MAdds/delay accounting.

    Depthwise convs are expressed with ``groups``; ``k=1`` covers pointwise
    and fully-connected (h_o = w_o = 1) layers.
    """

    k: int
    c_i: int
    c_o: int
    h_o: int
    w_o: int
    groups: int = 1

    @property
    def weights(self) -> int:
        return self.k * self.k * (self.c_i // self.groups) * self.c_o

    @property
    def macs(self) -> int:
        return self.weights * self.h_o * self.w_o


def total_macs(census: Iterable[ConvSpec]) -> int:
    return sum(l.macs for l in census)


def conv_delay_s(layer: ConvSpec, d: DelayConstants) -> float:
    """Eq. 7 for one layer."""
    wts = layer.weights
    read = math.ceil(wts / ((d.b_io / d.b_w) * d.n_bank)) * d.t_read_s
    mult = math.ceil(wts / d.n_mult) * layer.h_o * layer.w_o * d.t_mult_s
    return read + mult


def soc_delay_s(census: Iterable[ConvSpec], d: DelayConstants) -> float:
    return sum(conv_delay_s(l, d) for l in census)


# ---------------------------------------------------------------- E/D/EDP


@dataclasses.dataclass(frozen=True)
class EDPReport:
    energy_uj: float
    sens_energy_uj: float
    com_energy_uj: float
    soc_energy_uj: float
    delay_sequential_ms: float
    delay_conservative_ms: float
    edp_sequential: float  # µJ·ms
    edp_conservative: float


def evaluate_model(
    census: Sequence[ConvSpec],
    n_pix: int,
    e: EnergyConstants,
    d: DelayConstants,
) -> EDPReport:
    """Full Eq. 4-8 evaluation for one model/hardware pairing.

    ``census`` must list the *SoC-executed* conv layers only (for P²M the
    in-pixel first layer is excluded — its energy is inside e_pix/e_adc).
    """
    n_mac = total_macs(census)
    e_sens = (e.e_pix + e.e_adc) * n_pix * 1e-6  # pJ → µJ
    e_com = e.e_com * n_pix * 1e-6
    e_soc = e.e_mac * n_mac * 1e-6
    energy = e_sens + e_com + e_soc

    t_front = d.t_sens_s + d.t_adc_s
    t_soc = soc_delay_s(census, d)
    t_seq = (t_front + t_soc) * 1e3  # ms
    t_cons = max(t_front, t_soc) * 1e3

    return EDPReport(
        energy_uj=energy,
        sens_energy_uj=e_sens,
        com_energy_uj=e_com,
        soc_energy_uj=e_soc,
        delay_sequential_ms=t_seq,
        delay_conservative_ms=t_cons,
        edp_sequential=energy * t_seq,
        edp_conservative=energy * t_cons,
    )
