"""The P²M in-pixel convolutional layer (paper §3.2, §4.1-4.2).

Functionally a conv + BN + ReLU block, but computed the way the circuit
computes it:

* every multiply is the behavioral pixel function ``g(w, x)`` (not ``w·x``),
* weights live in [−1, 1] (normalized transistor driving strength; the CDS
  double-sample realizes the sign),
* the output passes through the SS-ADC: shifted ReLU with full-scale
  saturation, optionally integer-quantized.

Two parameterizations:

* **train form** — conv(g) → BatchNorm (batch stats) → saturating ReLU.
  This is what the paper trains.
* **deploy form** — BN folded (scale into weights, shift into the ADC
  counter pre-load), optional post-training quantization.  Produced by
  `bn_fold.deploy_params`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.pixel_model import PixelModel, default_pixel_model
from repro.kernels.p2m_conv.ops import p2m_matmul, p2m_matmul_jnp


@dataclasses.dataclass(frozen=True)
class P2MConvConfig:
    """Paper Table 1 defaults: k=5, s=5 (non-overlapping), p=0, c_o=8, N_b=8."""

    kernel: int = 5
    stride: int = 5
    in_channels: int = 3
    out_channels: int = 8
    n_bits: int = 8
    bn_momentum: float = 0.9
    bn_eps: float = 1e-5

    @property
    def adc(self) -> ADCConfig:
        return ADCConfig(n_bits=self.n_bits, v_lsb=1.0 / (2**self.n_bits - 1))

    def out_spatial(self, i: int) -> int:
        return (i - self.kernel) // self.stride + 1


def extract_patches(images: jax.Array, kernel: int, stride: int) -> jax.Array:
    """(B, H, W, C) → (B, P, k·k·C) patches, (kh, kw, C) fastest-varying.

    Fast path for the paper's non-overlapping case (stride == kernel,
    dims divisible): a pure reshape/transpose, no gather.  General path
    uses ``conv_general_dilated_patches`` and reorders its channel-major
    feature layout to (kh, kw, C).
    """
    b, h, w, c = images.shape
    k, s = kernel, stride
    if s == k and h % k == 0 and w % k == 0:
        ph, pw = h // k, w // k
        x = images.reshape(b, ph, k, pw, k, c)
        x = x.transpose(0, 1, 3, 2, 4, 5)  # (B, ph, pw, k, k, C)
        return x.reshape(b, ph * pw, k * k * c)
    patches = jax.lax.conv_general_dilated_patches(
        images,
        filter_shape=(k, k),
        window_strides=(s, s),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, ph, pw, C·k·k) with channel-major (C, kh, kw) feature order
    bb, ph, pw, f = patches.shape
    patches = patches.reshape(bb, ph * pw, c, k * k)
    patches = patches.transpose(0, 1, 3, 2)  # → (kh·kw, C) fastest-varying
    return patches.reshape(bb, ph * pw, k * k * c)


def init_p2m_conv(key: jax.Array, cfg: P2MConvConfig) -> dict[str, Any]:
    """Trainable params + BN state for the train form."""
    k = cfg.kernel
    fan_in = k * k * cfg.in_channels
    wkey, _ = jax.random.split(key)
    theta = jax.random.uniform(
        wkey, (k, k, cfg.in_channels, cfg.out_channels),
        minval=-1.0, maxval=1.0, dtype=jnp.float32,
    ) * (3.0 / fan_in) ** 0.5
    return {
        "theta": theta,
        "bn_gamma": jnp.ones((cfg.out_channels,), jnp.float32),
        "bn_beta": jnp.zeros((cfg.out_channels,), jnp.float32),
    }


def init_p2m_state(cfg: P2MConvConfig) -> dict[str, Any]:
    return {
        "bn_mean": jnp.zeros((cfg.out_channels,), jnp.float32),
        "bn_var": jnp.ones((cfg.out_channels,), jnp.float32),
    }


def _flat_weights(theta: jax.Array, cfg: P2MConvConfig) -> jax.Array:
    """(k,k,C,Co) → (k·k·C, Co), clipped to the transistor range [−1, 1]."""
    k = cfg.kernel
    w = jnp.clip(theta, -1.0, 1.0)
    return w.reshape(k * k * cfg.in_channels, cfg.out_channels)


def apply_p2m_conv_train(
    params: dict,
    state: dict,
    images: jax.Array,
    cfg: P2MConvConfig,
    model: PixelModel | None = None,
    *,
    train: bool = True,
    rng: jax.Array | None = None,
):
    """Train-form forward: conv(g) → BN → saturating ReLU.

    Returns ``(out (B, Ho, Wo, Co), new_state)``.
    """
    model = model or default_pixel_model()
    b = images.shape[0]
    ho = cfg.out_spatial(images.shape[1])
    wo = cfg.out_spatial(images.shape[2])
    patches = extract_patches(images, cfg.kernel, cfg.stride)  # (B,P,K)
    xf = patches.reshape(b * patches.shape[1], -1)
    w = _flat_weights(params["theta"], cfg)

    zero = jnp.zeros((cfg.out_channels,), jnp.float32)
    raw = p2m_matmul_jnp(xf, w, zero, model, cfg.adc, mode="raw")
    if model.read_noise_std > 0.0 and rng is not None:
        raw = raw + model.read_noise_std * jax.random.normal(rng, raw.shape, raw.dtype)

    if train:
        mean = raw.mean(axis=0)
        var = raw.var(axis=0)
        mom = cfg.bn_momentum
        new_state = {
            "bn_mean": mom * state["bn_mean"] + (1 - mom) * mean,
            "bn_var": mom * state["bn_var"] + (1 - mom) * var,
        }
    else:
        mean, var = state["bn_mean"], state["bn_var"]
        new_state = state
    xhat = (raw - mean) / jnp.sqrt(var + cfg.bn_eps)
    y = params["bn_gamma"] * xhat + params["bn_beta"]
    y = jnp.clip(y, 0.0, cfg.adc.full_scale)  # saturating ReLU (counter clamp)
    return y.reshape(b, ho, wo, cfg.out_channels), new_state


def apply_p2m_conv_deploy(
    deploy: dict,
    images: jax.Array,
    cfg: P2MConvConfig,
    model: PixelModel | None = None,
    *,
    quantize: bool = True,
    use_pallas: bool = True,
):
    """Deploy-form forward with folded BN: conv(g) → shifted-ReLU ADC.

    ``deploy`` holds ``w`` (k·k·C, Co) folded+clipped weights and ``shift``
    (Co,) counter pre-load in volts (see `bn_fold`).
    """
    model = model or default_pixel_model()
    b = images.shape[0]
    ho = cfg.out_spatial(images.shape[1])
    wo = cfg.out_spatial(images.shape[2])
    patches = extract_patches(images, cfg.kernel, cfg.stride)
    xf = patches.reshape(b * patches.shape[1], -1)
    mode = "quant" if quantize else "relu"
    fn = p2m_matmul if use_pallas else p2m_matmul_jnp
    if use_pallas:
        out = fn(xf, deploy["w"], deploy["shift"], model, cfg.adc, mode)
    else:
        out = fn(xf, deploy["w"], deploy["shift"], model, cfg.adc, mode=mode)
    return out.reshape(b, ho, wo, cfg.out_channels)
