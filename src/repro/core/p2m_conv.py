"""The P²M in-pixel convolutional layer (paper §3.2, §4.1-4.2).

Functionally a conv + BN + ReLU block, but computed the way the circuit
computes it:

* every multiply is the behavioral pixel function ``g(w, x)`` (not ``w·x``),
* weights live in [−1, 1] (normalized transistor driving strength; the CDS
  double-sample realizes the sign),
* the output passes through the SS-ADC: shifted ReLU with full-scale
  saturation, optionally integer-quantized.

Two parameterizations:

* **train form** — conv(g) → BatchNorm (batch stats) → saturating ReLU.
  This is what the paper trains.
* **deploy form** — BN folded (scale into weights, shift into the ADC
  counter pre-load), optional post-training quantization.  Produced by
  `bn_fold.deploy_params`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.pixel_model import PixelModel, default_pixel_model
from repro.kernels.p2m_conv.ops import p2m_conv, p2m_conv_jnp, p2m_matmul_jnp


@dataclasses.dataclass(frozen=True)
class P2MConvConfig:
    """Paper Table 1 defaults: k=5, s=5 (non-overlapping), p=0, c_o=8, N_b=8."""

    kernel: int = 5
    stride: int = 5
    in_channels: int = 3
    out_channels: int = 8
    n_bits: int = 8
    bn_momentum: float = 0.9
    bn_eps: float = 1e-5

    @property
    def adc(self) -> ADCConfig:
        return ADCConfig(n_bits=self.n_bits, v_lsb=1.0 / (2**self.n_bits - 1))

    def out_spatial(self, i: int) -> int:
        return (i - self.kernel) // self.stride + 1


def extract_patches(images: jax.Array, kernel: int, stride: int) -> jax.Array:
    """(B, H, W, C) → (B, P, k·k·C) patches, (kh, kw, C) fastest-varying.

    Fast path for the paper's non-overlapping case (stride == kernel,
    dims divisible): a pure reshape/transpose, no gather.  General path
    uses ``conv_general_dilated_patches`` and reorders its channel-major
    feature layout to (kh, kw, C).
    """
    b, h, w, c = images.shape
    k, s = kernel, stride
    if s == k and h % k == 0 and w % k == 0:
        ph, pw = h // k, w // k
        x = images.reshape(b, ph, k, pw, k, c)
        x = x.transpose(0, 1, 3, 2, 4, 5)  # (B, ph, pw, k, k, C)
        return x.reshape(b, ph * pw, k * k * c)
    patches = jax.lax.conv_general_dilated_patches(
        images,
        filter_shape=(k, k),
        window_strides=(s, s),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, ph, pw, C·k·k) with channel-major (C, kh, kw) feature order
    bb, ph, pw, f = patches.shape
    patches = patches.reshape(bb, ph * pw, c, k * k)
    patches = patches.transpose(0, 1, 3, 2)  # → (kh·kw, C) fastest-varying
    return patches.reshape(bb, ph * pw, k * k * c)


def init_p2m_conv(key: jax.Array, cfg: P2MConvConfig) -> dict[str, Any]:
    """Trainable params + BN state for the train form."""
    k = cfg.kernel
    fan_in = k * k * cfg.in_channels
    wkey, _ = jax.random.split(key)
    theta = jax.random.uniform(
        wkey, (k, k, cfg.in_channels, cfg.out_channels),
        minval=-1.0, maxval=1.0, dtype=jnp.float32,
    ) * (3.0 / fan_in) ** 0.5
    return {
        "theta": theta,
        "bn_gamma": jnp.ones((cfg.out_channels,), jnp.float32),
        "bn_beta": jnp.zeros((cfg.out_channels,), jnp.float32),
    }


def init_p2m_state(cfg: P2MConvConfig) -> dict[str, Any]:
    return {
        "bn_mean": jnp.zeros((cfg.out_channels,), jnp.float32),
        "bn_var": jnp.ones((cfg.out_channels,), jnp.float32),
    }


def _flat_weights(theta: jax.Array, cfg: P2MConvConfig) -> jax.Array:
    """(k,k,C,Co) → (k·k·C, Co), clipped to the transistor range [−1, 1]."""
    k = cfg.kernel
    w = jnp.clip(theta, -1.0, 1.0)
    return w.reshape(k * k * cfg.in_channels, cfg.out_channels)


def _resolve_impl(impl: str | None) -> str:
    """Conv implementation select: "pallas" (fused implicit-im2col kernel,
    the TPU hot path), "fused" (same decomposition in XLA ops — the
    off-TPU default), "patches" (extract_patches + p2m_matmul_jnp, the
    reference fallback)."""
    if impl is None:
        return "pallas" if jax.default_backend() == "tpu" else "fused"
    if impl not in ("pallas", "fused", "patches"):
        raise ValueError(f"unknown p2m conv impl {impl!r}")
    return impl


def _conv_raw(images, w, cfg: P2MConvConfig, model: PixelModel,
              impl: str) -> jax.Array:
    """Pre-epilogue conv accumulation (B, Ho, Wo, Co) via the chosen impl."""
    zero = jnp.zeros((cfg.out_channels,), jnp.float32)
    if impl == "pallas":
        return p2m_conv(images, w, zero, model, cfg.adc, "raw",
                        cfg.kernel, cfg.stride)
    if impl == "fused":
        return p2m_conv_jnp(images, w, zero, model, cfg.adc, "raw",
                            cfg.kernel, cfg.stride)
    b = images.shape[0]
    ho = cfg.out_spatial(images.shape[1])
    wo = cfg.out_spatial(images.shape[2])
    patches = extract_patches(images, cfg.kernel, cfg.stride)  # (B,P,K)
    xf = patches.reshape(b * patches.shape[1], -1)
    raw = p2m_matmul_jnp(xf, w, zero, model, cfg.adc, mode="raw")
    return raw.reshape(b, ho, wo, cfg.out_channels)


def apply_p2m_conv_train(
    params: dict,
    state: dict,
    images: jax.Array,
    cfg: P2MConvConfig,
    model: PixelModel | None = None,
    *,
    train: bool = True,
    rng: jax.Array | None = None,
    impl: str | None = None,
):
    """Train-form forward: conv(g) → BN → saturating ReLU.

    ``impl`` selects the conv path (see `_resolve_impl`); the default is
    the fused implicit-im2col kernel on TPU and its XLA twin elsewhere,
    with ``"patches"`` as the materializing reference fallback.

    Returns ``(out (B, Ho, Wo, Co), new_state)``.
    """
    model = model or default_pixel_model()
    b = images.shape[0]
    ho = cfg.out_spatial(images.shape[1])
    wo = cfg.out_spatial(images.shape[2])
    w = _flat_weights(params["theta"], cfg)

    raw = _conv_raw(images, w, cfg, model, _resolve_impl(impl))
    raw = raw.reshape(b * ho * wo, cfg.out_channels)
    if model.read_noise_std > 0.0 and rng is not None:
        raw = raw + model.read_noise_std * jax.random.normal(rng, raw.shape, raw.dtype)

    if train:
        mean = raw.mean(axis=0)
        var = raw.var(axis=0)
        mom = cfg.bn_momentum
        new_state = {
            "bn_mean": mom * state["bn_mean"] + (1 - mom) * mean,
            "bn_var": mom * state["bn_var"] + (1 - mom) * var,
        }
    else:
        mean, var = state["bn_mean"], state["bn_var"]
        new_state = state
    xhat = (raw - mean) / jnp.sqrt(var + cfg.bn_eps)
    y = params["bn_gamma"] * xhat + params["bn_beta"]
    y = jnp.clip(y, 0.0, cfg.adc.full_scale)  # saturating ReLU (counter clamp)
    return y.reshape(b, ho, wo, cfg.out_channels), new_state


def apply_p2m_conv_deploy(
    deploy: dict,
    images: jax.Array,
    cfg: P2MConvConfig,
    model: PixelModel | None = None,
    *,
    quantize: bool = True,
    use_pallas: bool = True,
    impl: str | None = None,
):
    """Deploy-form forward with folded BN: conv(g) → shifted-ReLU ADC.

    ``deploy`` holds ``w`` (k·k·C, Co) folded+clipped weights and ``shift``
    (Co,) counter pre-load in volts (see `bn_fold`).  The conv runs on the
    fused implicit-im2col path (``impl``, `_resolve_impl`);
    ``use_pallas=False`` is the back-compat spelling of
    ``impl="patches"`` — the patch-materializing reference.
    """
    model = model or default_pixel_model()
    mode = "quant" if quantize else "relu"
    if impl is None and not use_pallas:
        impl = "patches"
    impl = _resolve_impl(impl)
    if impl == "pallas":
        return p2m_conv(images, deploy["w"], deploy["shift"], model,
                        cfg.adc, mode, cfg.kernel, cfg.stride)
    if impl == "fused":
        return p2m_conv_jnp(images, deploy["w"], deploy["shift"], model,
                            cfg.adc, mode, cfg.kernel, cfg.stride)
    b = images.shape[0]
    ho = cfg.out_spatial(images.shape[1])
    wo = cfg.out_spatial(images.shape[2])
    patches = extract_patches(images, cfg.kernel, cfg.stride)
    xf = patches.reshape(b * patches.shape[1], -1)
    out = p2m_matmul_jnp(xf, deploy["w"], deploy["shift"], model, cfg.adc,
                         mode=mode)
    return out.reshape(b, ho, wo, cfg.out_channels)
