"""Build NamedSharding trees from (shape tree, logical-axes tree).

Logical-axes trees mirror the value trees structurally, with *tuples of
axis names* as leaves — tuples are pytree containers, so this walks
dicts manually instead of using ``jax.tree.map``.
"""
from __future__ import annotations

from typing import Any

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.axes import ShardingPlan, logical_spec


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def shardings_for(values: Any, axes: Any, plan: ShardingPlan) -> Any:
    """values: tree of arrays / ShapeDtypeStructs; axes: matching tree of
    logical-axis tuples → tree of NamedSharding."""
    if _is_axes_leaf(axes):
        shape = np.shape(values) if not hasattr(values, "shape") else values.shape
        return NamedSharding(plan.mesh, logical_spec(shape, axes, plan))
    assert isinstance(values, dict) and isinstance(axes, dict), (type(values), type(axes))
    return {k: shardings_for(values[k], axes[k], plan) for k in values.keys()}


def replicated(plan: ShardingPlan) -> NamedSharding:
    return NamedSharding(plan.mesh, P())
