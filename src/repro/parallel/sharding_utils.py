"""Build NamedSharding trees from (shape tree, logical-axes tree).

Logical-axes trees mirror the value trees structurally, with *tuples of
axis names* as leaves — tuples are pytree containers, so this walks
dicts manually instead of using ``jax.tree.map``.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.axes import ShardingPlan, logical_spec


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def shardings_for(values: Any, axes: Any, plan: ShardingPlan) -> Any:
    """values: tree of arrays / ShapeDtypeStructs; axes: matching tree of
    logical-axis tuples → tree of NamedSharding."""
    if _is_axes_leaf(axes):
        shape = np.shape(values) if not hasattr(values, "shape") else values.shape
        return NamedSharding(plan.mesh, logical_spec(shape, axes, plan))
    assert isinstance(values, dict) and isinstance(axes, dict), (type(values), type(axes))
    return {k: shardings_for(values[k], axes[k], plan) for k in values.keys()}


def replicated(plan: ShardingPlan) -> NamedSharding:
    return NamedSharding(plan.mesh, P())


def replicated_tree(values: Any, plan: ShardingPlan) -> Any:
    """Every leaf replicated — the param/optimizer sharding for plain-DP
    models (e.g. the VWW MobileNetV2, whose param tree carries no logical
    axes: conv stacks are small enough to live whole on every chip)."""
    rep = replicated(plan)
    return jax.tree.map(lambda _: rep, values)


def batch_shardings(batch: Any, plan: ShardingPlan) -> Any:
    """Dim-0 of every leaf sharded per the ``"batch"`` logical rule,
    remaining dims replicated — the input sharding for data-parallel
    steps over (B, ...) arrays (images, labels, token grids).  Scalar
    leaves (step counters, mixup lambdas) replicate."""
    def leaf(x):
        ndim = np.ndim(x)
        if ndim == 0:
            return replicated(plan)
        axes = ("batch",) + (None,) * (ndim - 1)
        return NamedSharding(plan.mesh, logical_spec(np.shape(x), axes, plan))

    return jax.tree.map(leaf, batch)
