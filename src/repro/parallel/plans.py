"""Rule tables: how logical axes map onto the production mesh.

The baseline plan is TP-over-"model" + DP-over-("pod","data"); large
archs add FSDP ("embed" → "data") so parameters and optimizer state are
fully sharded; long-context shapes add SP (sequence over "data") and
decode shapes shard the KV cache sequence over "model" (split-KV /
flash-decoding style — SPMD inserts the softmax combine collectives).

``plan_for`` is the single knob the perf hillclimb turns.
"""
from __future__ import annotations

from typing import Any

from jax.sharding import Mesh

from repro.parallel.axes import ShardingPlan

# Baseline logical rules (training, moderate model size).
BASE_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "embed_act": None,
    "heads_act": "model",
    "mlp_act": "model",
    "vocab_act": "model",
    # params
    "embed": None,          # switched to "data" under FSDP
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "conv": None,
    # decode caches / recurrent state
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_heads": "model",
    "state": "model",
}


# Vision rules (VWW MobileNetV2 ± P²M stem, DESIGN.md §7): pure data
# parallelism.  The conv stacks are tiny (≤ a few MB at width 1.0) so
# params/optimizer/BN state replicate whole; only the image batch dim is
# split.  "model"-axis rules are deliberately absent — a vision plan on a
# (data, model) mesh simply leaves the model axis unused, so the same
# plan serves a dedicated vision mesh and a slice of an LM mesh.
VISION_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "conv": None,
}


def vision_plan_for(mesh: Mesh, *,
                    overrides: dict[str, Any] | None = None) -> ShardingPlan:
    """Data-parallel plan for the VWW/vision stack (see VISION_RULES)."""
    rules = dict(VISION_RULES)
    if overrides:
        rules.update(overrides)
    return ShardingPlan(mesh=mesh, rules=rules)


def plan_for(
    mesh: Mesh,
    *,
    fsdp: bool = False,
    seq_shard: bool = False,
    cache_seq_shard: bool = False,
    cache_seq_axes: Any = "model",
    overrides: dict[str, Any] | None = None,
) -> ShardingPlan:
    """Build the sharding plan for an (arch × shape) cell.

    fsdp: shard the params' "embed" dim (and expert dim fallback) over
      "data" — ZeRO-3-style; needed for ≥7B archs to fit 16 GB/chip.
    seq_shard: sequence parallelism for activations (long prefill).
    cache_seq_shard: shard decode KV cache over ``cache_seq_axes``
      (split-KV decode; use ("data","model") when batch == 1).
    """
    rules = dict(BASE_RULES)
    if fsdp:
        rules["embed"] = "data"
    if seq_shard:
        rules["seq"] = "data"
        rules["batch"] = "pod"
    if cache_seq_shard:
        rules["cache_seq"] = cache_seq_axes
    if overrides:
        rules.update(overrides)
    # optimizer-state axes mirror the param axes unless explicitly
    # overridden (ZeRO-1: opt sharded more than params)
    rules.setdefault("opt_embed", rules.get("embed"))
    rules.setdefault("opt_mlp", rules.get("mlp"))
    return ShardingPlan(mesh=mesh, rules=rules)
