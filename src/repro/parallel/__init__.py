"""Distribution layer: mesh axes, logical-axis sharding rules, helpers.

Mesh axes (production): ``("pod", "data", "model")`` — 2 × 16 × 16 = 512
chips; single-pod is ``("data", "model")`` = 256.

Models annotate activations/params with *logical* axis names
(``batch``, ``seq``, ``embed``, ``heads``, ``mlp``, ``vocab``, ``expert``,
``cache_seq``, …); a per-run :class:`ShardingPlan` maps logical names to
mesh axes.  DP/TP/FSDP/EP/SP are all expressed as rule sets, so the perf
hillclimb is "swap the plan", not "rewrite the model".
"""
from repro.parallel.axes import (
    ShardingPlan,
    current_plan,
    logical_spec,
    logical_sharding,
    shard,
    use_plan,
    sanitize_spec,
)
from repro.parallel.plans import (
    BASE_RULES,
    VISION_RULES,
    plan_for,
    vision_plan_for,
)

__all__ = [
    "ShardingPlan",
    "current_plan",
    "logical_spec",
    "logical_sharding",
    "shard",
    "use_plan",
    "sanitize_spec",
    "BASE_RULES",
    "VISION_RULES",
    "plan_for",
    "vision_plan_for",
]
