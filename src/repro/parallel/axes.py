"""Logical-axis sharding: context-managed rules + constraint helpers.

A :class:`ShardingPlan` binds a mesh to a rule table
``logical axis name → mesh axis (or tuple of mesh axes, or None)``.
Model code calls ``shard(x, "batch", "seq", "embed")`` at layer
boundaries; outside a plan context this is a no-op, so the same model
runs unsharded on one CPU device and sharded under pjit on a pod.

Divisibility guard: a mesh axis is silently dropped from a dim's spec if
it does not divide the dim (e.g. 8 KV heads over a 16-way model axis) —
the standard MaxText-style fallback to replication for that dim.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    rules: dict[str, Any]  # logical name -> mesh axis | tuple | None

    def mesh_axes_for(self, logical: str | None):
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def with_rules(self, **overrides) -> "ShardingPlan":
        rules = dict(self.rules)
        rules.update(overrides)
        return ShardingPlan(mesh=self.mesh, rules=rules)


def current_plan() -> ShardingPlan | None:
    return getattr(_STATE, "plan", None)


@contextlib.contextmanager
def use_plan(plan: ShardingPlan | None):
    prev = current_plan()
    _STATE.plan = plan
    try:
        yield plan
    finally:
        _STATE.plan = prev


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def sanitize_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Make a raw spec legal for (shape, mesh):

    * mesh axes absent from the mesh are dropped (single-pod meshes have
      no "pod" axis);
    * axes that do not divide their dim are dropped (e.g. 8 KV heads over
      a 16-way model axis → replicate);
    * an axis may appear only once — later dims lose conflicts (e.g. MoE
      (expert, embed, mlp): when the expert dim takes "model" the mlp dim
      falls back to replicated, and when expert isn't divisible the mlp
      dim inherits "model" — EP↔TP-in-expert fallback for free).
    """
    out = []
    used: set[str] = set()
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        kept: list[str] = []
        size = dim
        for a in axes_t:
            if a not in mesh.shape or a in used:
                continue
            n = mesh.shape[a]
            if size % n == 0:
                kept.append(a)
                used.add(a)
                size //= n
            # else: drop → replicate along this mesh axis
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def logical_spec(shape: Sequence[int], logical_axes: Sequence[str | None],
                 plan: ShardingPlan | None = None) -> P:
    """Resolve logical axis names to a (sanitized) PartitionSpec."""
    plan = plan or current_plan()
    if plan is None:
        return P()
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    raw = P(*(plan.mesh_axes_for(name) for name in logical_axes))
    return sanitize_spec(shape, raw, plan.mesh)


def logical_sharding(shape: Sequence[int], logical_axes: Sequence[str | None],
                     plan: ShardingPlan | None = None) -> NamedSharding | None:
    plan = plan or current_plan()
    if plan is None:
        return None
    return NamedSharding(plan.mesh, logical_spec(shape, logical_axes, plan))


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Apply a logical sharding constraint (no-op outside a plan)."""
    plan = current_plan()
    if plan is None:
        return x
    spec = logical_spec(np.shape(x), logical_axes, plan)
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))
