import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_FLAGS",
                   "--xla_force_host_platform_device_count=512"))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract params/optimizer/cache specs (no
allocation), jits the train/prefill/serve step with shardings resolved
from the logical-axis plan, runs ``.lower().compile()``, and records:

* ``memory_analysis()`` — per-device bytes (proves the cell fits),
* ``cost_analysis()``   — FLOPs / bytes for §Roofline,
* collective bytes by op type, parsed from the optimized HLO,
* MODEL_FLOPS (6·N·D train / 2·N·D inference) for the usefulness ratio.

Results cache to ``benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json``;
re-runs skip cached cells unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
import zlib
from pathlib import Path

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.launch.hlo_analysis import analyze as analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.specs import (
    build_decode_cell,
    build_prefill_cell,
    build_train_cell,
    plan_for_cell,
)
from repro.parallel import use_plan

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def model_flops(cfg, spec) -> float:
    n = cfg.param_count_estimate()
    if spec.kind == "train":
        return 6.0 * n * spec.global_batch * spec.seq_len
    if spec.kind == "prefill":
        return 2.0 * n * spec.global_batch * spec.seq_len
    return 2.0 * n * spec.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape: str, multi_pod: bool, *, force: bool = False,
             plan_overrides: dict | None = None, tag: str = "",
             cfg_overrides: dict | None = None) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    out_path = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan_for_cell(cfg, spec, mesh, overrides=plan_overrides)

    t0 = time.time()
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape), "chips": mesh_chips(mesh),
        "kind": spec.kind, "status": "error", "tag": tag,
    }
    try:
        with use_plan(plan), mesh:
            if spec.kind == "train":
                step, abstract, shardings = build_train_cell(cfg, spec, plan)
                jitted = jax.jit(step, in_shardings=shardings,
                                 out_shardings=(shardings[0], None))
                lowered = jitted.lower(*abstract)
            elif spec.kind == "prefill":
                step, abstract, shardings = build_prefill_cell(cfg, spec, plan)
                jitted = jax.jit(step, in_shardings=shardings)
                lowered = jitted.lower(*abstract)
            else:
                step, abstract, shardings = build_decode_cell(cfg, spec, plan)
                jitted = jax.jit(step, in_shardings=shardings,
                                 out_shardings=(None, shardings[1]))
                lowered = jitted.lower(*abstract)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(t_compile, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("generated_code_size_in_bytes",
                         "argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    record.setdefault("memory", {})[attr] = int(v)

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        # raw XLA numbers (loop bodies counted ONCE — kept for reference)
        record["xla_cost_flops_bodyonce"] = float(cost.get("flops", 0.0))
        record["xla_cost_bytes_bodyonce"] = float(cost.get("bytes accessed", 0.0))

        hlo = compiled.as_text()
        # loop-aware static analysis (per-device): dot FLOPs, HBM traffic,
        # collective bytes — see launch/hlo_analysis.py
        ana = analyze_hlo(hlo)
        record["flops_per_device"] = ana["flops"]
        record["bytes_per_device"] = ana["traffic_bytes"]
        record["collectives"] = ana["collectives"]
        record["hlo_bytes"] = len(hlo)
        record["model_flops"] = model_flops(cfg, spec)
        record["param_count"] = cfg.param_count_estimate()
        record["status"] = "ok"
        hlo_path = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}{tag}.hlo.z"
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        hlo_path.write_bytes(zlib.compress(hlo.encode(), 6))
    except Exception as e:  # record failures — they are bugs to fix
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    status = record["status"]
    extra = ("" if status == "ok"
             else f"  {record.get('error', '')[:120]}")
    print(f"[dryrun] {arch:22s} {shape:12s} {mesh_name:8s} {status}{extra}",
          flush=True)
    return record


def reanalyze() -> None:
    """Recompute analysis fields from the saved .hlo.z artifacts (no
    recompilation) — used when the static analyzer improves."""
    n = 0
    for jpath in sorted(RESULTS_DIR.glob("*.json")):
        hpath = jpath.with_suffix("").with_suffix("")  # strip .json
        hpath = jpath.parent / (jpath.stem + ".hlo.z")
        if not hpath.exists():
            continue
        record = json.loads(jpath.read_text())
        hlo = zlib.decompress(hpath.read_bytes()).decode()
        ana = analyze_hlo(hlo)
        record["flops_per_device"] = ana["flops"]
        record["bytes_per_device"] = ana["traffic_bytes"]
        record["collectives"] = ana["collectives"]
        jpath.write_text(json.dumps(record, indent=1))
        n += 1
    print(f"[dryrun] reanalyzed {n} cells from saved HLO")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze()
        return

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for shape in shapes:
            if not applicable(arch, cfg.family, shape):
                n_skip += 1
                continue
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force)
                if rec["status"] == "ok":
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"(inapplicable cells)")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
