"""Static analysis of optimized (SPMD-partitioned) HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports)
counts each ``while`` body **once**, so for scan-over-layers models it
understates FLOPs and collective traffic by ~n_layers×.  This module
re-derives per-device totals with loop multipliers:

* splits the module into computations,
* walks the call graph from ENTRY, propagating multipliers:
  ``while`` bodies × known_trip_count (annotated by XLA in
  ``backend_config={"known_trip_count":{"n":…}}``), fusions/calls ×1,
* FLOPs: every ``dot`` (including inside fusions) as
  ``2 · result_elems · Π(contracting dims)``,
* HBM traffic: per top-level instruction, operands + result bytes
  (fusions count as one kernel; their internals are skipped) — the
  standard one-kernel-one-roundtrip traffic model,
* collective bytes by type (operand-side accounting; ``*-done`` ops
  skipped so async pairs count once).

All numbers are per-device (the module is the per-partition program).
"""
from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "f16": 2, "bf16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
                "c128": 16, "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CALLS = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes that move no HBM bytes themselves
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota"}


def _shape_info(type_str: str) -> tuple[int, list[list[int]]]:
    """(total bytes, list of dim-lists) for a (possibly tuple) type."""
    total = 0
    shapes = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        shapes.append(dl)
    return total, shapes


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_dims: list
    args: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    entry: bool
    instrs: list

    @property
    def root(self) -> "Instr | None":
        for i in self.instrs:
            if i.is_root:
                return i
        return self.instrs[-1] if self.instrs else None


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            current = Computation(hdr.group(2), bool(hdr.group(1)), [])
            comps[current.name] = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, args = m.groups()
        rb, dims = _shape_info(type_str)
        current.instrs.append(Instr(name, opcode, rb,
                                    dims[0] if len(dims) == 1 else dims, args,
                                    is_root="ROOT" in line.split("=")[0]))
    return comps


def _dus_traffic(ins: Instr, by_name: dict) -> float:
    """dynamic-update-slice is in-place on real hardware: traffic is the
    updated slice (read-modify-write), not the full carried buffer."""
    ops = _OPERAND.findall(ins.args)
    if len(ops) >= 2 and ops[1] in by_name:
        return 2.0 * by_name[ops[1]].result_bytes
    return 2.0 * ins.result_bytes


def _dot_flops(instr: Instr, by_name: dict[str, Instr]) -> float:
    ops = _OPERAND.findall(instr.args.split(", lhs_contracting")[0])
    lhs = by_name.get(ops[0]) if ops else None
    m = _LHS_C.search(instr.args)
    if lhs is None or m is None or not isinstance(lhs.result_dims, list):
        return 0.0
    contract = 1
    dims = lhs.result_dims
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            contract *= dims[idx]
    result_elems = 1
    rd = instr.result_dims if instr.result_dims and isinstance(
        instr.result_dims[0], int) else []
    for d in rd:
        result_elems *= d
    return 2.0 * result_elems * contract


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.entry), None)
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "collectives": {}}

    # call-graph multipliers + fusion marking
    mult: dict[str, float] = {entry.name: 1.0}
    fused: set[str] = set()
    order = [entry.name]
    seen = {entry.name}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for ins in comp.instrs:
            callees = _CALLS.findall(ins.args)
            conds = _COND.findall(ins.args)
            if ins.opcode == "while":
                tm = _TRIP.search(ins.args)
                trip = float(tm.group(1)) if tm else 1.0
                for cal in callees + conds:
                    mult[cal] = mult.get(cal, 0.0) + m * trip
                    if cal not in seen:
                        seen.add(cal)
                        order.append(cal)
            else:
                for cal in callees + conds:
                    mult[cal] = mult.get(cal, 0.0) + m
                    if ins.opcode == "fusion":
                        fused.add(cal)
                    if cal not in seen:
                        seen.add(cal)
                        order.append(cal)

    flops = 0.0
    traffic = 0.0
    coll = {c: {"bytes": 0.0, "count": 0.0} for c in COLLECTIVES}
    unknown_trips = 0

    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue  # unreachable (dead computations)
        by_name = {i.name: i for i in comp.instrs}
        in_fusion = comp.name in fused
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, by_name)
            if in_fusion:
                continue  # fusion internals: no independent HBM traffic
            if ins.opcode in _NO_TRAFFIC or ins.opcode.endswith("-done"):
                continue
            if ins.opcode == "dynamic-update-slice":
                traffic += m * _dus_traffic(ins, by_name)
                continue
            if ins.opcode == "dynamic-slice":
                traffic += m * 2.0 * ins.result_bytes
                continue
            if ins.opcode == "fusion":
                callee = _CALLS.search(ins.args)
                root = comps[callee.group(1)].root if (
                    callee and callee.group(1) in comps) else None
                if root is not None and root.opcode == "dynamic-update-slice":
                    # in-place update fusion: slice RMW + compute inputs ≈ 3×
                    callee_by = {i.name: i for i in comps[callee.group(1)].instrs}
                    traffic += m * 1.5 * _dus_traffic(root, callee_by)
                    continue
            operand_bytes = sum(
                by_name[o].result_bytes for o in _OPERAND.findall(ins.args)
                if o in by_name)
            base = None
            for c in COLLECTIVES:
                if ins.opcode == c or ins.opcode.startswith(c + "-"):
                    base = c
                    break
            if base is not None:
                eff = operand_bytes or ins.result_bytes
                if base == "all-gather":
                    eff = min(eff, ins.result_bytes)
                coll[base]["bytes"] += m * eff
                coll[base]["count"] += m
            traffic += m * (operand_bytes + ins.result_bytes)

    coll_total = sum(v["bytes"] for v in coll.values())
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": {**coll, "total_bytes": coll_total},
        "n_computations": len(comps),
        "unknown_trips": unknown_trips,
    }
