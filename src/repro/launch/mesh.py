"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e pods, 256
chips/pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512
chips).  Hardware constants for the roofline live here too.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_submeshes(n: int, *, model: int = 1, devices=None):
    """Split the visible devices into ``n`` disjoint ("data", "model")
    submeshes — one per replica of a `serving.pool.ReplicaPool`, so a
    pool of sharded engines gets data-parallelism *within* each replica
    and replica-parallelism across them (DESIGN.md §11).  Contiguous
    device slices: replica boundaries line up with physical locality on
    real topologies."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(devices if devices is not None else jax.devices())
    assert len(devs) % n == 0, (len(devs), n)
    per = len(devs) // n
    assert per % model == 0, (per, model)
    return [Mesh(np.asarray(devs[i * per:(i + 1) * per])
                 .reshape(per // model, model), ("data", "model"))
            for i in range(n)]


# TPU v5e per-chip constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size)
