"""Per-cell (arch × shape) abstract specs, sharding plans, and step
builders for the dry-run and the launchers.

Everything here is allocation-free: parameters/optimizer state come from
``jax.eval_shape`` over the real init functions, inputs are
ShapeDtypeStructs, and decode caches use the families' ``abstract=True``
path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.families import get_family
from repro.models.init_utils import abstract_init
from repro.optim import adamw, constant
from repro.parallel import ShardingPlan, plan_for
from repro.parallel.sharding_utils import shardings_for
from repro.train.state import state_logical_axes
from repro.train.step import make_train_step

FSDP_THRESHOLD = 5e9  # params; above this, shard "embed" over "data"
WHISPER_DECODER_LEN = 448


# ------------------------------------------------------------------ inputs


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> tuple[dict, dict]:
    """ShapeDtypeStruct stand-ins for every model input + logical axes."""
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    act = cfg.dtype
    if spec.kind == "train" or spec.kind == "prefill":
        if cfg.family == "encdec":
            # seq applies to the (stub-embedded) audio frames; decoder
            # tokens are bounded by whisper's context.
            sd = min(s, WHISPER_DECODER_LEN)
            inputs = {
                "src_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), act),
                "tokens": jax.ShapeDtypeStruct((b, sd), i32),
                "targets": jax.ShapeDtypeStruct((b, sd), i32),
            }
            axes = {
                "src_embeds": ("batch", "seq", "embed_act"),
                "tokens": ("batch", "seq"),
                "targets": ("batch", "seq"),
            }
        else:
            inputs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
            }
            axes = {
                "tokens": ("batch", "seq"),
                "targets": ("batch", "seq"),
            }
            if cfg.family == "vlm":
                inputs["image_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_image_tokens, cfg.d_model), act)
                axes["image_embeds"] = ("batch", None, "embed_act")
        if spec.kind == "prefill":
            inputs.pop("targets")
            axes.pop("targets")
        return inputs, axes

    # decode: one new token against a seq_len-deep cache/state
    inputs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
    }
    axes = {"tokens": ("batch", None), "pos": ("batch",)}
    return inputs, axes


# ------------------------------------------------------------------ plans


def plan_for_cell(cfg: ModelConfig, spec: ShapeSpec, mesh,
                  overrides: dict | None = None) -> ShardingPlan:
    fsdp = cfg.param_count_estimate() > FSDP_THRESHOLD
    cache_seq_shard = spec.kind == "decode"
    cache_axes: Any = ("data", "model") if spec.global_batch == 1 else "model"
    return plan_for(
        mesh,
        fsdp=fsdp,
        cache_seq_shard=cache_seq_shard,
        cache_seq_axes=cache_axes,
        overrides=overrides,
    )


# ------------------------------------------------------------------ steps


def build_train_cell(cfg: ModelConfig, spec: ShapeSpec, plan: ShardingPlan):
    """Abstract (state, batch) + shardings + step fn for a training cell."""
    family = get_family(cfg)
    optimizer = adamw(constant(1e-4))

    with abstract_init():
        params_sds, param_axes = family.init(jax.random.PRNGKey(0), cfg)
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    state_sds = {
        "params": params_sds,
        "opt": opt_sds,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_axes = state_logical_axes(param_axes, opt_sds)

    inputs, input_axes = input_specs(cfg, spec)
    step = make_train_step(cfg, optimizer)
    state_sh = shardings_for(state_sds, state_axes, plan)
    input_sh = shardings_for(inputs, input_axes, plan)
    return step, (state_sds, inputs), (state_sh, input_sh)


def build_prefill_cell(cfg: ModelConfig, spec: ShapeSpec, plan: ShardingPlan):
    """Forward-only (inference prefill) cell."""
    family = get_family(cfg)

    with abstract_init():
        params_sds, param_axes = family.init(jax.random.PRNGKey(0), cfg)
    inputs, input_axes = input_specs(cfg, spec)

    from repro.models import rglru, rwkv6, transformer, vlm, whisper

    if cfg.family in ("dense", "moe"):
        fwd = lambda p, b: transformer.forward(p, b["tokens"], cfg)[0]
    elif cfg.family == "rwkv":
        fwd = lambda p, b: rwkv6.forward(p, b["tokens"], cfg)[0]
    elif cfg.family == "rglru":
        fwd = lambda p, b: rglru.forward(p, b["tokens"], cfg)[0]
    elif cfg.family == "vlm":
        fwd = lambda p, b: vlm.forward(p, b["tokens"], b["image_embeds"], cfg)[0]
    else:
        fwd = lambda p, b: whisper.forward(p, b["src_embeds"], b["tokens"], cfg)[0]

    params_sh = shardings_for(params_sds, param_axes, plan)
    input_sh = shardings_for(inputs, input_axes, plan)
    return fwd, (params_sds, inputs), (params_sh, input_sh)


def build_decode_cell(cfg: ModelConfig, spec: ShapeSpec, plan: ShardingPlan):
    """serve_step: one token against a seq_len KV cache / recurrent state."""
    family = get_family(cfg)

    with abstract_init():
        params_sds, param_axes = family.init(jax.random.PRNGKey(0), cfg)
        state_sds, state_axes = family.init_decode_state(
            cfg, spec.global_batch, spec.seq_len, abstract=True)
    inputs, input_axes = input_specs(cfg, spec)

    def serve_step(params, state, tokens, pos):
        return family.decode(params, state, tokens, pos, cfg)

    shard_tuple = (
        shardings_for(params_sds, param_axes, plan),
        shardings_for(state_sds, state_axes, plan),
        shardings_for(inputs["tokens"], input_axes["tokens"], plan),
        shardings_for(inputs["pos"], input_axes["pos"], plan),
    )
    abstract = (params_sds, state_sds, inputs["tokens"], inputs["pos"])
    return serve_step, abstract, shard_tuple
