"""Training driver.

Runs an end-to-end training loop on the current host's devices (reduced
configs on CPU; the same code path scales to the production mesh — the
dry-run proves those shardings compile).  Wires: config → data pipeline
→ optimizer → jit'd train step (sharded when a mesh is available) →
Trainer (checkpointing, straggler monitor, restart).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --smoke --steps 50
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import DataPipeline, SyntheticLMDataset
from repro.checkpoint import CheckpointManager
from repro.models.families import get_family
from repro.optim import adamw, cosine_warmup
from repro.parallel import plan_for, use_plan
from repro.parallel.sharding_utils import shardings_for
from repro.train import Trainer, TrainState, make_train_step
from repro.train.state import state_logical_axes
from repro.launch.mesh import make_debug_mesh


def build_batch_transform(cfg, batch_size, seq):
    """Attach stub modality inputs for vlm/encdec families."""
    def transform(batch):
        if cfg.family == "vlm":
            rng = np.random.default_rng(0)
            batch["image_embeds"] = rng.normal(
                0, 1, (batch_size, cfg.n_image_tokens, cfg.d_model)
            ).astype(np.float32)
        if cfg.family == "encdec":
            rng = np.random.default_rng(0)
            batch["src_embeds"] = rng.normal(
                0, 1, (batch_size, seq, cfg.d_model)).astype(np.float32)
        return batch
    return transform


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", choices=["int8_ef"], default=None)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(dtype=jnp.float32)  # CPU-friendly
    family = get_family(cfg)

    mesh = make_debug_mesh(model=args.model_parallel)
    plan = plan_for(mesh)

    dataset = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                                 batch=args.batch)
    pipeline = DataPipeline(dataset,
                            transform=build_batch_transform(cfg, args.batch,
                                                            args.seq))

    optimizer = adamw(cosine_warmup(args.lr, warmup=20, total=args.steps))
    with use_plan(plan):
        params, param_axes = family.init(jax.random.PRNGKey(0), cfg)
        state = TrainState(params, optimizer.init(params))
        state_axes = state_logical_axes(param_axes, state["opt"])
        state_sh = shardings_for(state, state_axes, plan)
        step = make_train_step(cfg, optimizer, accum_steps=args.accum,
                               grad_compression=args.grad_compression)
        jitted = jax.jit(step, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None), donate_argnums=(0,))

        def wrapped(state, batch):
            return jitted(state, batch)

        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        trainer = Trainer(wrapped, state, pipeline, ckpt_manager=ckpt,
                          ckpt_every=args.ckpt_every if ckpt else 0)
        if ckpt is not None and trainer.restore():
            print(f"resumed from step {int(jax.device_get(trainer.state['step']))}")
        final = trainer.run(args.steps)
    pipeline.close()
    print(f"final: {final}")


if __name__ == "__main__":
    main()
