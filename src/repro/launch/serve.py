"""Serving driver: continuous-batching engines + the multi-engine front
door that routes mixed LM/vision traffic.

Single-engine LM serving (original driver):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 12 --max-batch 4

Mixed LM + vision traffic through the front door:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --mixed --vision-requests 12
"""
from __future__ import annotations

import argparse
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine, VisionRequest
from repro.serving.scheduler import drive


class FrontDoor:
    """Multi-engine front door: one submission surface over per-modality
    engines (DESIGN.md §8).

    Requests route by each engine's declared ``request_type``
    (``Request`` → the LM engine, ``VisionRequest`` → the vision engine,
    ``StreamRequest`` → the multi-tick video stream engine — any
    `SlotEngine` adapter that declares one plugs in without touching the
    router); each engine keeps its own clock, queue policy,
    and latency ledger, while the front door drives them in lockstep —
    one front-door tick steps every registered engine (idle engines just
    advance their clock, see ``step``) — and merges
    their completion streams into a single list in completion order
    (``(name, request)`` pairs; ties within a tick resolve in engine
    registration order).

    ``arrival_tick`` on submitted-via-``run`` requests is interpreted on
    the *front door's* clock, so a mixed trace replays against one
    timeline even though the engines tick independently.
    """

    def __init__(self, **engines):
        if not engines:
            raise ValueError("FrontDoor needs at least one engine")
        self.engines = engines
        self.tick = 0
        self.completed: list[tuple[str, object]] = []
        self.down: dict[str, str] = {}  # engine name -> failure reason

    def _route(self, req) -> str:
        # Route by the request type each engine's adapter declares.
        for name, engine in self.engines.items():
            want = getattr(engine, "request_type", None)
            if want is not None and isinstance(req, want):
                return name
        raise TypeError(f"no engine registered for {type(req).__name__}")

    def submit(self, req) -> str:
        """Route and submit; returns the engine's admission status
        (`ADMITTED` / a `REJECTED_*` constant).  Submissions to a down
        engine bounce with `REJECTED_HALTED` instead of raising — one
        modality failing must not poison the submission surface."""
        return self.engines[self._route(req)].submit(req)

    def busy(self) -> bool:
        return any(e.busy() for e in self.engines.values())

    def step(self) -> list[tuple[str, object]]:
        """One front-door tick: step every engine in lockstep (idle
        engines just advance their clock — the core skips the launch —
        so engine ticks stay aligned with the front-door timeline and
        per-engine latency counters read on one clock).  Returns this
        tick's merged completions as ``(engine name, request)``.

        Fault containment (DESIGN.md §10): an engine whose ``step``
        escapes its own containment (a bug past the scheduler's launch
        quarantine) is *halted*, not propagated — its queued and running
        requests land on its ``failed`` ledger, it bounces future
        submissions, and the other engines keep serving."""
        self.tick += 1
        out = []
        for name, engine in self.engines.items():
            if name in self.down:
                continue
            try:
                out.extend((name, r) for r in engine.step())
            except Exception as exc:  # noqa: BLE001 — isolation boundary
                reason = f"{type(exc).__name__}: {exc}"
                self.down[name] = reason
                engine.halt(reason)
        self.completed.extend(out)
        return out

    def run(self, requests: Sequence | None = None,
            max_ticks: int = 10_000,
            on_undrained: str = "warn") -> list[tuple[str, object]]:
        # same replay as a lone engine
        drive(self, requests, max_ticks, on_undrained=on_undrained)
        return self.completed

    def latency_summary(self) -> dict:
        return {name: engine.latency_summary()
                for name, engine in self.engines.items()}

    def health(self) -> dict:
        """Aggregate health report: per-engine `SlotEngine.health()`
        plus the front door's own view of which engines are down."""
        return {
            "tick": self.tick,
            "down": dict(self.down),
            "engines": {name: engine.health()
                        for name, engine in self.engines.items()},
        }


def _make_vision_engine(image_size: int = 40, max_batch: int = 4):
    from repro.models.mobilenetv2 import MNV2Config, init_mnv2
    from repro.serving import VisionEngine

    cfg = MNV2Config(variant="p2m", image_size=image_size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(1), cfg)
    return VisionEngine(params, bn, cfg, max_batch=max_batch), cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help=">1 enables the chunked-prefill fast path")
    ap.add_argument("--mixed", action="store_true",
                    help="route a mixed LM + vision stream via FrontDoor")
    ap.add_argument("--vision-requests", type=int, default=8)
    ap.add_argument("--video-streams", type=int, default=0,
                    help="with --mixed: add N multi-tick video streams "
                         "(delta-gated detection, DESIGN.md §9)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(dtype=jnp.float32)
    family = get_family(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve driver targets pure-text families; "
                         "multimodal serving needs per-request prefill of "
                         "cross-attention KV (see serving/engine.py notes)")

    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, max_batch=args.max_batch,
                         max_len=args.max_len,
                         prefill_chunk=args.prefill_chunk)

    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.max_new_tokens))

    if args.mixed:
        from repro.data import SyntheticVWW

        vision, vcfg = _make_vision_engine()
        frames = SyntheticVWW(image_size=vcfg.image_size,
                              batch=args.vision_requests).batch_at(0)["images"]
        for uid in range(args.vision_requests):
            reqs.append(VisionRequest(uid=1000 + uid, image=frames[uid],
                                      arrival_tick=uid // 2))
        engines = {"lm": engine, "vision": vision}
        if args.video_streams:
            from repro.models.mobilenetv2 import head_out_channels
            from repro.video import (DetectConfig, StreamEngine,
                                     StreamRequest, SyntheticVideo,
                                     init_detect_head)

            vparams, vbn = vision._params, vision._bn
            det = init_detect_head(
                jax.random.PRNGKey(2),
                head_out_channels(vcfg),
                DetectConfig())
            engines["stream"] = StreamEngine(vparams, vbn, vcfg, det,
                                             max_streams=2)
            for uid in range(args.video_streams):
                vid = SyntheticVideo(image_size=vcfg.image_size,
                                     n_frames=8, seed=uid)
                reqs.append(StreamRequest(uid=2000 + uid,
                                          frames=vid.frames(),
                                          arrival_tick=uid))
        door = FrontDoor(**engines)
        t0 = time.perf_counter()
        done = door.run(reqs)
        dt = time.perf_counter() - t0
        by = {name: [r for n, r in done if n == name] for name in engines}
        toks = sum(len(r.output) for r in by["lm"])
        print(f"front door: {len(by['lm'])} LM requests ({toks} tokens) + "
              f"{len(by['vision'])} frames + "
              f"{len(by.get('stream', []))} video streams in {dt:.2f}s "
              f"({door.tick} front-door ticks)")
        if "stream" in engines:
            s = engines["stream"].stream_summary()
            print(f"  stream: {s['frames']} frames, "
                  f"stem-skip {s['stem_skip_rate']:.2f}, "
                  f"measured bandwidth reduction "
                  f"{s['measured_reduction_vs_dense']:.2f}x vs dense")
        for name, s in door.latency_summary().items():
            print(f"  {name}: launches={s['launches']} "
                  f"mean_queue={s['mean_queue_ticks']:.2f} ticks "
                  f"mean_launch={s['mean_launch_us'] / 1e3:.1f} ms")
        return

    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4]} → out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
