"""Serving driver: continuous-batching engines + the multi-engine front
door that routes mixed LM/vision traffic.

Single-engine LM serving (original driver):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 12 --max-batch 4

Mixed LM + vision traffic through the front door:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --mixed --vision-requests 12
"""
from __future__ import annotations

import argparse
import heapq
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine, VisionRequest
from repro.serving.scheduler import drive


class FrontDoor:
    """Multi-engine front door: one submission surface over per-modality
    engines and replica pools (DESIGN.md §8, §11).

    Requests route by each engine's declared ``request_type``
    (``Request`` → the LM engine, ``VisionRequest`` → the vision engine,
    ``StreamRequest`` → the multi-tick video stream engine — any
    `SlotEngine` adapter or `serving.pool.ReplicaPool` that declares one
    plugs in without touching the router); each engine keeps its own
    clock, queue policy, and latency ledger, and completion streams
    merge into a single list in completion order (``(name, request)``
    pairs; ties within a tick resolve in engine registration order).

    **Event-driven cadences (DESIGN.md §11):** each engine declares a
    ``tick_cost`` — one engine tick costs that many ticks of front-door
    time (LM prefill is expensive, a vision microbatch cheap, a stream
    frame cheapest).  The door advances a dense virtual clock one tick
    per ``step`` and fires engines off a priority queue of ready events:
    an engine with ``tick_cost=c`` first fires at door tick ``c`` and
    re-arms ``c`` ticks later each time, so cheap engines tick many
    times while an expensive one ticks once and a slow modality never
    stalls a fast one.  With every ``tick_cost`` equal the schedule is
    *bit-identical* to the legacy lockstep door (``lockstep=True`` keeps
    that path alive as the equivalence reference, gated by
    ``benchmarks/bench_serve_saturation.py``).

    ``arrival_tick`` on submitted-via-``run`` requests is interpreted on
    the *front door's* clock, and every tick-denominated latency figure
    the door reports is converted engine ticks → front-door ticks here,
    once (``tick_cost ×``, any ``*_ticks`` key at any depth) — adapters
    never convert.
    """

    def __init__(self, lockstep: bool = False, tracer=None, registry=None,
                 **engines):
        """``tracer``/``registry`` are the observability knobs
        (DESIGN.md §13): the tracer gets this door attached as its clock
        root — each engine's track is labeled by its registration name
        and scaled by its ``tick_cost`` so every stamp in the export
        lands on the door's shared virtual clock; the registry receives
        the door's latency/health views (``None`` = process default).
        Neither touches the schedule (``tracer=None`` is bit-for-bit
        free)."""
        if not engines:
            raise ValueError("FrontDoor needs at least one engine")
        self.engines = engines
        self.lockstep = lockstep
        self.tracer = tracer
        self.tick = 0
        self.completed: list[tuple[str, object]] = []
        self.down: dict[str, str] = {}  # engine name -> failure reason
        self._order = list(engines)  # registration order = tie-break order
        self._costs = {}
        for name, engine in engines.items():
            cost = getattr(engine, "tick_cost", 1)
            if not (isinstance(cost, int) and cost >= 1):
                raise ValueError(f"engine {name!r} declares tick_cost "
                                 f"{cost!r}; need an int >= 1")
            if lockstep and cost != 1:
                raise ValueError(f"lockstep door requires tick_cost=1 "
                                 f"everywhere; engine {name!r} declares "
                                 f"{cost}")
            self._costs[name] = cost
        if tracer is not None:
            tracer.attach(self, "door")
            for name, engine in engines.items():
                engine.tracer = tracer
                tracer.label(engine, name)
                tracer.set_scale(engine, self._costs[name])
                # replica pools fan events out from their replicas, which
                # tick on the pool's cadence — same scale
                for k, rep in enumerate(getattr(engine, "replicas", ())):
                    rep.tracer = tracer
                    tracer.label(rep, f"{name}[{k}]")
                    tracer.set_scale(rep, self._costs[name])
        from repro.obs.metrics import default_registry

        reg = registry if registry is not None else default_registry()
        self.metrics_scope = reg.register_component(
            self, {"latency": self.latency_summary, "health": self.health})
        # Ready-event queue: (due door-tick, registration index).  An
        # engine first fires once its cost is paid, i.e. at tick ==
        # tick_cost; heap order + index tie-break keeps the schedule
        # deterministic.
        self._due = [(self._costs[name], ix)
                     for ix, name in enumerate(self._order)]
        heapq.heapify(self._due)

    def _route(self, req) -> str:
        # Route by the request type each engine's adapter declares.
        for name, engine in self.engines.items():
            want = getattr(engine, "request_type", None)
            if want is not None and isinstance(req, want):
                return name
        registered = ", ".join(
            f"{name}={getattr(e, 'request_type', None).__name__}"
            for name, e in self.engines.items()
            if getattr(e, "request_type", None) is not None) or "none"
        raise TypeError(f"no engine registered for {type(req).__name__}; "
                        f"registered request types: {registered}")

    def submit(self, req) -> str:
        """Route and submit; returns the engine's admission status
        (`ADMITTED` / a `REJECTED_*` constant).  Submissions to a down
        engine bounce with `REJECTED_HALTED` instead of raising — one
        modality failing must not poison the submission surface."""
        return self.engines[self._route(req)].submit(req)

    def busy(self) -> bool:
        return any(e.busy() for e in self.engines.values())

    def _step_engine(self, name: str, out: list) -> bool:
        """Step one engine inside the isolation boundary; returns False
        when the engine was halted by this step.

        Fault containment (DESIGN.md §10): an engine whose ``step``
        escapes its own containment (a bug past the scheduler's launch
        quarantine) is *halted*, not propagated — its queued and running
        requests land on its ``failed`` ledger, it bounces future
        submissions, and the other engines keep serving."""
        engine = self.engines[name]
        try:
            out.extend((name, r) for r in engine.step())
            return True
        except Exception as exc:  # noqa: BLE001 — isolation boundary
            reason = f"{type(exc).__name__}: {exc}"
            self.down[name] = reason
            engine.halt(reason)
            return False

    def step(self) -> list[tuple[str, object]]:
        """One front-door tick: advance the virtual clock by one and
        fire every engine whose ready event is due (all of them, in the
        lockstep reference path).  A fired engine re-arms ``tick_cost``
        ticks out; a halted engine leaves the event queue.  Returns this
        tick's merged completions as ``(engine name, request)``,
        registration-ordered within the tick."""
        self.tick += 1
        out: list[tuple[str, object]] = []
        if self.lockstep:
            for name in self._order:
                if name not in self.down:
                    self._step_engine(name, out)
            if self.tracer is not None:
                self.tracer.tick_span(self, "door_tick", self.tick, 1, 0,
                                      fired=len(self._order) - len(self.down),
                                      finished=len(out))
            self.completed.extend(out)
            return out
        fired: list[int] = []
        while self._due and self._due[0][0] <= self.tick:
            fired.append(heapq.heappop(self._due)[1])
        for ix in sorted(fired):  # registration order within the tick
            name = self._order[ix]
            if name in self.down:
                continue
            if self._step_engine(name, out):
                heapq.heappush(self._due, (self.tick + self._costs[name], ix))
        if self.tracer is not None and fired:
            self.tracer.tick_span(self, "door_tick", self.tick, 1, 0,
                                  fired=len(fired), finished=len(out))
        self.completed.extend(out)
        return out

    def run(self, requests: Sequence | None = None,
            max_ticks: int = 10_000,
            on_undrained: str = "warn") -> list[tuple[str, object]]:
        # same replay as a lone engine
        drive(self, requests, max_ticks, on_undrained=on_undrained)
        return self.completed

    def _on_door_clock(self, name: str, obj):
        """Convert an engine's tick-denominated report onto the shared
        front-door clock: every ``*_ticks`` key, at any depth (replica
        pools nest per-replica summaries), scales by the engine's
        ``tick_cost``.  This is the single conversion point — adapters
        and pools always report on their own clocks."""
        cost = self._costs[name]
        if cost == 1:
            return obj

        def conv(x):
            if isinstance(x, dict):
                return {k: (v * cost if k.endswith("_ticks")
                            and isinstance(v, (int, float))
                            else conv(v))
                        for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                return type(x)(conv(v) for v in x)
            return x

        return conv(obj)

    def latency_summary(self) -> dict:
        """Per-engine latency summaries, tick figures converted onto the
        front-door clock (see ``_on_door_clock``)."""
        return {name: self._on_door_clock(name, engine.latency_summary())
                for name, engine in self.engines.items()}

    def health(self) -> dict:
        """Aggregate health report: per-engine `SlotEngine.health()`
        (queue depth + occupancy — the dispatcher's load signal doubles
        as the operator's) *folded with* each engine's latency-summary
        percentiles on the front-door clock, plus the door's own view of
        which engines are down — one surface for observability and load
        signals alike."""
        return {
            "tick": self.tick,
            "down": dict(self.down),
            "engines": {
                name: {
                    **engine.health(),
                    "tick_cost": self._costs[name],
                    "latency": self._on_door_clock(
                        name, engine.latency_summary()),
                }
                for name, engine in self.engines.items()},
        }


def _make_vision_engine(image_size: int = 40, max_batch: int = 4):
    from repro.models.mobilenetv2 import MNV2Config, init_mnv2
    from repro.serving import VisionEngine

    cfg = MNV2Config(variant="p2m", image_size=image_size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(1), cfg)
    return VisionEngine(params, bn, cfg, max_batch=max_batch), cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help=">1 enables the chunked-prefill fast path")
    ap.add_argument("--mixed", action="store_true",
                    help="route a mixed LM + vision stream via FrontDoor")
    ap.add_argument("--vision-requests", type=int, default=8)
    ap.add_argument("--video-streams", type=int, default=0,
                    help="with --mixed: add N multi-tick video streams "
                         "(delta-gated detection, DESIGN.md §9)")
    ap.add_argument("--vision-replicas", type=int, default=1,
                    help="with --mixed: serve vision from a ReplicaPool "
                         "of N engines behind least-loaded dispatch "
                         "(DESIGN.md §11)")
    ap.add_argument("--lm-tick-cost", type=int, default=1,
                    help="with --mixed: front-door ticks one LM engine "
                         "tick costs — cheap engines tick more often "
                         "(event-driven cadences, DESIGN.md §11)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(dtype=jnp.float32)
    family = get_family(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve driver targets pure-text families; "
                         "multimodal serving needs per-request prefill of "
                         "cross-attention KV (see serving/engine.py notes)")

    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, max_batch=args.max_batch,
                         max_len=args.max_len,
                         prefill_chunk=args.prefill_chunk,
                         tick_cost=args.lm_tick_cost if args.mixed else 1)

    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.max_new_tokens))

    if args.mixed:
        from repro.data import SyntheticVWW

        vis0, vcfg = _make_vision_engine()
        vision = vis0
        if args.vision_replicas > 1:
            from repro.serving import ReplicaPool

            more = [_make_vision_engine()[0]
                    for _ in range(args.vision_replicas - 1)]
            vision = ReplicaPool(vis0, *more)
        frames = SyntheticVWW(image_size=vcfg.image_size,
                              batch=args.vision_requests).batch_at(0)["images"]
        for uid in range(args.vision_requests):
            reqs.append(VisionRequest(uid=1000 + uid, image=frames[uid],
                                      arrival_tick=uid // 2))
        engines = {"lm": engine, "vision": vision}
        if args.video_streams:
            from repro.models.mobilenetv2 import head_out_channels
            from repro.video import (DetectConfig, StreamEngine,
                                     StreamRequest, SyntheticVideo,
                                     init_detect_head)

            vparams, vbn = vis0._params, vis0._bn
            det = init_detect_head(
                jax.random.PRNGKey(2),
                head_out_channels(vcfg),
                DetectConfig())
            engines["stream"] = StreamEngine(vparams, vbn, vcfg, det,
                                             max_streams=2)
            for uid in range(args.video_streams):
                vid = SyntheticVideo(image_size=vcfg.image_size,
                                     n_frames=8, seed=uid)
                reqs.append(StreamRequest(uid=2000 + uid,
                                          frames=vid.frames(),
                                          arrival_tick=uid))
        door = FrontDoor(**engines)
        t0 = time.perf_counter()
        done = door.run(reqs)
        dt = time.perf_counter() - t0
        by = {name: [r for n, r in done if n == name] for name in engines}
        toks = sum(len(r.output) for r in by["lm"])
        print(f"front door: {len(by['lm'])} LM requests ({toks} tokens) + "
              f"{len(by['vision'])} frames + "
              f"{len(by.get('stream', []))} video streams in {dt:.2f}s "
              f"({door.tick} front-door ticks)")
        if "stream" in engines:
            s = engines["stream"].stream_summary()
            print(f"  stream: {s['frames']} frames, "
                  f"stem-skip {s['stem_skip_rate']:.2f}, "
                  f"measured bandwidth reduction "
                  f"{s['measured_reduction_vs_dense']:.2f}x vs dense")
        for name, s in door.latency_summary().items():
            print(f"  {name}: launches={s['launches']} "
                  f"mean_queue={s['mean_queue_ticks']:.2f} ticks "
                  f"mean_launch={s['mean_launch_us'] / 1e3:.1f} ms")
        return

    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4]} → out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
