"""Serving driver: continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 12 --max-batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(dtype=jnp.float32)
    family = get_family(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve driver targets pure-text families; "
                         "multimodal serving needs per-request prefill of "
                         "cross-attention KV (see serving/engine.py notes)")

    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, max_batch=args.max_batch,
                         max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4]} → out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
