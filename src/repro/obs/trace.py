"""Deterministic tick-domain tracing for the serving stack
(DESIGN.md §13.1/§13.3).

A `Tracer` records the causal life of every request — submit → admit →
queue → launch attempt/retry/quarantine → absorb → complete/evict/fault
— plus engine-tick, replica-dispatch, and fault-injection events, all
stamped in **tick-domain time**: the front door's virtual clock when the
engine runs behind a door, the engine's own clock otherwise.  Export is
Chrome/Perfetto trace-event JSON (``chrome://tracing`` /
``ui.perfetto.dev`` load it directly), with 1 trace microsecond ≡ 1
tick.

Two hard contracts, the reason this is a subsystem and not a logger:

* **Bit-for-bit free when disabled.**  ``tracer=None`` (the default
  everywhere) and a constructed-but-disabled ``Tracer(enabled=False)``
  are pinned like `serving.faults.FaultInjector`'s off mode: schedules,
  ledgers, and model outputs are identical to a run with no tracer
  anywhere on the path (``tests/test_obs.py``).  Every hook in the
  serving stack is behind an ``if tracer is not None`` (and the hooks
  themselves no-op when disabled); no hook ever touches schedule state.
* **Deterministic when enabled.**  Same seed + same trace config ⇒
  byte-identical export.  Every stamp is a tick, every arg is schedule
  state (uids, slots, statuses, counts) — never the wall clock.  Wall
  time is observability too, so per-launch wall spans exist behind
  ``wall=True``, an explicit opt-out of the byte-identity contract
  (the bench artifact and the determinism tests keep the default).
  ``export()`` serializes with sorted keys and compact separators.

Track model: ``pid`` is an engine (assigned per-tracer in attach order,
so identical runs get identical pids regardless of process history);
``tid`` 0 is the engine's tick/launch track, ``tid`` 1000+uid is a
request's track.  The span taxonomy and the validator's well-formedness
rules are documented in DESIGN.md §13.1 and enforced by
:func:`validate_trace_events` (which `scripts/bench_gate.py` runs over
the committed smoke artifact).
"""
from __future__ import annotations

import json
from typing import Any

#: Offset separating request tracks from engine-level tracks within a
#: pid: request uid u lives on tid REQUEST_TID_BASE + u.
REQUEST_TID_BASE = 1000

#: Event names the validator treats as terminal for a request's track —
#: at most one per submitted uid per engine.
TERMINAL_EVENTS = ("complete", "evict", "reject", "fail")

#: The full span/instant taxonomy (DESIGN.md §13.1).  The validator
#: rejects events outside it: a trace consumer should never meet an
#: undocumented name.
EVENT_NAMES = frozenset({
    "submit", "admit", "queue", "serve", "complete", "evict", "reject",
    "fail", "engine_tick", "door_tick", "launch", "launch_fault",
    "quarantine", "watchdog", "validate_fail", "halt", "dispatch",
    "inject", "session_turn",
})


class Tracer:
    """Deterministic tick-domain trace recorder; see module docstring.

    One tracer spans one run (a front door and all its engines, or a
    lone engine).  Attach it via the ``tracer=`` constructor knob on
    `SlotEngine` adapters / `FrontDoor` / `ReplicaPool`; the components
    call :meth:`attach` themselves.
    """

    def __init__(self, enabled: bool = True, wall: bool = False):
        self.enabled = enabled
        #: opt-in wall-clock args on launch spans — explicitly outside
        #: the byte-identity contract (DESIGN.md §13.3)
        self.wall = wall
        self.events: list[dict] = []
        self._pids: dict[int, int] = {}  # id(component) -> pid
        self._labels: dict[int, str] = {}  # pid -> label
        self._scales: dict[int, int] = {}  # id(component) -> ticks/tick

    # ----------------------------------------------------------- wiring

    def attach(self, component, label: str | None = None) -> int:
        """Assign (or look up) the pid for a component.  Pids count up
        from 1 in attach order — per tracer, so a fresh tracer over a
        fresh run always yields the same pids."""
        key = id(component)
        if key not in self._pids:
            pid = len(self._pids) + 1
            self._pids[key] = pid
            self._labels[pid] = (label
                                 or type(component).__name__)
        return self._pids[key]

    def label(self, component, label: str) -> None:
        """Re-label a component's track (the front door names engines by
        their registration keys — "lm" beats "ServeEngine")."""
        if not self.enabled:
            return
        pid = self.attach(component)
        self._labels[pid] = label

    # ------------------------------------------------------------ clock

    def set_scale(self, component, ticks_per_tick: int) -> None:
        """Declare the component's tick-domain conversion: one of its
        engine ticks spans ``ticks_per_tick`` front-door ticks.  The
        event-driven `FrontDoor` sets this to each engine's
        ``tick_cost`` at construction — engine tick ``e`` fired at door
        tick ``e × cost`` on the event heap (DESIGN.md §11), so scaling
        every stamp and duration by the cost lands all tracks on the
        door's shared virtual clock.  Standalone engines keep the
        default scale 1 (their own clock is the trace clock)."""
        self._scales[id(component)] = int(ticks_per_tick)

    def scale(self, component) -> int:
        return self._scales.get(id(component), 1)

    # ------------------------------------------------------- recording

    def tick_instant(self, component, name: str, tick: int, tid: int = 0,
                     **args: Any) -> None:
        """An instant ("i") event at engine-domain ``tick`` (converted
        onto the trace clock by the component's scale)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "i", "s": "t",
            "pid": self.attach(component), "tid": int(tid),
            "ts": int(tick) * self.scale(component), "args": args,
        })

    def tick_span(self, component, name: str, start_tick: int,
                  dur_ticks: int, tid: int = 0, **args: Any) -> None:
        """A complete ("X") span of ``dur_ticks`` engine ticks starting
        at engine-domain ``start_tick`` (both converted by scale)."""
        if not self.enabled:
            return
        k = self.scale(component)
        self.events.append({
            "name": name, "ph": "X",
            "pid": self.attach(component), "tid": int(tid),
            "ts": int(start_tick) * k, "dur": int(dur_ticks) * k,
            "args": args,
        })

    @staticmethod
    def req_tid(req) -> int:
        return REQUEST_TID_BASE + int(getattr(req, "uid", 0))

    # --------------------------------------------------------- export

    def trace_events(self) -> list[dict]:
        """The recorded events plus the metadata events naming each pid
        track (Perfetto reads ``process_name``)."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": label}}
            for pid, label in sorted(self._labels.items())
        ]
        return meta + self.events

    def export(self, path=None) -> str:
        """Chrome/Perfetto trace-event JSON; deterministic byte-for-byte
        under the §13.3 contract (sorted keys, compact separators, no
        wall stamps unless ``wall=True`` was requested)."""
        payload = {
            "displayTimeUnit": "ms",
            "otherData": {"clock": "ticks", "schema": 1},
            "traceEvents": self.trace_events(),
        }
        text = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        if path is not None:
            from pathlib import Path

            Path(path).write_text(text + "\n")
        return text


def validate_trace_events(payload: dict | list) -> list[str]:
    """Schema validation for an exported trace (DESIGN.md §13.1);
    returns a list of problems (empty ⇒ valid).  Enforced:

    * **well-formed spans** — every event carries name/ph/pid/tid/ts
      with the right types, "X" spans a non-negative integer ``dur``,
      names stay inside the documented taxonomy;
    * **no orphaned spans** — a terminal request event (complete /
      evict / reject / fail) on a track that never saw ``submit`` is an
      orphan, and a second terminal event on one track is a double
      completion;
    * **monotone tick stamps** — within each (pid, tid) track, ``ts``
      never decreases in recorded order (the tick domain only moves
      forward).
    """
    if isinstance(payload, dict):
        events = payload.get("traceEvents")
        if not isinstance(events, list):
            return ["payload has no traceEvents list"]
    else:
        events = payload
    problems: list[str] = []
    last_ts: dict[tuple, int] = {}
    submitted: dict[tuple, bool] = {}
    terminal: dict[tuple, str] = {}
    for k, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {k}: not an object")
            continue
        ph = ev.get("ph")
        if ph == "M":
            continue  # metadata (track names)
        name = ev.get("name")
        if ph not in ("i", "X"):
            problems.append(f"event {k} ({name}): unknown ph {ph!r}")
            continue
        if name not in EVENT_NAMES:
            problems.append(f"event {k}: name {name!r} outside the "
                            "documented taxonomy")
        bad = [f for f in ("pid", "tid", "ts")
               if not isinstance(ev.get(f), int)]
        if ph == "X" and not (isinstance(ev.get("dur"), int)
                              and ev["dur"] >= 0):
            bad.append("dur")
        if bad:
            problems.append(f"event {k} ({name}): malformed fields {bad}")
            continue
        track = (ev["pid"], ev["tid"])
        if track in last_ts and ev["ts"] < last_ts[track]:
            problems.append(
                f"event {k} ({name}): ts {ev['ts']} < previous "
                f"{last_ts[track]} on track {track} — tick stamps must "
                "be monotone")
        last_ts[track] = ev["ts"]
        if ev["tid"] >= REQUEST_TID_BASE:
            if name == "submit":
                submitted[track] = True
            elif name in TERMINAL_EVENTS:
                if track not in submitted:
                    problems.append(
                        f"event {k}: terminal {name!r} on track {track} "
                        "with no submit — orphaned span")
                if track in terminal:
                    problems.append(
                        f"event {k}: second terminal {name!r} on track "
                        f"{track} (already {terminal[track]!r})")
                terminal[track] = name
    return problems
