"""Observability subsystem: deterministic tick-domain tracing, the
unified metrics registry, and structured logging (DESIGN.md §13).

This layer never imports the serving stack it instruments — components
take a ``tracer=`` knob and publish views into the registry, so the
dependency arrow points serving → obs only.
"""
from repro.obs.log import SCHEMA_VERSION, format_record, structured
from repro.obs.metrics import (
    Counter,
    Gauge,
    MetricsRegistry,
    TickHistogram,
    counted_lru_cache,
    default_registry,
    tick_percentiles,
)
from repro.obs.trace import (
    EVENT_NAMES,
    REQUEST_TID_BASE,
    TERMINAL_EVENTS,
    Tracer,
    validate_trace_events,
)

__all__ = [
    "SCHEMA_VERSION",
    "format_record",
    "structured",
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "TickHistogram",
    "counted_lru_cache",
    "default_registry",
    "tick_percentiles",
    "EVENT_NAMES",
    "REQUEST_TID_BASE",
    "TERMINAL_EVENTS",
    "Tracer",
    "validate_trace_events",
]
