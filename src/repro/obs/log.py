"""Structured logging: one machine-parseable record schema for the
stack's operational notices (DESIGN.md §13.4).

Every notice the stack emits outside the trace/metric surfaces — the
autotuner's "disabled, serving defaults" info line, the bench gate's
cross-backend skip warnings — goes through :func:`structured` instead of
a bare ``logging``/``warnings``/``print`` call, so an operator (or a CI
log scraper) parses one schema instead of N ad-hoc formats:

    {"event": "<dotted.event.name>", "schema": 1, **fields}

The record is serialized with ``sort_keys`` and compact separators, so
identical records are byte-identical strings — the same determinism
contract the tracer export holds (§13.3).  ``structured`` also counts
each event name into the metrics registry (``log.<event>``), so the
registry snapshot shows *that* a notice fired even when the log stream
was discarded.

No timestamps: a structured record is stamped by its position in the
log stream (and, for tick-domain events, by the ``tick`` field the
caller supplies), never by the wall clock — wall stamps would break the
byte-identity contract and add nothing a log collector doesn't already
attach.
"""
from __future__ import annotations

import json
import logging
from typing import Any

#: Schema version embedded in every record; bump on breaking changes to
#: the field contract so parsers can dispatch.
SCHEMA_VERSION = 1


def format_record(event: str, **fields: Any) -> str:
    """The canonical serialized form of one structured record —
    deterministic: sorted keys, compact separators, no wall stamps."""
    record = {"event": event, "schema": SCHEMA_VERSION, **fields}
    return json.dumps(record, sort_keys=True, separators=(",", ":"),
                      default=str)


def structured(logger: logging.Logger, event: str,
               level: int = logging.INFO, **fields: Any) -> str:
    """Emit one structured record through ``logger`` and count it into
    the metrics registry; returns the serialized record (callers that
    also need a human-facing line print it themselves)."""
    line = format_record(event, **fields)
    logger.log(level, line)
    from repro.obs.metrics import default_registry

    default_registry().counter(f"log.{event}").inc()
    return line
