"""Unified metrics registry for the P²M serving stack (DESIGN.md §13.2).

One process-wide (or test-local) `MetricsRegistry` replaces the stack's
fragmented one-off summary dicts as the queryable surface: engines,
pools, the front door, the fault injectors, the delta-gate ledgers, the
autotuner, and the compile caches all publish into it, and
``registry.snapshot()`` returns everything at once.  The legacy dict
APIs (`SlotEngine.latency_summary`, `FrontDoor.health`,
`StreamEngine.stream_summary`, `FaultInjector.summary`, …) stay — they
are the per-component *views* the registry aggregates, so existing
callers and tests read the same numbers through either surface
(pinned by ``tests/test_obs.py``).

Three instrument kinds, all deterministic state:

* **Counter** — monotone float/int accumulator (``inc``).  Used for
  compile-cache hits/misses, autotuner decisions, structured-log event
  counts, injected-fault tallies.
* **Gauge** — last-set value (``set``).  Used for instantaneous load
  signals published at snapshot time.
* **TickHistogram** — append-only series of tick-denominated
  observations with the same (p50, p95, p99) estimator the serving
  ledgers use (`serving.scheduler.tick_percentiles`), so a percentile
  read from the registry equals the one in the legacy summary.

Component views are registered with ``register_view(scope, name, fn)``
where ``fn`` is a zero-arg callable (typically a bound method like
``engine.latency_summary``).  Views hold the component via **weakref**:
a dead engine silently drops out of the snapshot instead of being kept
alive by the registry — a process-wide registry must not leak every
engine ever constructed.

Scopes are deterministic per process: ``scope_for(obj)`` assigns
``<classname>#<k>`` with ``k`` counting instances of that class in
registration order.  (Trace ``pid`` labels are assigned per-*tracer*,
not from these process-global scopes, so two identical runs in one
process still export byte-identical traces — DESIGN.md §13.3.)
"""
from __future__ import annotations

import weakref
from typing import Any, Callable, Sequence

import numpy as np


def tick_percentiles(values: Sequence[float]) -> tuple[float, float, float]:
    """(p50, p95, p99) of a tick series; zeros when empty.  The same
    linear-interpolation estimator as
    `repro.serving.scheduler.tick_percentiles` — defined here (the
    serving module re-exports compatibly) so the obs layer never imports
    the serving layer it instruments."""
    if not values:
        return 0.0, 0.0, 0.0
    arr = np.asarray(values, np.float64)
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 95)),
            float(np.percentile(arr, 99)))


class Counter:
    """Monotone accumulator."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments are non-negative, got {n}")
        self.value += n


class Gauge:
    """Last-set value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class TickHistogram:
    """Append-only tick-denominated series; percentile reads share the
    serving stack's estimator so registry and ledger numbers agree."""

    __slots__ = ("values",)

    def __init__(self) -> None:
        self.values: list[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def percentiles(self) -> tuple[float, float, float]:
        return tick_percentiles(self.values)

    def summary(self) -> dict:
        p50, p95, p99 = self.percentiles()
        n = len(self.values)
        return {"count": n,
                "sum": float(sum(self.values)),
                "mean": (sum(self.values) / n) if n else 0.0,
                "p50": p50, "p95": p95, "p99": p99}


class MetricsRegistry:
    """Process-wide metric surface; see module docstring."""

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, TickHistogram] = {}
        # scope -> view name -> weakly-bound callable
        self._views: dict[str, dict[str, Callable[[], Any]]] = {}
        self._scope_counts: dict[str, int] = {}

    # ------------------------------------------------------ instruments

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def tick_histogram(self, name: str) -> TickHistogram:
        return self._hists.setdefault(name, TickHistogram())

    # ------------------------------------------------------------ views

    def scope_for(self, obj: object) -> str:
        """Deterministic per-process scope name for one component:
        ``<classname>#<k>`` in registration order."""
        cls = type(obj).__name__
        k = self._scope_counts.get(cls, 0)
        self._scope_counts[cls] = k + 1
        return f"{cls}#{k}"

    def register_view(self, scope: str, name: str, method) -> None:
        """Register a component view: ``method`` is a *bound method*
        (``engine.latency_summary``); only a weakref to its receiver is
        held, so registration never extends the component's life."""
        ref = weakref.ref(method.__self__)
        func = method.__func__

        def call():
            obj = ref()
            return None if obj is None else func(obj)

        self._views.setdefault(scope, {})[name] = call

    def register_component(self, obj: object,
                           views: dict[str, Any] | None = None,
                           scope: str | None = None) -> str:
        """Register a component's named views in one call; returns the
        scope assigned.  ``views`` maps view name → bound method."""
        scope = scope or self.scope_for(obj)
        for name, method in (views or {}).items():
            self.register_view(scope, name, method)
        return scope

    # --------------------------------------------------------- snapshot

    def snapshot(self) -> dict:
        """Everything at once: instrument values plus every live
        component view (dead components drop out silently)."""
        out: dict = {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "tick_histograms": {k: h.summary()
                                for k, h in sorted(self._hists.items())},
        }
        comps: dict = {}
        for scope, views in sorted(self._views.items()):
            live = {}
            for name, call in sorted(views.items()):
                val = call()
                if val is not None:
                    live[name] = val
            if live:
                comps[scope] = live
        out["components"] = comps
        return out

    def reset(self) -> None:
        """Drop every instrument and view (test isolation)."""
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
        self._views.clear()
        self._scope_counts.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry every component publishes into unless
    handed an explicit one (tests pass their own for isolation)."""
    return _DEFAULT


def counted_lru_cache(name: str, maxsize: int | None = None):
    """``functools.lru_cache`` with registry-visible hit/miss counters.

    Drop-in replacement for ``@functools.lru_cache(maxsize=None)`` on
    the serving stack's compile caches (`_decode_step_for`,
    `_chunk_step_for`, `_deploy_forward_for`, `_stream_forward_for`):
    every call increments ``compile_cache.<name>.hits`` or
    ``compile_cache.<name>.misses`` in the default registry, so the
    snapshot shows whether engines are actually sharing compilations
    (a re-jit-per-engine regression shows up as a flat hit count —
    exactly the bug class PR 3 fixed, now permanently metered).

    ``cache_info``/``cache_clear`` pass through, so callers and tests
    that poke the cache keep working unchanged.
    """
    import functools

    def deco(fn):
        cached = functools.lru_cache(maxsize=maxsize)(fn)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            before = cached.cache_info()
            out = cached(*args, **kwargs)
            after = cached.cache_info()
            # counters re-fetched per call so a registry reset() (test
            # isolation) never leaves the cache feeding orphans
            reg = default_registry()
            reg.counter(f"compile_cache.{name}.hits").inc(
                after.hits - before.hits)
            reg.counter(f"compile_cache.{name}.misses").inc(
                after.misses - before.misses)
            return out

        wrapper.cache_info = cached.cache_info
        wrapper.cache_clear = cached.cache_clear
        wrapper.__wrapped__ = fn
        return wrapper

    return deco
