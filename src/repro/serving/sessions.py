"""Stateful streaming-LM sessions: long-lived conversations in slots.

The LM twin of a video stream (DESIGN.md §9 → §12.4): a
``SessionRequest`` carries a *sequence of turns* and occupies its slot
for the whole conversation — the recurrent (token-shift, WKV) state
stays device-resident in the slot's batch row across every tick of
every turn, so turn t+1 continues from the state turn t left behind
instead of re-prefilling the conversation history.  This is only sound
for positionless O(1)-state recurrent families (rwkv): a KV-cache
family would need per-session position tracking and an O(history)
cache; the constructor rejects anything without a family ``prefill``
hook.

Scheduling semantics come free from the `SlotEngine` core: sessions
queue, admit, evict, deadline-shed, watchdog-recycle and quarantine
exactly like any other request (DESIGN.md §10–§11), and the
event-driven `FrontDoor` routes them by the engine's declared
``request_type`` — a new modality plugs in without touching the router.
Slot recycling inherits `ServeEngine._reset_slot`'s zero-fill, so a
recycled slot never sees a previous conversation's state (pinned by the
leak property test in `tests/test_sessions.py`).

Per-turn flow: turn t's prompt prefills through the shared chunked step
(the fused WKV path), generation appends to ``outputs[t]`` one token
per tick until ``eos`` / ``max_new_tokens``, then the next turn's
prompt starts prefilling *without touching the state*.  The final
generated token of a turn is recorded but never fed back — the next
thing the model sees is the next user turn (a user interrupting with a
new message), matching the front-door event model.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig
from repro.models.families import get_family
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import ScheduledRequest


@dataclasses.dataclass
class SessionRequest(ScheduledRequest):
    """One conversation: ``turns[t]`` is turn t's prompt tokens;
    generation for turn t lands in ``outputs[t]``."""
    uid: int
    turns: list[list[int]]
    max_new_tokens: int = 16
    outputs: list[list[int]] = dataclasses.field(default_factory=list)
    turn: int = 0
    done: bool = False


class SessionEngine(ServeEngine):
    """Multi-turn streaming-LM engine over the `ServeEngine` adapter.

    Accepts every `ServeEngine` knob (``mesh`` shards session state over
    the data axis, resident across ticks; ``prefill_chunk`` routes turn
    prompts through the fused chunked-WKV prefill; ``core`` kwargs reach
    the scheduler's fault-tolerance layer)."""

    request_type = SessionRequest

    def __init__(self, params, cfg: ModelConfig, **kw):
        if get_family(cfg).prefill is None:
            raise ValueError(
                f"stateful sessions need a positionless recurrent family "
                f"with a fused prefill hook (rwkv); {cfg.family!r} decodes "
                f"against a positional KV cache whose per-session history "
                f"a recycled slot cannot carry")
        super().__init__(params, cfg, **kw)

    # ------------------------------------------------- adapter hooks

    def _prompt(self, req: SessionRequest) -> list[int]:
        return req.turns[req.turn]

    def _gen(self, req: SessionRequest) -> list[int]:
        return req.outputs[req.turn]

    def _on_admit(self, i: int, req: SessionRequest) -> None:
        super()._on_admit(i, req)  # zero state + cursors: fresh session
        req.turn = 0
        req.outputs = [[]]

    def _absorb(self, i: int, req: SessionRequest, result) -> bool:
        nxt, adv = result
        n = int(adv[i])
        self._slot_pos[i] += n
        cur = int(self._slot_cursor[i])
        prompt = self._prompt(req)
        if cur < len(prompt):
            self._slot_cursor[i] = cur + n
            if cur + n < len(prompt):
                return False  # still prefilling this turn's prompt
        tok = int(nxt[i])
        out = self._gen(req)
        out.append(tok)
        if self._slot_pos[i] >= self.max_len - 1:
            req.done = True  # hard length stop ends the whole session
            return True
        if not ((self.eos_id is not None and tok == self.eos_id)
                or len(out) >= req.max_new_tokens):
            return False  # keep generating this turn
        if req.turn + 1 >= len(req.turns):
            req.done = True
            return True  # conversation over — slot recyclable
        # Next turn: new prompt cursor, SAME recurrent state — the whole
        # point of the session slot.
        req.turn += 1
        req.outputs.append([])
        self._slot_cursor[i] = 0
        if self.tracer is not None:
            self.tracer.tick_instant(self, "session_turn", self.tick, 0,
                                     uid=req.uid, turn=req.turn, slot=i)
        return False
