"""Batched vision serving: microbatched single-shot inference through
the deploy-folded P²M stem + MobileNetV2 backbone (DESIGN.md §7).

The LM engine (`engine.py`) keeps a request in its slot for many decode
ticks; the vision workload is single-shot, so a slot here is a position
in a fixed-shape microbatch that a request occupies for exactly one tick.
Everything else mirrors ``ServeEngine``: requests queue on arrival, each
tick admits up to ``max_batch`` of them (free slots carry a zero image
and their logits are discarded, keeping the jitted computation
shape-stable), one compiled forward serves the whole batch, and the
completed list preserves submission order.

The forward is the *deployed* model: for the P²M variant the stem runs
with BN folded into the pixel weights and (optionally) PTQ-quantized —
i.e. what the manufactured sensor + SoC would execute, served through
the fused implicit-im2col conv path (`core.p2m_conv._resolve_impl`).

Latency accounting is per request: ticks spent queued, the serving
tick, and the wall-clock of the launch that served it — enough to read
queueing delay and batch amortization separately.  The bounded queue
evicts the *oldest* waiting request on overflow (the always-on-sensor
policy: stale frames are worthless; fresh ones are not).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.p2m_vww import (
    SERVE_MAX_BATCH,
    SERVE_MAX_QUEUE,
    SERVE_QUANT_BITS,
)
from repro.core.bn_fold import deploy_params
from repro.core.pixel_model import PixelModel
from repro.core.quant import QuantSpec, quantize_deploy
from repro.models.mobilenetv2 import MNV2Config, apply_mnv2


@dataclasses.dataclass
class VisionRequest:
    uid: int
    image: np.ndarray  # (H, W, 3) float32 in [0, 1]
    arrival_tick: int = 0  # earliest engine tick this request exists

    # Filled by the engine:
    label: int | None = None
    probs: np.ndarray | None = None
    submitted_tick: int = -1
    served_tick: int = -1
    batch_wall_us: float = 0.0  # wall-clock of the launch that served it
    evicted: bool = False

    @property
    def queue_ticks(self) -> int:
        """Ticks spent waiting in the queue before being served."""
        return self.served_tick - self.submitted_tick


class VisionEngine:
    def __init__(self, params, bn_state, cfg: MNV2Config, *,
                 pixel_model: PixelModel | None = None,
                 max_batch: int = SERVE_MAX_BATCH,
                 max_queue: int = SERVE_MAX_QUEUE,
                 deploy_quant_bits: int | None = SERVE_QUANT_BITS):
        """``deploy_quant_bits``: PTQ bit-width for the folded P²M stem
        (None ⇒ fold only, no quantization; ignored for the baseline
        variant, which has no in-pixel layer to fold)."""
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_queue = max_queue
        self.tick = 0

        dep = None
        if cfg.variant == "p2m":
            dep = deploy_params(params["stem"], bn_state["stem"], cfg.p2m)
            if deploy_quant_bits is not None:
                dep = quantize_deploy(
                    dep, QuantSpec(deploy_quant_bits, deploy_quant_bits))
        self._deploy = dep

        def forward(images):
            logits, _ = apply_mnv2(params, bn_state, images, cfg,
                                   pixel_model, train=False, p2m_deploy=dep)
            return jax.nn.softmax(logits, axis=-1)

        self._fwd = jax.jit(forward)
        self.queue: list[VisionRequest] = []
        self.completed: list[VisionRequest] = []
        self.evicted: list[VisionRequest] = []
        self.stats = {"launches": 0, "served": 0, "evictions": 0,
                      "slot_ticks": 0, "wall_us": 0.0}

    # ------------------------------------------------------------- API

    def submit(self, req: VisionRequest) -> None:
        """Enqueue now.  ``arrival_tick`` is traffic-replay metadata that
        only ``run`` consults to delay submission; calling ``submit``
        directly means the request exists as of the current tick."""
        req.submitted_tick = self.tick
        if len(self.queue) >= self.max_queue:
            victim = self.queue.pop(0)  # oldest-drop (freshness policy)
            victim.evicted = True
            self.evicted.append(victim)
            self.stats["evictions"] += 1
        self.queue.append(req)

    def step(self) -> list[VisionRequest]:
        """One engine tick: serve up to ``max_batch`` queued requests with
        a single compiled launch.  Returns the requests served this tick
        (empty when the queue was idle — the tick still advances, so
        arrival-driven ``run`` loops make progress)."""
        self.tick += 1
        batch = self.queue[: self.max_batch]
        self.queue = self.queue[len(batch):]
        if not batch:
            return []

        h = w = self.cfg.image_size
        images = np.zeros((self.max_batch, h, w, 3), np.float32)
        for slot, req in enumerate(batch):
            images[slot] = req.image

        t0 = time.perf_counter()
        probs = np.asarray(
            jax.block_until_ready(self._fwd(jnp.asarray(images))))
        wall_us = (time.perf_counter() - t0) * 1e6

        for slot, req in enumerate(batch):
            req.probs = probs[slot]
            req.label = int(probs[slot].argmax())
            req.served_tick = self.tick
            req.batch_wall_us = wall_us
            self.completed.append(req)

        self.stats["launches"] += 1
        self.stats["served"] += len(batch)
        self.stats["slot_ticks"] += self.max_batch
        self.stats["wall_us"] += wall_us
        return batch

    def run(self, requests: Sequence[VisionRequest] | None = None,
            max_ticks: int = 10_000) -> list[VisionRequest]:
        """Drive the engine until all traffic drains.  ``requests`` with
        ``arrival_tick`` in the future are submitted when the engine
        clock reaches them (variable-arrival traffic replay)."""
        pending = sorted(requests or [], key=lambda r: r.arrival_tick)
        ticks = 0
        while (pending or self.queue) and ticks < max_ticks:
            while pending and pending[0].arrival_tick <= self.tick:
                self.submit(pending.pop(0))
            self.step()
            ticks += 1
        return self.completed

    def latency_summary(self) -> dict:
        """Aggregate counters: slot utilization (served / slot-ticks over
        non-idle launches), mean queueing delay in ticks, mean per-launch
        wall-clock, eviction count."""
        served = self.stats["served"]
        return {
            "served": served,
            "launches": self.stats["launches"],
            "evictions": self.stats["evictions"],
            "utilization": (served / self.stats["slot_ticks"]
                            if self.stats["slot_ticks"] else 0.0),
            "mean_queue_ticks": (
                sum(r.queue_ticks for r in self.completed) / served
                if served else 0.0),
            "mean_launch_us": (self.stats["wall_us"] / self.stats["launches"]
                               if self.stats["launches"] else 0.0),
        }
