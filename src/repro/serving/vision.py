"""Batched vision serving: microbatched single-shot inference through
the deploy-folded P²M stem + MobileNetV2 backbone (DESIGN.md §7–§8).

``VisionEngine`` is a thin adapter over the shared scheduler core
(`serving/scheduler.py`): the LM engine keeps a request in its slot for
many decode ticks; the vision workload is single-shot, so a slot here is
a position in a fixed-shape microbatch that a request occupies for
exactly one tick — ``_absorb`` always reports "finished" and the core
recycles every slot every tick.  Free slots carry a zero image and their
logits are discarded, keeping the jitted computation shape-stable.

The forward is the *deployed* model: for the P²M variant the stem runs
with BN folded into the pixel weights and (optionally) PTQ-quantized —
i.e. what the manufactured sensor + SoC would execute, served through
the fused implicit-im2col conv path (`core.p2m_conv._resolve_impl`).

Scale-out (``mesh=``): pass a data mesh and the padded microbatch is
split across devices under the pure-DP vision plan (DESIGN.md §7.1 —
`vision_plan_for`; params/BN/deploy trees replicate, the image batch
dim shards, the probs come back replicated).  The adapter is otherwise
identical, so every queue/eviction/latency test holds sharded as-is.

The bounded queue evicts the *oldest* waiting request on overflow (the
always-on-sensor policy: stale frames are worthless; fresh ones are
not).  Per-request latency accounting comes from the core: ticks spent
queued, the serving tick, and the wall-clock of the launch that served
it — enough to read queueing delay and batch amortization separately.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.p2m_vww import (
    SERVE_MAX_BATCH,
    SERVE_MAX_QUEUE,
    SERVE_QUANT_BITS,
)
from repro.core.bn_fold import deploy_params
from repro.core.pixel_model import PixelModel
from repro.core.quant import QuantSpec, quantize_deploy
from repro.models.mobilenetv2 import MNV2Config, apply_mnv2
from repro.obs.metrics import counted_lru_cache
from repro.parallel import vision_plan_for
from repro.parallel.sharding_utils import batch_shardings
from repro.serving.scheduler import ScheduledRequest, SlotEngine


@dataclasses.dataclass
class VisionRequest(ScheduledRequest):
    uid: int
    image: np.ndarray  # (H, W, 3) float32 in [0, 1]

    # Filled by the engine:
    label: int | None = None
    probs: np.ndarray | None = None

    @property
    def batch_wall_us(self) -> float:
        """Wall-clock of the (single) launch that served this request."""
        return self.launch_wall_us


def _make_forward(cfg: MNV2Config, pixel_model: PixelModel | None,
                  impl: str | None = None):
    def forward(params, bn, dep, images):
        logits, _ = apply_mnv2(params, bn, images, cfg, pixel_model,
                               train=False, p2m_deploy=dep, p2m_impl=impl)
        return jax.nn.softmax(logits, axis=-1)

    return forward


def _jit_forward(forward, cfg: MNV2Config, mesh: Mesh | None,
                 batch: int | None):
    """Jit the deploy forward, optionally under the data mesh: the
    microbatch is split over the data axes of the pure-DP vision plan
    (DESIGN.md §7.1) while the small param/BN/deploy trees replicate;
    probabilities return replicated so the host-side slot bookkeeping
    never changes."""
    if mesh is None:
        return jax.jit(forward)
    plan = vision_plan_for(mesh)
    h = w = cfg.image_size
    img = batch_shardings(
        jax.ShapeDtypeStruct((batch, h, w, 3), jnp.float32), plan)
    rep = NamedSharding(mesh, P())
    return jax.jit(forward, in_shardings=(rep, rep, rep, img),
                   out_shardings=rep)


@counted_lru_cache("deploy_forward")
def _deploy_forward_for(cfg: MNV2Config, mesh: Mesh | None = None,
                        batch: int | None = None, impl: str | None = None):
    """Deploy-mode forward, jitted once per (config, mesh, conv impl) —
    params, BN state and the folded deploy tree ride as traced arguments
    so every engine on this config shares one compilation (metered:
    ``compile_cache.deploy_forward.*`` in the metrics registry).
    ``impl`` selects the stem conv path; the fault-degradation ladder
    requests ``"patches"`` (the reference conv) after repeated kernel
    faults."""
    return _jit_forward(_make_forward(cfg, None, impl), cfg, mesh, batch)


class VisionEngine(SlotEngine):
    request_type = VisionRequest

    def __init__(self, params, bn_state, cfg: MNV2Config, *,
                 pixel_model: PixelModel | None = None,
                 max_batch: int = SERVE_MAX_BATCH,
                 max_queue: int = SERVE_MAX_QUEUE,
                 deploy_quant_bits: int | None = SERVE_QUANT_BITS,
                 mesh: Mesh | None = None,
                 evict: str = "drop-oldest",
                 degrade_after: int = 3, **core):
        """``deploy_quant_bits``: PTQ bit-width for the folded P²M stem
        (None ⇒ fold only, no quantization; ignored for the baseline
        variant, which has no in-pixel layer to fold).  ``mesh``: shard
        the microbatch over the mesh's data axes (None ⇒ single device).
        ``degrade_after``: launch-fault count after which the engine
        falls back from the fused conv to the patches reference path
        (DESIGN.md §10); ``core`` forwards the scheduler's
        fault-tolerance knobs and the front door's ``tick_cost``
        cadence declaration (a one-tick microbatch is cheaper than an
        LM launch and dearer than a stream frame, DESIGN.md §11) to
        `SlotEngine`.  Pool several engines (one per submesh of
        `launch.mesh.make_submeshes`) behind a
        `serving.pool.ReplicaPool` for replica-parallel serving.
        """
        super().__init__(max_batch, max_queue=max_queue, evict=evict, **core)
        self.cfg = cfg
        self.mesh = mesh
        self.degrade_after = degrade_after
        self._kernel_faults = 0
        self._params = params
        self._bn = bn_state
        self._pixel_model = pixel_model

        dep = None
        if cfg.variant == "p2m":
            dep = deploy_params(params["stem"], bn_state["stem"], cfg.p2m)
            if deploy_quant_bits is not None:
                dep = quantize_deploy(
                    dep, QuantSpec(deploy_quant_bits, deploy_quant_bits))
        self._deploy = dep

        if pixel_model is None:
            self._fwd = _deploy_forward_for(cfg, mesh, max_batch)
        else:  # PixelModel trees aren't hashable — private compilation,
            # but the mesh (if any) still applies
            self._fwd = _jit_forward(_make_forward(cfg, pixel_model),
                                     cfg, mesh, max_batch)

    # ------------------------------------------------- adapter hooks

    def _on_launch_fault(self, exc: Exception) -> None:
        """Degradation ladder, rung 1 (DESIGN.md §10): after
        ``degrade_after`` launch faults, swap the fused-conv forward for
        the patches reference path — the kernel that keeps failing stops
        being on the serving path, and the engine keeps answering."""
        self._kernel_faults += 1
        if self.degraded is None and self._kernel_faults >= self.degrade_after:
            self._degrade_to_patches()

    def _degrade_to_patches(self) -> None:
        self.degraded = "patches"
        if self._pixel_model is None:
            self._fwd = _deploy_forward_for(self.cfg, self.mesh,
                                            self.n_slots, "patches")
        else:
            self._fwd = _jit_forward(
                _make_forward(self.cfg, self._pixel_model, "patches"),
                self.cfg, self.mesh, self.n_slots)

    def _launch(self, active):
        h = w = self.cfg.image_size
        images = np.zeros((self.n_slots, h, w, 3), np.float32)
        for i, req in active:
            images[i] = req.image
        probs = self._fwd(self._params, self._bn, self._deploy,
                          jnp.asarray(images))
        return np.asarray(jax.block_until_ready(probs))

    def _absorb(self, i, req: VisionRequest, probs) -> bool:
        req.probs = probs[i]
        req.label = int(probs[i].argmax())
        return True  # a vision slot lives exactly one tick
