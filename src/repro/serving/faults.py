"""Deterministic, seeded fault injection for the serving stack
(DESIGN.md §10).

Process variation makes corrupted analog activations a real input class
the digital stack must survive (tri-design, arXiv:2304.02968), and an
always-on sensor pipeline has to keep serving through kernel raises and
stuck streams (Neuromorphic-P2M, arXiv:2301.09111 frames the workload).
`FaultInjector` manufactures those conditions on demand, reproducibly:

  launch raises   ``_launch`` throws `InjectedLaunchError` naming the
                  victim slot — exercises retry → quarantine containment
  NaN outputs     one slot's rows of the launch result are corrupted to
                  NaN (float) / -1 (int) — exercises the NaN/Inf guard
  slow launches   a ``time.sleep`` before the launch — exercises the
                  latency ledger's tail, never the schedule
  stuck slots     a request that never absorbs, holding its slot until
                  the ``max_serve_ticks`` watchdog evicts it

Every decision is a pure function of ``(seed, fault kind, engine tick /
request uid, attempt)`` via per-decision `np.random.SeedSequence` draws:
no global RNG state, no draw-order coupling — the same plan over the
same traffic replays the same faults, and a rate of 0 for a kind means
that kind draws nothing.  A plan that injects nothing is **bit-for-bit
free**: the wrapped engine's schedule, outputs, and tick ledgers are
identical to running without the injector (pinned by
`tests/test_faults.py`).

Plug into any `SlotEngine` adapter via the ``faults=`` constructor
argument; the core calls ``pre_launch`` / ``post_launch`` around each
launch attempt and ``holds`` before absorbing each slot.  Targeted
deterministic chaos (for tests) uses the explicit ``launch_error_ticks``
/ ``nan_ticks`` / ``stuck_uids`` plan fields instead of rates.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

# Fault-kind salts for the per-decision seed streams: each (kind, key)
# pair owns an independent stream, so toggling one rate never shifts
# another kind's decisions.
_LAUNCH, _SLOW, _NAN, _STUCK, _VICTIM = range(5)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Injection plan: per-kind rates in [0, 1] plus explicit targets.

    Rates draw once per (tick, attempt) for launch/slow faults, once per
    tick for NaN corruption, and once per request uid for stuck slots
    (a stuck request is stuck for life — the decision never flips).
    ``launch_error_ticks`` / ``nan_ticks`` / ``stuck_uids`` force the
    fault regardless of rate — deterministic chaos for tests."""

    launch_error_rate: float = 0.0
    nan_rate: float = 0.0
    slow_rate: float = 0.0
    stuck_rate: float = 0.0
    slow_s: float = 1e-4  # sleep per slow fault (latency tail, not schedule)
    launch_error_ticks: tuple[int, ...] = ()
    nan_ticks: tuple[int, ...] = ()
    stuck_uids: tuple[int, ...] = ()
    seed: int = 0

    @property
    def enabled(self) -> bool:
        return bool(self.launch_error_rate or self.nan_rate
                    or self.slow_rate or self.stuck_rate
                    or self.launch_error_ticks or self.nan_ticks
                    or self.stuck_uids)


#: The chaos-bench smoke plan (`benchmarks/bench_serve_chaos.py`): every
#: fault kind present at rates low enough that most traffic completes —
#: the bench gate holds the completion floors against this exact plan.
SMOKE_PLAN = FaultPlan(launch_error_rate=0.05, nan_rate=0.05,
                       slow_rate=0.1, stuck_rate=0.08, seed=0)


class InjectedLaunchError(RuntimeError):
    """A manufactured ``_launch`` failure.  Carries the victim ``slot``
    so containment can quarantine exactly the poisoned request — the
    shape real per-slot kernel faults (a poisoned operand, a corrupted
    stream state) would take."""

    def __init__(self, slot: int, tick: int):
        super().__init__(f"injected launch fault (slot {slot}, tick {tick})")
        self.slot = slot
        self.tick = tick


def _corrupt_slot_row(result, slot: int, n_slots: int):
    """Copy-on-write corruption of one slot's rows across the result
    tree: NaN into float arrays, -1 into int arrays (sampled tokens are
    non-negative, so -1 is the integer analogue of NaN).  Arrays without
    a leading slot axis pass through untouched."""
    if isinstance(result, tuple):
        return tuple(_corrupt_slot_row(x, slot, n_slots) for x in result)
    if isinstance(result, list):
        return [_corrupt_slot_row(x, slot, n_slots) for x in result]
    if isinstance(result, dict):
        return {k: _corrupt_slot_row(v, slot, n_slots)
                for k, v in result.items()}
    if getattr(result, "ndim", 0) >= 1 and result.shape[0] == n_slots:
        arr = np.array(result, copy=True)
        if np.issubdtype(arr.dtype, np.floating):
            arr[slot] = np.nan
            return arr
        if np.issubdtype(arr.dtype, np.integer):
            arr[slot] = -1
            return arr
    return result


class FaultInjector:
    """Seeded chaos source for one engine; see module docstring.

    ``counts`` tallies injected faults per kind; ``poisoned_uids`` is
    every request uid an injection targeted (launch victims that later
    survive a retry stay listed — the set is "touched by a fault", and
    the chaos bench's non-faulted completion floor reads it as the
    conservative denominator)."""

    def __init__(self, plan: FaultPlan = SMOKE_PLAN, registry=None):
        self.plan = plan
        self.counts = {"launch": 0, "nan": 0, "slow": 0, "stuck": 0}
        self.poisoned_uids: set = set()
        self._stuck_uids: set = set()
        from repro.obs.metrics import default_registry

        reg = registry if registry is not None else default_registry()
        reg.register_component(self, {"faults": self.summary})

    def _trace(self, engine, kind: str, **args) -> None:
        """Record an ``inject`` instant on the wrapped engine's trace
        track (DESIGN.md §13.1).  Injection *decisions* are pure
        functions of (seed, kind, tick/uid) — the trace only witnesses
        them, so tracing never perturbs the fault schedule."""
        tr = getattr(engine, "tracer", None)
        if tr is not None:
            tr.tick_instant(engine, "inject", engine.tick, 0,
                            kind=kind, **args)

    def _draw(self, *key: int) -> float:
        seq = np.random.SeedSequence(
            [int(self.plan.seed)] + [int(k) & 0x7FFFFFFF for k in key])
        return float(np.random.default_rng(seq).random())

    def _victim(self, active: list, *key: int):
        """Pick the victim (slot, request) among the active pairs."""
        k = int(self._draw(_VICTIM, *key) * len(active)) % len(active)
        return active[k]

    # ------------------------------------------------- SlotEngine hooks

    def pre_launch(self, engine, active: list, attempt: int) -> None:
        """Before a launch attempt: maybe sleep (slow fault), maybe
        raise (launch fault).  Keyed per (tick, attempt) so a transient
        fault can clear on retry while ``rate=1.0`` (or an explicit
        tick) stays persistent through the whole retry budget."""
        p = self.plan
        if p.slow_rate and self._draw(_SLOW, engine.tick, attempt) < p.slow_rate:
            self.counts["slow"] += 1
            self._trace(engine, "slow", attempt=attempt)
            time.sleep(p.slow_s)
        hit = engine.tick in p.launch_error_ticks or (
            p.launch_error_rate
            and self._draw(_LAUNCH, engine.tick, attempt) < p.launch_error_rate)
        if hit:
            slot, req = self._victim(active, _LAUNCH, engine.tick, attempt)
            self.counts["launch"] += 1
            self.poisoned_uids.add(getattr(req, "uid", None))
            self._trace(engine, "launch", slot=slot,
                        uid=getattr(req, "uid", None), attempt=attempt)
            raise InjectedLaunchError(slot, engine.tick)

    def post_launch(self, engine, active: list, result):
        """After a successful launch: maybe corrupt one victim slot's
        rows to NaN/-1 — the corrupted-analog-activation input class the
        NaN/Inf guard must contain to one request."""
        p = self.plan
        hit = engine.tick in p.nan_ticks or (
            p.nan_rate and self._draw(_NAN, engine.tick) < p.nan_rate)
        if not hit:
            return result
        slot, req = self._victim(active, _NAN, engine.tick)
        self.counts["nan"] += 1
        self.poisoned_uids.add(getattr(req, "uid", None))
        self._trace(engine, "nan", slot=slot, uid=getattr(req, "uid", None))
        return _corrupt_slot_row(result, slot, engine.n_slots)

    def holds(self, engine, req) -> bool:
        """True ⇒ this occupant is stuck: its result is never absorbed,
        the slot stays held, and only the watchdog frees it.  Decided
        once per uid (seeded), so the answer never flips mid-stream."""
        uid = getattr(req, "uid", 0)
        p = self.plan
        stuck = uid in p.stuck_uids or uid in self._stuck_uids or (
            p.stuck_rate and self._draw(_STUCK, uid) < p.stuck_rate)
        if stuck and uid not in self._stuck_uids:
            self._stuck_uids.add(uid)
            self.counts["stuck"] += 1
            self.poisoned_uids.add(uid)
            self._trace(engine, "stuck", uid=uid)
        return bool(stuck)

    def summary(self) -> dict:
        """Injected-fault tallies plus the touched-uid count."""
        return {**self.counts, "poisoned": len(self.poisoned_uids)}
