"""Serving: batched decode with continuous batching.

``ServeEngine`` maintains a fixed set of decode *slots* over one shared
(jit-compiled) ``decode_step``.  Requests join free slots as others
finish — no batch-boundary stalls.  Per-slot absolute positions ride in
the ``pos`` vector; finished/inactive slots keep stepping on a pad token
(their logits are ignored) so the compiled computation stays
shape-stable — the standard static-batch continuous-batching trick.

Prefill is token-by-token through the same decode step (correct for all
families incl. recurrent state models; a chunked-prefill fast path is a
documented extension point — see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.families import get_family


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def greedy_generate(params, cfg: ModelConfig, prompts: jax.Array,
                    steps: int, max_len: int | None = None,
                    eos_id: int | None = None):
    """Simple batched greedy decode (no slot management).

    prompts: (B, P) int32.  Returns (B, steps) generated tokens.
    """
    family = get_family(cfg)
    b, p = prompts.shape
    max_len = max_len or (p + steps)
    state, _ = family.init_decode_state(cfg, b, max_len)
    step_fn = jax.jit(lambda s, t, pos: family.decode(params, s, t, pos, cfg))

    logits = None
    for t in range(p):
        logits, state = step_fn(state, prompts[:, t : t + 1],
                                jnp.full((b,), t, jnp.int32))
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for i in range(steps):
        out.append(tok[:, 0])
        logits, state = step_fn(state, tok, jnp.full((b,), p + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return jnp.stack(out, axis=1)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, eos_id: int | None = None,
                 pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.family = get_family(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.state, _ = self.family.init_decode_state(cfg, max_batch, max_len)
        self._step = jax.jit(
            lambda s, t, pos: self.family.decode(self.params, s, t, pos, cfg))
        self.slots: list[Request | None] = [None] * max_batch
        self._slot_pos = np.zeros(max_batch, np.int64)
        self._slot_cursor = np.zeros(max_batch, np.int64)  # prompt cursor
        self.queue: list[Request] = []
        self.completed: list[Request] = []

    # ------------------------------------------------------------- API

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _reset_slot(self, i: int) -> None:
        """Zero slot i's state (batch axis = 1 across all state trees) so a
        recycled slot never sees the previous request's KV / recurrent
        state."""
        self.state = jax.tree.map(lambda a: a.at[:, i].set(0), self.state)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._reset_slot(i)
                self.slots[i] = req
                self._slot_pos[i] = 0
                self._slot_cursor[i] = 0

    def step(self) -> None:
        """One engine tick: every active slot advances one token."""
        self._admit()
        tokens = np.full((self.max_batch, 1), self.pad_id, np.int32)
        pos = np.zeros(self.max_batch, np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = int(self._slot_cursor[i])
            if cur < len(req.prompt):
                tokens[i, 0] = req.prompt[cur]
            elif req.output:
                tokens[i, 0] = req.output[-1]
            else:
                tokens[i, 0] = self.pad_id
            pos[i] = self._slot_pos[i]

        logits, self.state = self._step(self.state, jnp.asarray(tokens),
                                        jnp.asarray(pos))
        nxt = np.asarray(jax.device_get(jnp.argmax(logits[:, -1], axis=-1)))

        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._slot_pos[i] += 1
            cur = int(self._slot_cursor[i])
            if cur < len(req.prompt) - 1:
                self._slot_cursor[i] = cur + 1
                continue
            if cur == len(req.prompt) - 1:
                self._slot_cursor[i] = cur + 1  # prompt consumed; start emitting
            tok = int(nxt[i])
            req.output.append(tok)
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(req.output) >= req.max_new_tokens or \
                    self._slot_pos[i] >= self.max_len - 1:
                req.done = True
                self.completed.append(req)
                self.slots[i] = None  # slot freed; NOTE: state slot reused —
                # fresh requests overwrite positions from 0 so stale KV
                # beyond the new request's positions is masked by kv_pos.

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed
