"""LM serving: batched decode with continuous batching.

``ServeEngine`` is a thin adapter over the shared scheduler core
(`serving/scheduler.py`, DESIGN.md §8): the core owns the arrival
queue, the slot table, the tick loop, and the latency ledger; this
module owns the decode state and the compiled step.  An LM slot lives
many ticks — prefill then decode — and finished/inactive slots keep
stepping on a pad token (their logits are ignored) so the compiled
computation stays shape-stable — the standard static-batch
continuous-batching trick.

Prefill is token-by-token through the decode step by default (correct
for all families incl. recurrent state models).  ``prefill_chunk=C``
enables the chunked fast path: one shape-stable compiled chunk step
advances every prefilling slot up to C prompt tokens per tick,
collapsing C host⇄device round-trips and launch overheads into one.
Families that declare a fused ``prefill`` hook (rwkv: one chunked-WKV
forward over the whole chunk, DESIGN.md §12) take it; the rest run a
masked ``lax.scan`` over the decode step, token-identical to C
separate launches — see ``_chunk_step_for``.

Compiled steps are cached per config (``_decode_step_for`` /
``_chunk_step_for``), not constructed per call or per engine: repeated
``greedy_generate`` calls and freshly constructed engines on the same
config hit the jit compile cache instead of re-tracing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.families import get_family, validate_slot_layout
from repro.obs.metrics import counted_lru_cache
from repro.serving.scheduler import ScheduledRequest, SlotEngine


@dataclasses.dataclass
class Request(ScheduledRequest):
    uid: int
    prompt: list[int]
    max_new_tokens: int
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _slot_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int):
    """Decode-state shardings for a mesh-backed engine: batch axis (1,
    per `validate_slot_layout`) over the mesh's ``data`` axis, leaves
    otherwise replicated.  The state stays device-resident and sharded
    across ticks — tokens scatter, logits gather, the recurrent state
    never moves."""
    family = get_family(cfg)
    state, _ = family.init_decode_state(cfg, batch, max_len, abstract=True)
    spec = lambda a: NamedSharding(
        mesh, P(*((None, "data") + (None,) * (a.ndim - 2))))
    return jax.tree.map(spec, state)


def _jit_step(fn, cfg, mesh, batch, max_len, n_vec_args):
    """jit ``fn(params, state, tokens, *vec)`` — plain when mesh is None,
    otherwise with explicit in/out shardings: params replicated, state
    per `_slot_shardings`, every batch-leading operand split over
    ``data``.  The state sharding is also the *out* sharding, so the
    slot state round-trips device-resident without a per-tick reshard."""
    if mesh is None:
        return jax.jit(fn)
    ss = _slot_shardings(cfg, mesh, batch, max_len)
    rep = NamedSharding(mesh, P())
    row = NamedSharding(mesh, P("data"))
    return jax.jit(fn, in_shardings=(rep, ss) + (row,) * (1 + n_vec_args),
                   out_shardings=(row, ss))


@counted_lru_cache("decode_step")
def _decode_step_for(cfg: ModelConfig, mesh=None, batch: int = 0,
                     max_len: int = 0):
    """One-token decode step, jitted once per (config, mesh).

    ``params`` rides as a traced argument (not a closure) so every
    caller — ``greedy_generate``, every ``ServeEngine`` on this config —
    shares one compilation.  The cache is metered
    (``compile_cache.decode_step.hits``/``.misses`` in the metrics
    registry) so a re-trace-per-engine regression is visible.
    """
    family = get_family(cfg)

    def run(params, state, tokens, pos):
        logits, state = family.decode(params, state, tokens, pos, cfg)
        return logits[:, -1], state

    return _jit_step(run, cfg, mesh, batch, max_len, 1)


@counted_lru_cache("chunk_step")
def _chunk_step_for(cfg: ModelConfig, chunk: int, mesh=None, batch: int = 0,
                    max_len: int = 0):
    """Shape-stable chunked-prefill step: advance slot ``i`` by
    ``n_active[i] ∈ [0, chunk]`` tokens in one compiled launch.

    Two implementations behind one signature
    ``(params, state, tokens (B,C), pos, n_active) → (last_logits, state)``:

    * **Family prefill hook** (rwkv): ONE fused chunked forward over all
      C positions — the Pallas WKV kernel eats the whole chunk in a
      masked-prefix forward (`models/rwkv6.py::prefill_step`), no
      per-token scan at all.  Positionless families only.
    * **Masked decode scan** (KV-cache families): a ``lax.scan`` over
      the single-token decode step where slot i participates at scan
      index ``j`` iff ``j < n_active[i]``; the ``where``-select makes
      the masked step the identity, so results are token-identical to
      ``chunk`` separate decode launches.

    Both assume batch at axis 1 of every state leaf — validated against
    the family's declared layout (`validate_slot_layout`), not assumed.

    Returns ``(last_logits, new_state)`` where ``last_logits[i]`` is the
    logits row from slot i's final *active* step — the row the engine
    samples the next token from.
    """
    family = get_family(cfg)
    validate_slot_layout(cfg)

    if family.prefill is not None:
        def run(params, state, tokens, pos, n_active):
            del pos  # prefill hook ⇒ positionless state
            return family.prefill(params, state, tokens, n_active, cfg)

        return _jit_step(run, cfg, mesh, batch, max_len, 2)

    def run(params, state, tokens, pos, n_active):
        # tokens (B, C) int32; pos, n_active (B,) int32
        def body(carry, xs):
            state, pos = carry
            tok, j = xs
            active = j < n_active  # (B,)
            logits, new_state = family.decode(params, state, tok[:, None],
                                              pos, cfg)

            def keep(new, old):  # batch axis 1 — see validate_slot_layout
                m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)

            state = jax.tree.map(keep, new_state, state)
            pos = jnp.where(active, pos + 1, pos)
            return (state, pos), logits[:, -1]

        c = tokens.shape[1]
        (state, _), outs = jax.lax.scan(
            body, (state, pos), (tokens.T, jnp.arange(c, dtype=jnp.int32)))
        idx = jnp.clip(n_active - 1, 0, c - 1)
        last = outs[idx, jnp.arange(tokens.shape[0])]
        return last, state

    return _jit_step(run, cfg, mesh, batch, max_len, 2)


def greedy_generate(params, cfg: ModelConfig, prompts: jax.Array,
                    steps: int, max_len: int | None = None,
                    eos_id: int | None = None,
                    prefill_chunk: int | None = None):
    """Simple batched greedy decode (no slot management).

    prompts: (B, P) int32.  Returns (B, steps) generated tokens.

    Prefill routes through the shared chunked step (`_chunk_step_for`):
    ``prefill_chunk=None`` (default) eats the whole prompt in
    ⌈P/C⌉ = 1 launch; an explicit C prefills C tokens per launch;
    ``prefill_chunk=1`` keeps the legacy token-by-token loop (one host
    sync per prompt token) — the reference the chunked path is pinned
    token-identical to in `tests/test_serving.py`.
    """
    family = get_family(cfg)
    b, p = prompts.shape
    max_len = max_len or (p + steps)
    state, _ = family.init_decode_state(cfg, b, max_len)
    step_fn = _decode_step_for(cfg)

    c = p if prefill_chunk is None else min(prefill_chunk, p)
    if c > 1:
        chunk_fn = _chunk_step_for(cfg, c)
        prompts_np = np.asarray(prompts, np.int32)
        last = None
        for off in range(0, p, c):
            n = min(c, p - off)
            block = np.zeros((b, c), np.int32)
            block[:, :n] = prompts_np[:, off:off + n]
            last, state = chunk_fn(params, state, jnp.asarray(block),
                                   jnp.full((b,), off, jnp.int32),
                                   jnp.full((b,), n, jnp.int32))
    else:
        last = None
        for t in range(p):
            last, state = step_fn(params, state, prompts[:, t : t + 1],
                                  jnp.full((b,), t, jnp.int32))
    out = []
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
    for i in range(steps):
        out.append(tok[:, 0])
        last, state = step_fn(params, state, tok,
                              jnp.full((b,), p + i, jnp.int32))
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
    return jnp.stack(out, axis=1)


class ServeEngine(SlotEngine):
    """Continuous-batching LM engine: scheduler core + decode adapter.

    The queue is unbounded by default (every accepted prompt is served);
    pass ``max_queue`` to bound it — overflow then sheds per ``evict``
    ("drop-newest" by default: an arriving request is rejected at the
    door rather than breaking a promise already queued).
    """

    request_type = Request

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, eos_id: int | None = None,
                 pad_id: int = 0, prefill_chunk: int = 1,
                 max_queue: int | None = None,
                 evict: str = "drop-newest", mesh=None, **core):
        """``core`` forwards the scheduler's fault-tolerance knobs
        (``admission`` / ``max_serve_ticks`` / ``launch_retries`` /
        ``faults`` — DESIGN.md §10) and the event-driven front door's
        cadence declaration (``tick_cost`` — an LM prefill/decode launch
        is the heaviest tick in a mixed door, so LM engines typically
        declare the largest cost, DESIGN.md §11) to `SlotEngine`.

        ``mesh`` shards the slot table over the mesh's ``data`` axis:
        decode state lives device-resident and sharded across ticks
        (`_slot_shardings`); requires ``max_batch`` divisible by the
        data-axis size."""
        super().__init__(max_batch, max_queue=max_queue, evict=evict, **core)
        validate_slot_layout(cfg)  # slot ops assume batch at state axis 1
        self.cfg = cfg
        self.params = params
        self.family = get_family(cfg)
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.prefill_chunk = prefill_chunk
        self.mesh = mesh
        self.state, _ = self.family.init_decode_state(cfg, max_batch, max_len)
        if mesh is not None:
            if max_batch % mesh.shape["data"]:
                raise ValueError(f"max_batch={max_batch} must divide over "
                                 f"the data axis ({mesh.shape['data']})")
            self.state = jax.device_put(
                self.state, _slot_shardings(cfg, mesh, max_batch, max_len))
        self._step = _decode_step_for(cfg, mesh, max_batch, max_len)
        self._chunk_step = (
            _chunk_step_for(cfg, prefill_chunk, mesh, max_batch, max_len)
            if prefill_chunk > 1 else None)
        self._slot_pos = np.zeros(max_batch, np.int64)
        self._slot_cursor = np.zeros(max_batch, np.int64)  # prompt cursor

    # ------------------------------------------------- adapter hooks

    def _reset_slot(self, i: int) -> None:
        """Zero slot i's state (batch axis = 1 across all state trees) so a
        recycled slot never sees the previous request's KV / recurrent
        state."""
        self.state = jax.tree.map(lambda a: a.at[:, i].set(0), self.state)

    def _on_admit(self, i: int, req: Request) -> None:
        self._reset_slot(i)
        self._slot_pos[i] = 0
        self._slot_cursor[i] = 0

    # Per-request accessors the stateful session engine overrides
    # (`serving/sessions.py`): which token list is being prefilled and
    # which one generation appends to.
    def _prompt(self, req) -> list[int]:
        return req.prompt

    def _gen(self, req) -> list[int]:
        return req.output

    def _launch(self, active):
        """One decode (or chunked-prefill) launch over the slot table.

        Returns ``(nxt, adv)``: per-slot sampled next token and how many
        tokens each slot advanced this tick.
        """
        b = self.n_slots
        c = self.prefill_chunk if self._chunk_step is not None else 1
        tokens = np.full((b, c), self.pad_id, np.int32)
        pos = np.zeros(b, np.int32)
        adv = np.zeros(b, np.int32)
        for i, req in active:
            cur = int(self._slot_cursor[i])
            prompt = self._prompt(req)
            remaining = len(prompt) - cur
            if remaining > 0:  # prefilling: up to C prompt tokens
                n = min(c, remaining)
                tokens[i, :n] = prompt[cur:cur + n]
            else:  # generating: one token per tick, feed last output
                n = 1
                out = self._gen(req)
                if out:
                    tokens[i, 0] = out[-1]
            pos[i] = self._slot_pos[i]
            adv[i] = n

        if self._chunk_step is not None and int(adv.max()) > 1:
            last, self.state = self._chunk_step(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(adv))
        else:
            # Pure-decode tick (every slot advancing ≤1 token): the plain
            # one-token step — no point scanning C-1 masked identity steps.
            last, self.state = self._step(self.params, self.state,
                                          jnp.asarray(tokens[:, :1]),
                                          jnp.asarray(pos))
        nxt = np.asarray(jax.device_get(jnp.argmax(last, axis=-1)))
        return nxt, adv

    def _validate(self, i: int, req: Request, result) -> bool:
        """A sampled token is a non-negative vocab index; a corrupted
        slot row (the int analogue of a NaN activation) fails its own
        request, never the engine (DESIGN.md §10)."""
        nxt, adv = result
        return int(nxt[i]) >= 0 and int(adv[i]) >= 0

    def _absorb(self, i: int, req: Request, result) -> bool:
        nxt, adv = result
        n = int(adv[i])
        self._slot_pos[i] += n
        cur = int(self._slot_cursor[i])
        prompt = self._prompt(req)
        if cur < len(prompt):
            self._slot_cursor[i] = cur + n
            if cur + n < len(prompt):
                return False  # prompt not consumed yet; nothing to emit
        tok = int(nxt[i])
        self._gen(req).append(tok)
        if (self.eos_id is not None and tok == self.eos_id) or \
                len(req.output) >= req.max_new_tokens or \
                self._slot_pos[i] >= self.max_len - 1:
            req.done = True
            return True
        # slot stays occupied; NOTE: state slot reused across requests —
        # fresh requests overwrite positions from 0 so stale KV beyond
        # the new request's positions is masked by kv_pos.
        return False
