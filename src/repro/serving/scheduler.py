"""Continuous-batching scheduler core shared by the LM and vision engines.

``ServeEngine`` (many-tick decode slots) and ``VisionEngine`` (one-tick
microbatch slots) are the same machine wearing different compute: a
bounded arrival queue feeding a fixed table of slots that one compiled
launch advances every tick.  This module owns that machine — the queue
with its pluggable eviction policy, the slot table with admit/recycle
semantics, the tick loop with arrival replay, and the per-request
latency ledger — so the engines reduce to three adapter hooks
(DESIGN.md §8):

  _on_admit(slot, req)   recycle the slot for a new occupant (LM: zero
                         the decode-state column; vision: nothing)
  _launch(active)        run ONE compiled, shape-stable launch covering
                         every slot (free slots ride as padding) and
                         return whatever _absorb needs
  _absorb(slot, req, r)  fold the launch result into the request;
                         return True when the request is finished
                         (vision: always — a slot lives one tick)

Eviction policies (applied when the bounded queue overflows on submit):

  "drop-newest"  reject the arriving request (LM front door: an
                 accepted prompt is a promise; shed load at the door)
  "drop-oldest"  evict the oldest *waiting* request (the always-on
                 sensor: stale frames are worthless, fresh ones are not)
  "deadline"     shed already-expired requests first, then the
                 lowest-priority one (SLO-aware load shedding)

Fault tolerance (DESIGN.md §10) is first-class scheduler semantics, not
adapter code: ``submit`` applies admission control and returns an
explicit status (backpressure, never a silent drop); a slot watchdog
(``max_serve_ticks``) evicts stuck occupants and recycles their slots
leak-free; ``step`` contains ``_launch`` failures with bounded
retry-with-backoff and then quarantines the poisoned requests onto the
``failed`` ledger while the rest of the traffic keeps serving; absorbed
results are guarded against NaN/Inf so one corrupted analog activation
fails one request, not the engine.  A seeded `serving.faults`
``FaultInjector`` plugs into any adapter via ``faults=`` and is
bit-for-bit free when its plan injects nothing.

Latency accounting is unified and per request: ``queue_ticks`` (ticks
between submit and first slot tick — or between submit and shedding for
evicted requests), ``serve_ticks`` (ticks occupying a slot — 1 for
vision, prefill+decode for LM), and ``launch_wall_us`` (summed
wall-clock of the launches that served the request; for a one-tick
vision slot this is the single batch launch it rode in).
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any, Callable, Sequence

import numpy as np

from repro.obs.metrics import default_registry

#: Explicit admission statuses ``submit`` returns — overload is
#: backpressure the caller can see, never a silent drop.
ADMITTED = "admitted"
REJECTED_DEADLINE = "rejected-deadline"  # projected queue residency misses it
REJECTED_QUEUE = "rejected-queue-full"  # the arrival was the eviction victim
REJECTED_HALTED = "rejected-halted"  # the engine was halted (front-door isolation)

#: Sentinel for "no launch succeeded this tick" — ``None`` is a valid
#: adapter launch result, so it cannot double as the failure marker.
_NO_RESULT = object()


@dataclasses.dataclass(kw_only=True)
class ScheduledRequest:
    """Accounting fields the scheduler core maintains on every request.

    Engine request types (``Request``, ``VisionRequest``) inherit from
    this; all fields are keyword-only so subclasses keep positional
    fields of their own.
    """

    arrival_tick: int = 0  # traffic-replay metadata; ``run`` consults it
    deadline_tick: int = -1  # absolute engine tick; -1 = no deadline
    priority: int = 0  # higher survives "deadline" shedding longer
    submitted_tick: int = -1  # tick at which submit() saw the request
    served_tick: int = -1  # first tick the request held a slot
    finished_tick: int = -1  # tick the request completed (or failed)
    evicted_tick: int = -1  # tick the request was shed/rejected
    serve_ticks: int = 0  # ticks spent occupying a slot
    launch_wall_us: float = 0.0  # summed wall-clock of its launches
    evicted: bool = False
    failed: bool = False
    failure: str = ""  # "", "launch", "nonfinite", "watchdog", "halt:…"

    @property
    def queue_ticks(self) -> int:
        """Ticks spent waiting in the queue — until first service for
        served requests, until shedding for evicted ones (never
        negative: eviction stamps ``evicted_tick``)."""
        if self.served_tick >= 0:
            return self.served_tick - self.submitted_tick
        if self.evicted_tick >= 0:
            return self.evicted_tick - self.submitted_tick
        return 0

    @property
    def deadline_missed(self) -> bool:
        """True when a deadline was set and not met: completed too late,
        or shed/failed before completing at all."""
        if self.deadline_tick < 0:
            return False
        if self.failed or self.evicted:
            return True
        return self.finished_tick < 0 or self.finished_tick > self.deadline_tick


def drop_newest(queue: list, incoming: ScheduledRequest) -> ScheduledRequest:
    """Reject the arriving request; the queue is untouched."""
    return incoming


def drop_oldest(queue: list, incoming: ScheduledRequest) -> ScheduledRequest:
    """Evict the oldest waiting request to make room for the arrival.
    With nothing waiting (max_queue=0) the arrival itself is shed, same
    as drop-newest — there is no older frame to trade away."""
    return queue.pop(0) if queue else incoming


def shed_deadline(queue: list, incoming: ScheduledRequest) -> ScheduledRequest:
    """SLO-aware shedding: already-expired requests first, then the
    lowest-priority one.

    "Now" is ``incoming.submitted_tick`` — ``submit`` stamps it with the
    engine clock before consulting the policy.  An expired waiter (its
    deadline at or before now) is worthless however important it once
    was; with none expired, the victim is the lowest-priority request
    among the queue and the arrival, newest-first within a priority
    class (an old promise outranks a new one of equal worth).
    """
    now = incoming.submitted_tick
    for j, r in enumerate(queue):
        if 0 <= r.deadline_tick <= now:
            return queue.pop(j)  # oldest expired waiter
    pool = list(enumerate(queue)) + [(len(queue), incoming)]
    j, victim = min(pool, key=lambda jr: (jr[1].priority, -jr[0]))
    return incoming if victim is incoming else queue.pop(j)


EVICTION_POLICIES: dict[str, Callable] = {
    "drop-newest": drop_newest,
    "drop-oldest": drop_oldest,
    "deadline": shed_deadline,
}


def tick_percentiles(values: Sequence[float]) -> tuple[float, float, float]:
    """(p50, p95, p99) of a tick series; zeros when empty.  Shared by
    `SlotEngine.latency_summary`, the replica pool's pooled ledger, and
    the serving benches, so every percentile in the stack is the same
    (linear-interpolation) estimator."""
    if not values:
        return 0.0, 0.0, 0.0
    arr = np.asarray(values, np.float64)
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 95)),
            float(np.percentile(arr, 99)))


def _uids(requests) -> list:
    return [getattr(r, "uid", None) for r in requests]


def _undrained_report(engine, name: str = "engine") -> list[tuple]:
    """Per-ledger undrained detail across an engine or a front door:
    ``(ledger name, queued uids, occupied-slot uids)`` triples, one per
    leaf engine (front doors report each registered engine under its
    registration key)."""
    subs = getattr(engine, "engines", None)
    if subs is not None:  # multi-engine front door
        out: list[tuple] = []
        for sub, e in subs.items():
            out.extend(_undrained_report(e, sub))
        return out
    queued = _uids(getattr(engine, "queue", ()))
    occupied = _uids(s for s in getattr(engine, "slots", ())
                     if s is not None)
    return [(name, queued, occupied)]


def drive(engine, requests: Sequence | None = None,
          max_ticks: int = 10_000, on_undrained: str = "warn") -> None:
    """Arrival-replay driver: submit each request when the clock reaches
    its ``arrival_tick``, tick until all traffic drains.  ``engine`` is
    anything with ``submit``/``step``/``busy``/``tick`` — a single
    ``SlotEngine`` or the multi-engine front door — so single-engine and
    front-door runs replay traffic with identical semantics.

    Stopping at ``max_ticks`` with traffic still pending is never
    silent: the message names every stranded request — per-ledger
    undrained counts *and* the offending uids, per engine behind a front
    door — via ``RuntimeWarning`` (``on_undrained="warn"``, the default)
    or raised (``on_undrained="raise"``).  A truncated replay that looks
    drained is how deadlocks hide; a count without uids is a deadlock an
    operator cannot chase.
    """
    pending = sorted(requests or [], key=lambda r: r.arrival_tick)
    ticks = 0
    while (pending or engine.busy()) and ticks < max_ticks:
        while pending and pending[0].arrival_tick <= engine.tick:
            engine.submit(pending.pop(0))
        engine.step()
        ticks += 1
    if pending or engine.busy():
        report = _undrained_report(engine)
        queued = sum(len(q) for _, q, _ in report)
        occupied = sum(len(o) for _, _, o in report)
        detail = "; ".join(
            f"{name}: queued={len(q)} uids={q}, occupied={len(o)} uids={o}"
            for name, q, o in report if q or o)
        msg = (f"drive() stopped at max_ticks={max_ticks} with traffic "
               f"undrained: {len(pending)} arrivals unsubmitted "
               f"(uids {_uids(pending)}), {queued} queued, "
               f"{occupied} slots occupied"
               + (f" [{detail}]" if detail else ""))
        if on_undrained == "raise":
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)


class SlotEngine:
    """The shared continuous-batching core (see module docstring).

    Subclasses implement ``_on_admit`` / ``_launch`` / ``_absorb`` and
    get submit/step/run/latency accounting for free.  Public state the
    adapters and tests rely on:

      tick        engine clock (ticks once per step, idle or not)
      queue       waiting requests, FIFO
      slots       fixed table, ``None`` = free
      completed   finished requests in completion order
      evicted     requests shed by the queue policy
      rejected    requests bounced at admission (backpressure)
      failed      requests quarantined by fault containment
      stats       aggregate counters (launches, served, evictions,
                  rejections, failures, watchdog_evictions,
                  launch_faults, slot_ticks, busy_slot_ticks, wall_us)
    """

    #: Request class this adapter serves — the multi-engine front door
    #: (`launch/serve.py::FrontDoor`) routes submissions on it, so each
    #: adapter declares its own traffic type instead of the router
    #: hardcoding an engine/request table.
    request_type: type | None = None

    def __init__(self, n_slots: int, *, max_queue: int | None = None,
                 evict: str | Callable = "drop-newest",
                 admission: str | None = None,
                 max_serve_ticks: int | None = None,
                 launch_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 tick_cost: int = 1,
                 faults=None,
                 tracer=None,
                 registry=None):
        """Fault-tolerance knobs (all off by default — the core without
        them is tick-for-tick the pre-§10 machine):

        ``admission="deadline"``    reject at submit when projected queue
                                    residency implies a deadline miss
        ``max_serve_ticks=N``       slot watchdog: evict any occupant
                                    after N held ticks (stuck streams)
        ``launch_retries``          bounded retry budget before a failing
                                    ``_launch`` quarantines requests
        ``retry_backoff_s``         base sleep between retries (doubles
                                    per attempt; 0 = no backoff sleep)
        ``faults``                  a `serving.faults.FaultInjector` —
                                    deterministic chaos for any adapter

        ``tick_cost`` is declarative capacity metadata for the
        event-driven front door (`launch/serve.py::FrontDoor`,
        DESIGN.md §11): one engine tick costs this many ticks of
        front-door time, so a cheap engine (vision microbatch) ticks
        several times while an expensive one (LM prefill) ticks once.
        The engine itself never reads it — its own clock stays
        one-per-step — and the door converts tick-denominated ledgers
        onto the shared clock exactly once.

        Observability knobs (DESIGN.md §13, both schedule-neutral):

        ``tracer``      an `obs.Tracer` recording this engine's request
                        lifecycles and tick/launch spans.  ``None`` (the
                        default) or a disabled tracer is bit-for-bit
                        free — every hook sits behind a ``None`` check
                        and no hook touches schedule state.
        ``registry``    the `obs.MetricsRegistry` this engine publishes
                        its latency/health views and tick histograms
                        into; ``None`` means the process-wide default.
        """
        if isinstance(evict, str):
            evict = EVICTION_POLICIES[evict]
        if admission not in (None, "deadline"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if not (isinstance(tick_cost, int) and tick_cost >= 1):
            raise ValueError(f"tick_cost must be an int >= 1, got "
                             f"{tick_cost!r}")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self._evict = evict
        self.admission = admission
        self.max_serve_ticks = max_serve_ticks
        self.launch_retries = launch_retries
        self.retry_backoff_s = retry_backoff_s
        self.tick_cost = tick_cost
        self.faults = faults
        self.tracer = tracer
        self.registry = registry if registry is not None else default_registry()
        self.metrics_scope = self.registry.register_component(
            self, {"latency": self.latency_summary, "health": self.health})
        self._hist_queue = self.registry.tick_histogram(
            f"{self.metrics_scope}.queue_ticks")
        self._hist_serve = self.registry.tick_histogram(
            f"{self.metrics_scope}.serve_ticks")
        self.tick = 0
        self.queue: list = []
        self.slots: list = [None] * n_slots
        self.completed: list = []
        self.evicted: list = []
        self.rejected: list = []
        self.failed: list = []
        self.halted: str | None = None
        self.degraded: str | None = None  # adapters set on fallback
        self.stats = {"launches": 0, "served": 0, "evictions": 0,
                      "rejections": 0, "failures": 0,
                      "watchdog_evictions": 0, "launch_faults": 0,
                      "slot_ticks": 0, "busy_slot_ticks": 0, "wall_us": 0.0}

    @property
    def max_batch(self) -> int:
        """The slot count, under the name the engines' callers use."""
        return self.n_slots

    # -------------------------------------------------- adapter contract

    def _on_admit(self, slot: int, req) -> None:
        """Recycle ``slot`` for ``req`` (zero per-slot state, cursors)."""

    def _launch(self, active: list[tuple[int, Any]]):
        """One compiled launch over the whole slot table; ``active`` is
        the occupied ``(slot, request)`` pairs.  Returns the per-slot
        result object ``_absorb`` consumes.  Must be retry-safe: mutate
        engine state only after the compiled call returns, so a raise
        leaves the engine exactly as before the attempt."""
        raise NotImplementedError

    def _absorb(self, slot: int, req, result) -> bool:
        """Fold this tick's result into ``req``; True ⇒ finished."""
        raise NotImplementedError

    def _validate(self, slot: int, req, result) -> bool:
        """Guard a slot's share of the launch result before ``_absorb``
        sees it.  The default rejects NaN/Inf in any float array leaf
        with a leading slot axis — a corrupted analog activation
        (tri-design, arXiv:2304.02968) fails its own request, never the
        engine.  Adapters extend with domain checks (LM: sampled token
        in range)."""
        stack = [result]
        while stack:
            x = stack.pop()
            if isinstance(x, (tuple, list)):
                stack.extend(x)
            elif isinstance(x, dict):
                stack.extend(x.values())
            elif (getattr(x, "ndim", 0) >= 1
                  and getattr(x, "shape", (0,))[0] == self.n_slots):
                row = np.asarray(x[slot])
                if (np.issubdtype(row.dtype, np.floating)
                        and not np.isfinite(row).all()):
                    return False
        return True

    def _on_launch_fault(self, exc: Exception) -> None:
        """Called once per ``_launch`` failure (before any retry) —
        adapters hook graceful degradation here (e.g. the vision engines
        fall back to the patches reference conv, DESIGN.md §10)."""

    # -------------------------------------------------------------- API

    def submit(self, req) -> str:
        """Enqueue now; returns an explicit admission status
        (``ADMITTED`` / ``REJECTED_*``) so overload is visible
        backpressure, not a silent drop.  ``arrival_tick`` is
        traffic-replay metadata that only ``run`` consults to delay
        submission; calling ``submit`` directly means the request exists
        as of the current tick."""
        req.submitted_tick = self.tick
        tr = self.tracer
        if tr is not None:
            tr.tick_instant(self, "submit", self.tick, tr.req_tid(req),
                            uid=getattr(req, "uid", None))
        if self.halted is not None:
            self._reject(req, REJECTED_HALTED)
            return REJECTED_HALTED
        if self.admission == "deadline" and self._projected_miss(req):
            self._reject(req, REJECTED_DEADLINE)
            return REJECTED_DEADLINE
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            victim = self._evict(self.queue, req)
            victim.evicted = True
            victim.evicted_tick = self.tick
            self.evicted.append(victim)
            self.stats["evictions"] += 1
            if tr is not None:
                vid = tr.req_tid(victim)
                tr.tick_span(self, "queue", victim.submitted_tick,
                             victim.queue_ticks, vid)
                tr.tick_instant(self, "evict", self.tick, vid,
                                uid=getattr(victim, "uid", None))
            if victim is req:
                return REJECTED_QUEUE
        self.queue.append(req)
        return ADMITTED

    def _reject(self, req, reason: str = "rejected") -> None:
        req.evicted = True
        req.evicted_tick = self.tick
        self.rejected.append(req)
        self.stats["rejections"] += 1
        if self.tracer is not None:
            self.tracer.tick_instant(
                self, "reject", self.tick, self.tracer.req_tid(req),
                uid=getattr(req, "uid", None), status=reason)

    def admission_probe(self, req) -> str:
        """Non-mutating preview of the status ``submit`` would return
        for ``req`` at the current tick — nothing lands on any ledger,
        no victim is evicted, the request is untouched on return.

        `serving.pool.ReplicaPool` dispatches on this: it probes
        replicas in least-loaded order and commits the request to the
        first that will admit, so a rejection is recorded on exactly
        one replica instead of every one it was offered to.  The
        preview is exact because probe and the committing ``submit``
        run back-to-back on one thread: the admission projection and
        the eviction policy see identical state (the policy runs
        against a *copy* of the queue, so a victim-selecting policy
        like ``shed_deadline`` cannot shed anyone during the probe).
        """
        if self.halted is not None:
            return REJECTED_HALTED
        prev = req.submitted_tick
        req.submitted_tick = self.tick  # policies read "now" off the request
        try:
            if self.admission == "deadline" and self._projected_miss(req):
                return REJECTED_DEADLINE
            if self.max_queue is not None and len(self.queue) >= self.max_queue:
                if self._evict(list(self.queue), req) is req:
                    return REJECTED_QUEUE
            return ADMITTED
        finally:
            req.submitted_tick = prev

    def _estimated_serve_ticks(self) -> float:
        """Mean slot residency of completed traffic (1.0 before any)."""
        if not self.completed:
            return 1.0
        return max(1.0, sum(r.serve_ticks for r in self.completed)
                   / len(self.completed))

    def _projected_miss(self, req) -> bool:
        """Admission projection: with the backlog ahead of this arrival
        draining ``n_slots`` requests per estimated-residency round,
        would it finish past its deadline?  Deliberately a heuristic —
        it holds the door against hopeless work, the "deadline" eviction
        policy sheds whatever the projection lets through that expires
        anyway."""
        if req.deadline_tick < 0:
            return False
        est = self._estimated_serve_ticks()
        occupied = sum(s is not None for s in self.slots)
        ahead = len(self.queue) + occupied
        if ahead < self.n_slots:
            wait = 0.0  # a slot is free (or frees) before its turn
        else:
            wait = est * math.ceil((ahead - self.n_slots + 1) / self.n_slots)
        return self.tick + wait + est > req.deadline_tick

    def _admit(self) -> None:
        tr = self.tracer
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._on_admit(i, req)
                self.slots[i] = req
                req.served_tick = self.tick
                if tr is not None:
                    tid = tr.req_tid(req)
                    tr.tick_span(self, "queue", req.submitted_tick,
                                 req.queue_ticks, tid)
                    tr.tick_instant(self, "admit", self.tick, tid,
                                    uid=getattr(req, "uid", None), slot=i)

    def _fail(self, slot: int | None, req, reason: str) -> None:
        """Quarantine ``req`` onto the failed ledger; recycle its slot."""
        if slot is not None:
            self.slots[slot] = None
        req.failed = True
        req.failure = reason
        req.finished_tick = self.tick
        self.failed.append(req)
        self.stats["failures"] += 1
        tr = self.tracer
        if tr is not None:
            tid = tr.req_tid(req)
            if req.served_tick >= 0:  # failed while holding a slot
                tr.tick_span(self, "serve", req.served_tick,
                             req.serve_ticks, tid)
            else:  # failed while still queued (engine halt)
                tr.tick_span(self, "queue", req.submitted_tick,
                             self.tick - req.submitted_tick, tid)
            tr.tick_instant(self, "fail", self.tick, tid,
                            uid=getattr(req, "uid", None), reason=reason)

    def _watchdog(self) -> None:
        """Evict occupants stuck past ``max_serve_ticks``: the slot is
        recycled leak-free (the next ``_on_admit`` resets all per-slot
        state — the same contract recycling always relies on)."""
        if self.max_serve_ticks is None:
            return
        for i, req in enumerate(self.slots):
            if req is not None and req.serve_ticks >= self.max_serve_ticks:
                self.stats["watchdog_evictions"] += 1
                if self.tracer is not None:
                    self.tracer.tick_instant(
                        self, "watchdog", self.tick, 0,
                        uid=getattr(req, "uid", None), slot=i)
                self._fail(i, req, "watchdog")

    def _attempt_launch(self, active: list, attempt: int):
        """One launch attempt, with the fault injector (if any) wrapped
        around it — injection raises/slowdowns land before the real
        launch, result corruption after, so a raise never leaves the
        adapter half-mutated."""
        if self.faults is not None:
            self.faults.pre_launch(self, active, attempt)
            return self.faults.post_launch(self, active, self._launch(active))
        return self._launch(active)

    def _launch_contained(self, active: list):
        """Run ``_launch`` with bounded retry-with-backoff, then
        quarantine: a fault that names its slot (``exc.slot``) costs
        exactly that request and the survivors retry with a fresh
        budget; an anonymous fault after exhausted retries quarantines
        the whole cohort — honest containment when the launch cannot say
        which occupant poisoned it.  Returns ``(result, served,
        quarantined)``; ``result is _NO_RESULT`` when no launch
        succeeded.  Terminates: every exhausted budget removes at least
        one slot."""
        act = list(active)
        quarantined: list = []
        attempt = 0
        tr = self.tracer
        while act:
            try:
                result = self._attempt_launch(act, attempt), act, quarantined
                if tr is not None:
                    tr.tick_span(self, "launch", self.tick, 1, 0,
                                 attempt=attempt, n_active=len(act), ok=True)
                return result
            except Exception as exc:  # noqa: BLE001 — containment boundary
                attempt += 1
                self.stats["launch_faults"] += 1
                if tr is not None:
                    tr.tick_span(self, "launch", self.tick, 1, 0,
                                 attempt=attempt - 1, n_active=len(act),
                                 ok=False)
                    tr.tick_instant(self, "launch_fault", self.tick, 0,
                                    error=type(exc).__name__,
                                    slot=getattr(exc, "slot", None),
                                    attempt=attempt - 1)
                self._on_launch_fault(exc)
                if attempt <= self.launch_retries:
                    if self.retry_backoff_s:
                        time.sleep(self.retry_backoff_s * 2 ** (attempt - 1))
                    continue
                slot = getattr(exc, "slot", None)
                hit = [(i, r) for i, r in act if i == slot]
                if tr is not None:
                    for i, r in (hit or act):
                        tr.tick_instant(self, "quarantine", self.tick, 0,
                                        uid=getattr(r, "uid", None), slot=i)
                quarantined.extend(hit or act)
                act = [] if not hit else [(i, r) for i, r in act if i != slot]
                attempt = 0
        return _NO_RESULT, [], quarantined

    def step(self) -> list:
        """One engine tick: watchdog-evict stuck occupants, admit into
        free slots, run one contained launch over the slot table,
        validate + absorb results, release finished slots.  Returns the
        requests that *completed* this tick (empty when idle — the tick
        still advances, so arrival-driven ``run`` loops make
        progress)."""
        self.tick += 1
        if self.halted is not None:
            return []
        self._watchdog()
        self._admit()
        active = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []

        t0 = time.perf_counter()
        result, served, quarantined = self._launch_contained(active)
        wall_us = (time.perf_counter() - t0) * 1e6

        tr = self.tracer
        for i, req in quarantined:
            req.serve_ticks += 1
            req.launch_wall_us += wall_us
            self._fail(i, req, "launch")

        finished = []
        if result is not _NO_RESULT:
            for i, req in served:
                req.serve_ticks += 1
                req.launch_wall_us += wall_us
                if self.faults is not None and self.faults.holds(self, req):
                    continue  # injected stuck occupant: the watchdog's prey
                if not self._validate(i, req, result):
                    if tr is not None:
                        tr.tick_instant(self, "validate_fail", self.tick, 0,
                                        uid=getattr(req, "uid", None), slot=i)
                    self._fail(i, req, "nonfinite")
                    continue
                if self._absorb(i, req, result):
                    req.finished_tick = self.tick
                    self.completed.append(req)
                    self.slots[i] = None
                    finished.append(req)
                    self._hist_queue.observe(req.queue_ticks)
                    self._hist_serve.observe(req.serve_ticks)
                    if tr is not None:
                        tid = tr.req_tid(req)
                        tr.tick_span(self, "serve", req.served_tick,
                                     req.serve_ticks, tid)
                        tr.tick_instant(self, "complete", self.tick, tid,
                                        uid=getattr(req, "uid", None),
                                        serve_ticks=req.serve_ticks)
            self.stats["launches"] += 1
            self.stats["wall_us"] += wall_us

        self.stats["served"] += len(finished)
        self.stats["slot_ticks"] += self.n_slots
        self.stats["busy_slot_ticks"] += len(active)
        if tr is not None:
            wall = {"wall_us": round(wall_us, 1)} if tr.wall else {}
            tr.tick_span(self, "engine_tick", self.tick, 1, 0,
                         n_active=len(active), finished=len(finished),
                         **wall)
        return finished

    def busy(self) -> bool:
        if self.halted is not None:
            return False
        return bool(self.queue) or any(s is not None for s in self.slots)

    def halt(self, reason: str) -> None:
        """Take the engine out of service (front-door isolation): every
        in-flight and queued request fails visibly onto the ledger —
        callers see the outage, nothing hangs — and subsequent submits
        return ``REJECTED_HALTED``."""
        self.halted = reason or "halted"
        tag = f"halt:{self.halted}"
        if self.tracer is not None:
            self.tracer.tick_instant(self, "halt", self.tick, 0,
                                     reason=self.halted)
        for i, req in enumerate(self.slots):
            if req is not None:
                self._fail(i, req, tag)
        for req in self.queue:
            self._fail(None, req, tag)
        self.queue.clear()

    def run(self, requests: Sequence | None = None,
            max_ticks: int = 10_000, on_undrained: str = "warn") -> list:
        """Drive the engine until all traffic drains.  ``requests`` with
        ``arrival_tick`` in the future are submitted when the engine
        clock reaches them (variable-arrival traffic replay)."""
        drive(self, requests, max_ticks, on_undrained)
        return self.completed

    def health(self) -> dict:
        """Degradation/fault report: halted state, adapter degradation
        (e.g. "patches" after kernel-fault fallback), the fault
        counters, and the instantaneous load signal (queue depth +
        occupied slots — the same score `ReplicaPool` dispatches on) —
        what an operator reads before trusting the latency summary."""
        return {
            "halted": self.halted,
            "degraded": self.degraded,
            "launch_faults": self.stats["launch_faults"],
            "watchdog_evictions": self.stats["watchdog_evictions"],
            "failed": len(self.failed),
            "evicted": len(self.evicted),
            "rejected": len(self.rejected),
            "queue_depth": len(self.queue),
            "occupied_slots": sum(s is not None for s in self.slots),
        }

    def latency_summary(self) -> dict:
        """Aggregate counters: completions, slot utilization (completed /
        slot-ticks and busy / slot-ticks over non-idle launches), mean
        *and* p50/p95/p99 queueing delay, slot residency in ticks, mean
        per-launch wall-clock, and the shed/failed accounting (eviction,
        rejection, failure, deadline-miss counts).  Tick-denominated
        keys all end in ``_ticks`` — the front door relies on that
        suffix to convert them onto its shared clock (DESIGN.md §11).
        """
        served = self.stats["served"]
        slot_ticks = self.stats["slot_ticks"]
        q50, q95, q99 = tick_percentiles(
            [r.queue_ticks for r in self.completed])
        s50, s95, s99 = tick_percentiles(
            [r.serve_ticks for r in self.completed])
        return {
            "served": served,
            "launches": self.stats["launches"],
            "evictions": self.stats["evictions"],
            "rejections": self.stats["rejections"],
            "failures": self.stats["failures"],
            "evicted": len(self.evicted),
            "failed": len(self.failed),
            "rejected": len(self.rejected),
            "deadline_misses": sum(r.deadline_missed for r in self.completed),
            "utilization": served / slot_ticks if slot_ticks else 0.0,
            "busy_utilization": (self.stats["busy_slot_ticks"] / slot_ticks
                                 if slot_ticks else 0.0),
            "mean_queue_ticks": (
                sum(r.queue_ticks for r in self.completed) / served
                if served else 0.0),
            "mean_serve_ticks": (
                sum(r.serve_ticks for r in self.completed) / served
                if served else 0.0),
            "p50_queue_ticks": q50, "p95_queue_ticks": q95,
            "p99_queue_ticks": q99,
            "p50_serve_ticks": s50, "p95_serve_ticks": s95,
            "p99_serve_ticks": s99,
            "mean_launch_us": (self.stats["wall_us"] / self.stats["launches"]
                               if self.stats["launches"] else 0.0),
        }
