"""Continuous-batching scheduler core shared by the LM and vision engines.

``ServeEngine`` (many-tick decode slots) and ``VisionEngine`` (one-tick
microbatch slots) are the same machine wearing different compute: a
bounded arrival queue feeding a fixed table of slots that one compiled
launch advances every tick.  This module owns that machine — the queue
with its pluggable eviction policy, the slot table with admit/recycle
semantics, the tick loop with arrival replay, and the per-request
latency ledger — so the engines reduce to three adapter hooks
(DESIGN.md §8):

  _on_admit(slot, req)   recycle the slot for a new occupant (LM: zero
                         the decode-state column; vision: nothing)
  _launch(active)        run ONE compiled, shape-stable launch covering
                         every slot (free slots ride as padding) and
                         return whatever _absorb needs
  _absorb(slot, req, r)  fold the launch result into the request;
                         return True when the request is finished
                         (vision: always — a slot lives one tick)

Eviction policies (applied when the bounded queue overflows on submit):

  "drop-newest"  reject the arriving request (LM front door: an
                 accepted prompt is a promise; shed load at the door)
  "drop-oldest"  evict the oldest *waiting* request (the always-on
                 sensor: stale frames are worthless, fresh ones are not)

Latency accounting is unified and per request: ``queue_ticks`` (ticks
between submit and first slot tick), ``serve_ticks`` (ticks occupying a
slot — 1 for vision, prefill+decode for LM), and ``launch_wall_us``
(summed wall-clock of the launches that served the request; for a
one-tick vision slot this is the single batch launch it rode in).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence


@dataclasses.dataclass(kw_only=True)
class ScheduledRequest:
    """Accounting fields the scheduler core maintains on every request.

    Engine request types (``Request``, ``VisionRequest``) inherit from
    this; all fields are keyword-only so subclasses keep positional
    fields of their own.
    """

    arrival_tick: int = 0  # traffic-replay metadata; ``run`` consults it
    submitted_tick: int = -1  # tick at which submit() saw the request
    served_tick: int = -1  # first tick the request held a slot
    finished_tick: int = -1  # tick the request completed
    serve_ticks: int = 0  # ticks spent occupying a slot
    launch_wall_us: float = 0.0  # summed wall-clock of its launches
    evicted: bool = False

    @property
    def queue_ticks(self) -> int:
        """Ticks spent waiting in the queue before being served."""
        return self.served_tick - self.submitted_tick


def drop_newest(queue: list, incoming: ScheduledRequest) -> ScheduledRequest:
    """Reject the arriving request; the queue is untouched."""
    return incoming


def drop_oldest(queue: list, incoming: ScheduledRequest) -> ScheduledRequest:
    """Evict the oldest waiting request to make room for the arrival.
    With nothing waiting (max_queue=0) the arrival itself is shed, same
    as drop-newest — there is no older frame to trade away."""
    return queue.pop(0) if queue else incoming


EVICTION_POLICIES: dict[str, Callable] = {
    "drop-newest": drop_newest,
    "drop-oldest": drop_oldest,
}


def drive(engine, requests: Sequence | None = None,
          max_ticks: int = 10_000) -> None:
    """Arrival-replay driver: submit each request when the clock reaches
    its ``arrival_tick``, tick until all traffic drains.  ``engine`` is
    anything with ``submit``/``step``/``busy``/``tick`` — a single
    ``SlotEngine`` or the multi-engine front door — so single-engine and
    front-door runs replay traffic with identical semantics."""
    pending = sorted(requests or [], key=lambda r: r.arrival_tick)
    ticks = 0
    while (pending or engine.busy()) and ticks < max_ticks:
        while pending and pending[0].arrival_tick <= engine.tick:
            engine.submit(pending.pop(0))
        engine.step()
        ticks += 1


class SlotEngine:
    """The shared continuous-batching core (see module docstring).

    Subclasses implement ``_on_admit`` / ``_launch`` / ``_absorb`` and
    get submit/step/run/latency accounting for free.  Public state the
    adapters and tests rely on:

      tick        engine clock (ticks once per step, idle or not)
      queue       waiting requests, FIFO
      slots       fixed table, ``None`` = free
      completed   finished requests in completion order
      evicted     requests shed by the queue policy
      stats       aggregate counters (launches, served, evictions,
                  slot_ticks, busy_slot_ticks, wall_us)
    """

    #: Request class this adapter serves — the multi-engine front door
    #: (`launch/serve.py::FrontDoor`) routes submissions on it, so each
    #: adapter declares its own traffic type instead of the router
    #: hardcoding an engine/request table.
    request_type: type | None = None

    def __init__(self, n_slots: int, *, max_queue: int | None = None,
                 evict: str | Callable = "drop-newest"):
        if isinstance(evict, str):
            evict = EVICTION_POLICIES[evict]
        self.n_slots = n_slots
        self.max_queue = max_queue
        self._evict = evict
        self.tick = 0
        self.queue: list = []
        self.slots: list = [None] * n_slots
        self.completed: list = []
        self.evicted: list = []
        self.stats = {"launches": 0, "served": 0, "evictions": 0,
                      "slot_ticks": 0, "busy_slot_ticks": 0, "wall_us": 0.0}

    @property
    def max_batch(self) -> int:
        """The slot count, under the name the engines' callers use."""
        return self.n_slots

    # -------------------------------------------------- adapter contract

    def _on_admit(self, slot: int, req) -> None:
        """Recycle ``slot`` for ``req`` (zero per-slot state, cursors)."""

    def _launch(self, active: list[tuple[int, Any]]):
        """One compiled launch over the whole slot table; ``active`` is
        the occupied ``(slot, request)`` pairs.  Returns the per-slot
        result object ``_absorb`` consumes."""
        raise NotImplementedError

    def _absorb(self, slot: int, req, result) -> bool:
        """Fold this tick's result into ``req``; True ⇒ finished."""
        raise NotImplementedError

    # -------------------------------------------------------------- API

    def submit(self, req) -> None:
        """Enqueue now.  ``arrival_tick`` is traffic-replay metadata that
        only ``run`` consults to delay submission; calling ``submit``
        directly means the request exists as of the current tick."""
        req.submitted_tick = self.tick
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            victim = self._evict(self.queue, req)
            victim.evicted = True
            self.evicted.append(victim)
            self.stats["evictions"] += 1
            if victim is req:
                return
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._on_admit(i, req)
                self.slots[i] = req
                req.served_tick = self.tick

    def step(self) -> list:
        """One engine tick: admit into free slots, run one launch over
        the slot table, absorb results, release finished slots.  Returns
        the requests that *completed* this tick (empty when idle — the
        tick still advances, so arrival-driven ``run`` loops make
        progress)."""
        self.tick += 1
        self._admit()
        active = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []

        t0 = time.perf_counter()
        result = self._launch(active)
        wall_us = (time.perf_counter() - t0) * 1e6

        finished = []
        for i, req in active:
            req.serve_ticks += 1
            req.launch_wall_us += wall_us
            if self._absorb(i, req, result):
                req.finished_tick = self.tick
                self.completed.append(req)
                self.slots[i] = None
                finished.append(req)

        self.stats["launches"] += 1
        self.stats["served"] += len(finished)
        self.stats["slot_ticks"] += self.n_slots
        self.stats["busy_slot_ticks"] += len(active)
        self.stats["wall_us"] += wall_us
        return finished

    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def run(self, requests: Sequence | None = None,
            max_ticks: int = 10_000) -> list:
        """Drive the engine until all traffic drains.  ``requests`` with
        ``arrival_tick`` in the future are submitted when the engine
        clock reaches them (variable-arrival traffic replay)."""
        drive(self, requests, max_ticks)
        return self.completed

    def latency_summary(self) -> dict:
        """Aggregate counters: completions, slot utilization (completed /
        slot-ticks and busy / slot-ticks over non-idle launches), mean
        queueing delay and slot residency in ticks, mean per-launch
        wall-clock, eviction count."""
        served = self.stats["served"]
        slot_ticks = self.stats["slot_ticks"]
        return {
            "served": served,
            "launches": self.stats["launches"],
            "evictions": self.stats["evictions"],
            "utilization": served / slot_ticks if slot_ticks else 0.0,
            "busy_utilization": (self.stats["busy_slot_ticks"] / slot_ticks
                                 if slot_ticks else 0.0),
            "mean_queue_ticks": (
                sum(r.queue_ticks for r in self.completed) / served
                if served else 0.0),
            "mean_serve_ticks": (
                sum(r.serve_ticks for r in self.completed) / served
                if served else 0.0),
            "mean_launch_us": (self.stats["wall_us"] / self.stats["launches"]
                               if self.stats["launches"] else 0.0),
        }
