"""Replica pools: N adapters of one modality behind one engine surface.

``ReplicaPool`` wraps N `SlotEngine` adapters (same modality, same
``request_type``, same ``tick_cost``) and presents the exact interface
the event-driven front door (`launch/serve.py::FrontDoor`, DESIGN.md
§11) drives — ``submit`` / ``step`` / ``busy`` / ``halt`` / ``health`` /
``latency_summary`` plus the ledger attributes — so a pool registers in
place of a single engine without the router or the driver changing.

Dispatch is **least-loaded and deterministic**: an arrival goes to the
live replica with the lowest load score ``queue depth + occupied
slots``, ties broken by replica index.  Admission composes with the
scheduler's overload control (DESIGN.md §10) through
`SlotEngine.admission_probe`: replicas are probed in score order and the
request commits to the first that will admit it, so the pool rejects
only when *every* replica rejects — and the rejection is recorded on
exactly one replica's ledger (the least-loaded one), never duplicated.

Fault isolation mirrors the front door one level down: a replica whose
``step`` escapes its own launch containment is halted — its in-flight
and queued traffic drains onto its ``failed`` ledger — and excluded
from dispatch while the siblings keep serving.  The pool as a whole
reports ``halted`` only when every replica is down.

Scale-out: each replica is an ordinary adapter, so sharded engines plug
in unchanged — e.g. N ``VisionEngine(mesh=submesh)`` replicas over the
disjoint submeshes of `launch.mesh.make_submeshes`, giving
data-parallelism *within* a replica and replica-parallelism across the
pool (exercised on the CI 8-virtual-device lane).
"""
from __future__ import annotations

from repro.obs.metrics import default_registry
from repro.serving.scheduler import (
    ADMITTED,
    SlotEngine,
    drive,
    tick_percentiles,
)


class ReplicaPool:
    """N same-modality `SlotEngine` replicas behind least-loaded
    dispatch; see module docstring."""

    def __init__(self, *replicas: SlotEngine, tracer=None, registry=None):
        """``tracer``/``registry``: observability knobs (DESIGN.md §13).
        The tracer records a ``dispatch`` instant per submission (chosen
        replica + load score); when the pool sits behind a traced
        `FrontDoor` the door propagates its tracer and clock scale to
        the pool *and* every replica, so an explicit ``tracer`` here is
        only for standalone pools.  Both are schedule-neutral."""
        if not replicas:
            raise ValueError("ReplicaPool needs at least one replica")
        want = getattr(replicas[0], "request_type", None)
        cost = getattr(replicas[0], "tick_cost", 1)
        for ix, r in enumerate(replicas):
            if getattr(r, "request_type", None) is not want:
                raise ValueError(
                    f"replica {ix} serves "
                    f"{getattr(r, 'request_type', None)!r}, pool serves "
                    f"{want!r} — a pool is one modality")
            if getattr(r, "tick_cost", 1) != cost:
                raise ValueError(
                    f"replica {ix} has tick_cost "
                    f"{getattr(r, 'tick_cost', 1)}, pool cadence is {cost} "
                    "— replicas of one pool share one cadence")
        self.replicas = list(replicas)
        self.request_type = want
        self.tick_cost = cost
        self.tracer = tracer
        if tracer is not None:  # standalone traced pool: wire replicas
            for ix, r in enumerate(self.replicas):
                r.tracer = tracer
                tracer.label(r, f"replica[{ix}]")
        self.tick = 0
        self.completed: list = []  # pool-level merged completion order
        self.down: dict[int, str] = {}  # replica index -> failure reason
        reg = registry if registry is not None else default_registry()
        self.metrics_scope = reg.register_component(
            self, {"latency": self.latency_summary, "health": self.health})

    # ------------------------------------------------------- dispatch

    def load_score(self, ix: int) -> int:
        """The dispatch score of replica ``ix``: queue depth + occupied
        slots — everything admitted but not finished.  Lower is
        less loaded."""
        r = self.replicas[ix]
        return len(r.queue) + sum(s is not None for s in r.slots)

    def _dispatch_order(self) -> list[int]:
        """Live replicas, least-loaded first, ties by replica index."""
        return sorted(
            (ix for ix, r in enumerate(self.replicas) if r.halted is None),
            key=lambda ix: (self.load_score(ix), ix))

    def submit(self, req) -> str:
        """Least-loaded dispatch with pool-level admission: probe
        replicas in score order, commit to the first that admits.
        Rejection only when every replica rejects — committed on the
        least-loaded live replica (or replica 0 when all are down), so
        the request lands on exactly one ledger."""
        order = self._dispatch_order()
        chosen = None
        for ix in order:
            if self.replicas[ix].admission_probe(req) == ADMITTED:
                chosen = ix
                break
        if chosen is None:
            chosen = order[0] if order else 0
        if self.tracer is not None:
            self.tracer.tick_instant(
                self, "dispatch", self.tick, 0,
                uid=getattr(req, "uid", None), replica=chosen,
                score=self.load_score(chosen), probed=len(order))
        return self.replicas[chosen].submit(req)

    # ------------------------------------------------------- tick loop

    def step(self) -> list:
        """One pool tick: step every live replica (one modality — one
        cadence), merging completions in replica-index order.  A replica
        step that escapes its launch containment halts *that replica*
        (its traffic fails onto its ledger, dispatch excludes it) and
        the pool keeps serving — the front door's isolation boundary,
        one level down."""
        self.tick += 1
        out = []
        for ix, r in enumerate(self.replicas):
            if ix in self.down:
                continue
            try:
                out.extend(r.step())
            except Exception as exc:  # noqa: BLE001 — isolation boundary
                reason = f"{type(exc).__name__}: {exc}"
                self.down[ix] = reason
                r.halt(reason)
        self.completed.extend(out)
        return out

    def busy(self) -> bool:
        return any(r.busy() for r in self.replicas)

    def run(self, requests=None, max_ticks: int = 10_000,
            on_undrained: str = "warn") -> list:
        """Drive the pool until all traffic drains — same arrival-replay
        semantics as `SlotEngine.run` (the pool is an engine to
        `drive`); returns the pool-level merged completions."""
        drive(self, requests, max_ticks, on_undrained)
        return self.completed

    def halt(self, reason: str) -> None:
        """Take the whole pool out of service (front-door isolation when
        the *pool's* step raises): every replica halts visibly."""
        for ix, r in enumerate(self.replicas):
            if r.halted is None:
                r.halt(reason)
            self.down.setdefault(ix, reason)

    @property
    def halted(self) -> str | None:
        """Non-None only when every replica is down — one live replica
        keeps the pool serving."""
        if any(r.halted is None for r in self.replicas):
            return None
        return "; ".join(f"replica {ix}: {r.halted}"
                         for ix, r in enumerate(self.replicas))

    # ---------------------------------------------- aggregate ledgers
    # (list-valued views so `drive()`'s undrained accounting and the
    # benches read a pool exactly like a single engine)

    @property
    def queue(self) -> list:
        return [req for r in self.replicas for req in r.queue]

    @property
    def slots(self) -> list:
        return [s for r in self.replicas for s in r.slots]

    @property
    def failed(self) -> list:
        return [req for r in self.replicas for req in r.failed]

    @property
    def evicted(self) -> list:
        return [req for r in self.replicas for req in r.evicted]

    @property
    def rejected(self) -> list:
        return [req for r in self.replicas for req in r.rejected]

    # ------------------------------------------------------ reporting

    def health(self) -> dict:
        """Pool health: the single-engine keys (so front-door
        aggregation reads a pool like an engine — ``halted`` is
        all-replicas-down, counters sum) plus per-replica reports and
        the pool's own view of which replicas are down."""
        per = [r.health() for r in self.replicas]
        agg = {
            "halted": self.halted,
            "degraded": next((h["degraded"] for h in per
                              if h["degraded"] is not None), None),
            "down": dict(self.down),
            "replicas": per,
        }
        for key in ("launch_faults", "watchdog_evictions", "failed",
                    "evicted", "rejected", "queue_depth", "occupied_slots"):
            agg[key] = sum(h[key] for h in per)
        return agg

    def latency_summary(self) -> dict:
        """Pool-level aggregation with the same keys as
        `SlotEngine.latency_summary` (counts sum; utilizations and
        means re-derive from pooled totals; percentiles pool the
        completed ledgers — *not* a mean of per-replica percentiles,
        which would be biased), plus ``replicas`` with the per-replica
        summaries.  Tick-denominated keys keep the ``_ticks`` suffix so
        the front door's clock conversion applies at every depth."""
        per = [r.latency_summary() for r in self.replicas]
        served = sum(s["served"] for s in per)
        launches = sum(s["launches"] for s in per)
        slot_ticks = sum(r.stats["slot_ticks"] for r in self.replicas)
        busy_ticks = sum(r.stats["busy_slot_ticks"] for r in self.replicas)
        wall_us = sum(r.stats["wall_us"] for r in self.replicas)
        done = [req for r in self.replicas for req in r.completed]
        q50, q95, q99 = tick_percentiles([req.queue_ticks for req in done])
        s50, s95, s99 = tick_percentiles([req.serve_ticks for req in done])
        return {
            "served": served,
            "launches": launches,
            "evictions": sum(s["evictions"] for s in per),
            "rejections": sum(s["rejections"] for s in per),
            "failures": sum(s["failures"] for s in per),
            "evicted": sum(s["evicted"] for s in per),
            "failed": sum(s["failed"] for s in per),
            "rejected": sum(s["rejected"] for s in per),
            "deadline_misses": sum(s["deadline_misses"] for s in per),
            "utilization": served / slot_ticks if slot_ticks else 0.0,
            "busy_utilization": busy_ticks / slot_ticks if slot_ticks else 0.0,
            "mean_queue_ticks": (
                sum(req.queue_ticks for req in done) / served
                if served else 0.0),
            "mean_serve_ticks": (
                sum(req.serve_ticks for req in done) / served
                if served else 0.0),
            "p50_queue_ticks": q50, "p95_queue_ticks": q95,
            "p99_queue_ticks": q99,
            "p50_serve_ticks": s50, "p95_serve_ticks": s95,
            "p99_serve_ticks": s99,
            "mean_launch_us": wall_us / launches if launches else 0.0,
            "replicas": per,
        }
