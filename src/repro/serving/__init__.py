from repro.serving.scheduler import (
    ADMITTED,
    EVICTION_POLICIES,
    REJECTED_DEADLINE,
    REJECTED_HALTED,
    REJECTED_QUEUE,
    ScheduledRequest,
    SlotEngine,
    drop_newest,
    drop_oldest,
    shed_deadline,
)
from repro.serving.faults import (
    SMOKE_PLAN,
    FaultInjector,
    FaultPlan,
    InjectedLaunchError,
)
from repro.serving.engine import Request, ServeEngine, greedy_generate
from repro.serving.pool import ReplicaPool
from repro.serving.sessions import SessionEngine, SessionRequest
from repro.serving.vision import VisionEngine, VisionRequest

__all__ = ["Request", "ServeEngine", "greedy_generate",
           "SessionEngine", "SessionRequest",
           "VisionEngine", "VisionRequest", "ReplicaPool",
           "ScheduledRequest", "SlotEngine",
           "EVICTION_POLICIES", "drop_newest", "drop_oldest",
           "shed_deadline",
           "ADMITTED", "REJECTED_DEADLINE", "REJECTED_HALTED",
           "REJECTED_QUEUE",
           "FaultInjector", "FaultPlan", "InjectedLaunchError",
           "SMOKE_PLAN"]
