from repro.serving.engine import Request, ServeEngine, greedy_generate
from repro.serving.vision import VisionEngine, VisionRequest

__all__ = ["Request", "ServeEngine", "greedy_generate",
           "VisionEngine", "VisionRequest"]
