from repro.serving.scheduler import (
    EVICTION_POLICIES,
    ScheduledRequest,
    SlotEngine,
    drop_newest,
    drop_oldest,
)
from repro.serving.engine import Request, ServeEngine, greedy_generate
from repro.serving.vision import VisionEngine, VisionRequest

__all__ = ["Request", "ServeEngine", "greedy_generate",
           "VisionEngine", "VisionRequest",
           "ScheduledRequest", "SlotEngine",
           "EVICTION_POLICIES", "drop_newest", "drop_oldest"]
