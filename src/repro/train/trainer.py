"""Trainer loop: metrics, checkpointing, straggler monitoring, restart.

Fault-tolerance model (single-process simulation of the pod runtime):

* checkpoints are written asynchronously every ``ckpt_every`` steps and
  at exit; the data-pipeline cursor is stored inside the checkpoint, so
  ``Trainer.restore()`` resumes bit-exact;
* the straggler monitor tracks a rolling step-time median; steps slower
  than ``k×median`` are logged and counted (at scale this signal feeds
  the coordination service to evict/replace the slow host — here it
  drives logs + metrics so tests can assert the detection);
* any exception during a step triggers a checkpoint-backed restart path
  (``max_restarts``), the same code path a preemption would take.
"""
from __future__ import annotations

import collections
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 3.0):
        self.window = window
        self.threshold = threshold
        self.times: collections.deque = collections.deque(maxlen=window)
        self.stragglers = 0
        self.last_flagged: int | None = None

    def observe(self, step: int, dt: float) -> bool:
        flagged = False
        if len(self.times) >= max(4, self.window // 4):
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.stragglers += 1
                self.last_flagged = step
                flagged = True
        self.times.append(dt)
        return flagged


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        state: dict,
        pipeline,
        *,
        ckpt_manager: CheckpointManager | None = None,
        ckpt_every: int = 0,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
        straggler: StragglerMonitor | None = None,
        max_restarts: int = 2,
    ):
        self.train_step = train_step
        self.state = state
        self.pipeline = pipeline
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.straggler = straggler or StragglerMonitor()
        self.max_restarts = max_restarts
        self.history: list[dict] = []

    def _save(self):
        if self.ckpt is None:
            return
        step = int(jax.device_get(self.state["step"]))
        self.ckpt.save(step, self.state,
                       extra={"pipeline": self.pipeline.state_dict()})

    def restore(self) -> bool:
        if self.ckpt is None:
            return False
        restored = self.ckpt.restore_latest(self.state)
        if restored is None:
            return False
        self.state, extra = restored
        if "pipeline" in extra:
            self.pipeline.load_state_dict(extra["pipeline"])
        return True

    def run(self, num_steps: int) -> dict:
        restarts = 0
        done = 0
        while done < num_steps:
            try:
                batch = next(self.pipeline)
                t0 = time.perf_counter()
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(self.state["params"])
                dt = time.perf_counter() - t0
                step = int(jax.device_get(self.state["step"]))
                flagged = self.straggler.observe(step, dt)
                metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                metrics.update(step=step, step_time_s=dt, straggler=flagged)
                self.history.append(metrics)
                if self.log_every and step % self.log_every == 0:
                    self.log(f"step {step}: loss={metrics.get('loss', float('nan')):.4f} "
                             f"({dt*1e3:.1f} ms)" + ("  [STRAGGLER]" if flagged else ""))
                if self.ckpt_every and step % self.ckpt_every == 0:
                    self._save()
                done += 1
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # preemption / transient failure path
                restarts += 1
                self.log(f"step failed ({type(e).__name__}: {e}); "
                         f"restart {restarts}/{self.max_restarts}")
                if restarts > self.max_restarts or not self.restore():
                    raise
        self._save()
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.history[-1] if self.history else {}
