"""Train state: params + optimizer state + step, as a plain pytree dict
(checkpoint- and pjit-friendly)."""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp


def TrainState(params: Any, opt_state: Any, step: int = 0,
               extras: dict | None = None) -> dict:
    state = {
        "params": params,
        "opt": opt_state,
        "step": jnp.asarray(step, jnp.int32),
    }
    if extras:
        state["extras"] = extras
    return state


def _rename_opt_axes(axes: Any) -> Any:
    """Optimizer-state axes get their own logical names (``opt_embed`` /
    ``opt_mlp``), which default to mirroring the param rules but can be
    overridden for ZeRO-1 (optimizer sharded more than params)."""
    if isinstance(axes, tuple):
        ren = {"embed": "opt_embed", "mlp": "opt_mlp"}
        return tuple(ren.get(a, a) for a in axes)
    return {k: _rename_opt_axes(v) for k, v in axes.items()}


def state_logical_axes(param_axes: Any, opt_state: Any) -> dict:
    """Logical-axes tree matching TrainState structure.  Optimizer moments
    ("mu" / "m" / "v") mirror the param axes (via the opt_* aliases); the
    step scalar is unsharded."""
    opt_axes = {k: _rename_opt_axes(param_axes) for k in opt_state.keys()}
    return {"params": param_axes, "opt": opt_axes, "step": ()}
