"""Gradient compression: int8 quantization with error feedback (EF-SGD,
Seide et al. / Karimireddy et al. style).

At real scale the win is in the DP all-reduce: gradients cross the ICI
(or DCN, across pods) at 1 byte/element instead of 4, a 4× cut on the
collective term of the roofline for communication-bound steps.  Under
``jit`` SPMD the reduction itself is inserted by XLA, so this module
implements the *quantize → (reduce) → dequantize + error-feedback*
transform around it; the error accumulator lives in the train state and
is itself sharded like the gradients.

The transform is lossy per-step but unbiased in the long run: the
quantization residual is fed back into the next step's gradient, which
is what keeps convergence intact (validated in tests on a quadratic
and on the tiny LM).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize_leaf(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_int8_ef(grads: Any, error: Any | None):
    """Returns (compressed-dequantized grads, new error accumulator)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(corrected)
        deq = q.astype(jnp.float32) * scale
        return deq, corrected - deq

    flat = jax.tree.map(leaf, grads, error)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err
