from repro.train.state import TrainState
from repro.train.step import make_train_step
from repro.train.trainer import Trainer, StragglerMonitor

__all__ = ["TrainState", "make_train_step", "Trainer", "StragglerMonitor"]
