"""Training step for the paper's VWW pipeline (MobileNetV2 ± P²M stem).

Keeps BN running stats in the train state (paper trains with standard
BN and SGD+momentum, §5.1).

Scaling story (DESIGN.md §7): the step is written to be SPMD-safe under
a data-parallel plan — the image batch carries a ``"batch"`` logical
constraint, every reduction in the model (loss mean, BN batch stats) is
a global reduction XLA lowers to the matching collectives, and the
optional int8 error-feedback gradient compression is the same transform
the LM trainer uses (`train.compression`), so the compressed VWW step is
semantically identical between one device and a DP mesh (per-tensor
quantization scales are computed on the *globally reduced* gradient; the
residual float-reassociation differences and their interaction with the
clip nonlinearities are quantified in DESIGN.md §7).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.mobilenetv2 import MNV2Config, apply_mnv2
from repro.optim.optimizers import Optimizer
from repro.core.pixel_model import PixelModel
from repro.parallel import shard
from repro.train.compression import compress_grads_int8_ef


def vww_train_state(params, bn, opt_state, *, step: int = 0,
                    grad_compression: str | None = None) -> dict:
    """Canonical VWW train-state dict.

    When compression is on, the error-feedback accumulator is seeded with
    zeros up front so the state *structure* is identical on step 0 and
    step N — which is what lets ``jax.jit`` take one
    (in_shardings == out_shardings) tree instead of a step-0 special case.
    """
    state = {"params": params, "bn": bn, "opt": opt_state,
             "step": jnp.asarray(step, jnp.int32)}
    if grad_compression == "int8_ef":
        state["extras"] = {"ef_error": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    elif grad_compression is not None:
        raise ValueError(f"unknown grad_compression {grad_compression!r}")
    return state


def vww_train_shardings(state: dict, batch: dict, plan):
    """(state shardings, batch shardings) for jitting the VWW step under a
    data-parallel plan: every state leaf replicated (MNV2 param stacks are
    small — DESIGN.md §7), batch dim-0 split over the data axes."""
    from repro.parallel.sharding_utils import batch_shardings, replicated_tree
    return replicated_tree(state, plan), batch_shardings(batch, plan)


def softmax_ce(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - true).mean()


def make_vww_train_step(cfg: MNV2Config, optimizer: Optimizer,
                        pixel_model: PixelModel | None = None,
                        *, grad_compression: str | None = None) -> Callable:
    """Build the VWW train step.

    grad_compression: None | "int8_ef" — int8 quantization with error
      feedback on the (globally reduced) gradients; the EF accumulator
      rides in ``state["extras"]["ef_error"]`` exactly like the LM
      trainer's, so checkpointing and sharding treat both the same way.
    """
    def step(state: dict, batch: dict):
        images = shard(batch["images"], "batch", None, None, None)
        labels = shard(batch["labels"], "batch")

        def loss_fn(params):
            logits, new_bn = apply_mnv2(params, state["bn"], images,
                                        cfg, pixel_model, train=True)
            ce = softmax_ce(logits, labels)
            acc = (logits.argmax(-1) == labels).mean()
            return ce, (new_bn, acc)

        (loss, (new_bn, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])

        extras = dict(state.get("extras", {}))
        if grad_compression == "int8_ef":
            grads, extras["ef_error"] = compress_grads_int8_ef(
                grads, extras.get("ef_error"))
        elif grad_compression is not None:
            raise ValueError(f"unknown grad_compression {grad_compression!r}")

        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"])
        new_state = {"params": new_params, "bn": new_bn, "opt": new_opt,
                     "step": state["step"] + 1}
        if extras:
            new_state["extras"] = extras
        return new_state, {"loss": loss, "acc": acc}

    return step


def make_vww_eval(cfg: MNV2Config, pixel_model: PixelModel | None = None):
    def evaluate(params, bn_state, batch, p2m_deploy=None):
        logits, _ = apply_mnv2(params, bn_state, batch["images"], cfg,
                               pixel_model, train=False, p2m_deploy=p2m_deploy)
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return float(acc)

    return evaluate
