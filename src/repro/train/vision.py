"""Training step for the paper's VWW pipeline (MobileNetV2 ± P²M stem).

Keeps BN running stats in the train state (paper trains with standard
BN and SGD+momentum, §5.1)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.mobilenetv2 import MNV2Config, apply_mnv2
from repro.optim.optimizers import Optimizer
from repro.core.pixel_model import PixelModel


def softmax_ce(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - true).mean()


def make_vww_train_step(cfg: MNV2Config, optimizer: Optimizer,
                        pixel_model: PixelModel | None = None) -> Callable:
    def step(state: dict, batch: dict):
        def loss_fn(params):
            logits, new_bn = apply_mnv2(params, state["bn"], batch["images"],
                                        cfg, pixel_model, train=True)
            ce = softmax_ce(logits, batch["labels"])
            acc = (logits.argmax(-1) == batch["labels"]).mean()
            return ce, (new_bn, acc)

        (loss, (new_bn, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"])
        new_state = {"params": new_params, "bn": new_bn, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "acc": acc}

    return step


def make_vww_eval(cfg: MNV2Config, pixel_model: PixelModel | None = None):
    def evaluate(params, bn_state, batch, p2m_deploy=None):
        logits, _ = apply_mnv2(params, bn_state, batch["images"], cfg,
                               pixel_model, train=False, p2m_deploy=p2m_deploy)
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return float(acc)

    return evaluate
