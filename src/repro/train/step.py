"""Train-step builder: loss → grad → (optional compression) → optimizer.

The returned step is a pure function suitable for ``jax.jit`` with
shardings derived from logical axes (the launcher wires those).  Grad
accumulation (microbatching) runs as a ``lax.scan`` over microbatch
slices — the standard memory lever when activations dominate.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.families import get_family
from repro.optim.optimizers import Optimizer
from repro.train.compression import compress_grads_int8_ef


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    *,
    accum_steps: int = 1,
    grad_compression: str | None = None,  # None | "int8_ef"
) -> Callable:
    family = get_family(cfg)

    def loss_fn(params, batch):
        return family.loss(params, batch, cfg)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, 0.0), micro_batches)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {}

        extras = dict(state.get("extras", {}))
        if grad_compression == "int8_ef":
            grads, extras["ef_error"] = compress_grads_int8_ef(
                grads, extras.get("ef_error"))

        new_params, new_opt = optimizer.update(grads, state["opt"], params,
                                               state["step"])
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if extras:
            new_state["extras"] = extras
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    family = get_family(cfg)

    def eval_step(params, batch):
        loss, metrics = family.loss(params, batch, cfg)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return metrics

    return eval_step
