"""Mixtral-8x22B [arXiv:2401.04088; hf] — MoE 8 experts top-2, GQA kv=8,
sliding-window attention."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768, rope_theta=1e6,
    n_experts=8, top_k=2, sliding_window=4096,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab=512, n_experts=4, top_k=2, sliding_window=16, capacity_factor=4.0,
)
