"""The paper's own benchmark: MobileNetV2-VWW with the P²M first layer
(Table 1 hyperparameters: k=5, s=5, p=0, c_o=8, N_b=8)."""
from repro.core.p2m_conv import P2MConvConfig
from repro.models.mobilenetv2 import MNV2Config

P2M_LAYER = P2MConvConfig(kernel=5, stride=5, in_channels=3, out_channels=8,
                          n_bits=8)

CONFIG = MNV2Config(variant="p2m", image_size=560, p2m=P2M_LAYER)
BASELINE = MNV2Config(variant="baseline", image_size=560)

# reduced configs for CPU training runs / tests
SMOKE = MNV2Config(variant="p2m", image_size=80, width=0.25, head_channels=64,
                   p2m=P2M_LAYER)
SMOKE_BASELINE = MNV2Config(variant="baseline", image_size=80, width=0.25,
                            head_channels=64)

# Batched vision serving defaults (serving/vision.py, DESIGN.md §7.2).
# Microbatch 8 fills the N=8 output-channel lane of the fused conv at the
# paper geometry; queue depth 64 rides out ~8 launches of burst before
# the oldest-frame eviction policy kicks in.
SERVE_MAX_BATCH = 8
SERVE_MAX_QUEUE = 64
SERVE_QUANT_BITS = 8  # PTQ width for the deploy-folded stem (Table 1 N_b)

# Streaming-video detection defaults (video/engine.py, DESIGN.md §9).
# A stream occupies a slot for its whole lifetime, so the slot table is
# narrower than the single-shot microbatch; the queue holds a couple of
# generations of waiting streams.  Delta threshold 0.0 = lossless event
# gating (skip only bit-identical frames — gated output == dense,
# pinned by test); raise it to trade accuracy for readout bandwidth.
STREAM_MAX_SLOTS = 4
STREAM_MAX_QUEUE = 8
STREAM_DELTA_THRESHOLD = 0.0
