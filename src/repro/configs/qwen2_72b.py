"""Qwen2-72B [arXiv:2407.10671; hf] — dense, GQA kv=8, QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, qkv_bias=True, rope_theta=1e6,
)
