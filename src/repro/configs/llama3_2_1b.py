"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B; unverified] — dense, GQA kv=8,
tied embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128256, tie_embeddings=True, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, tie_embeddings=True, rope_theta=500000.0,
)
