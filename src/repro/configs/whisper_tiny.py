"""Whisper-tiny [arXiv:2212.04356; unverified] — enc-dec; conv frame
frontend stubbed (input_specs provide frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, n_encoder_layers=4,
    max_source_positions=1500, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, n_encoder_layers=2,
    max_source_positions=16, tie_embeddings=True,
)
