"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

One module per assigned architecture (exact public config) plus the
paper's own P²M-VWW model.  Every module defines ``CONFIG`` and
``SMOKE`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeSpec, applicable, cells

ARCH_IDS = [
    "qwen3-32b",
    "stablelm-1.6b",
    "qwen2-72b",
    "llama3.2-1b",
    "qwen3-moe-30b-a3b",
    "mixtral-8x22b",
    "rwkv6-3b",
    "llama-3.2-vision-11b",
    "recurrentgemma-9b",
    "whisper-tiny",
]

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_")
            for name in ARCH_IDS}


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str):
    return _load(name).CONFIG


def get_smoke_config(name: str):
    return _load(name).SMOKE


__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "applicable", "cells",
           "get_config", "get_smoke_config"]
