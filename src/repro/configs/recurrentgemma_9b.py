"""RecurrentGemma-9B / Griffin [arXiv:2402.19427; unverified] — RG-LRU
recurrent blocks + local attention (window 2048), pattern rec:rec:attn,
MQA (kv=1)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="rglru",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, d_rnn=4096, sliding_window=2048,
    block_pattern=("rec", "rec", "attn"), rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="rglru",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=512, d_rnn=64, sliding_window=8,
    block_pattern=("rec", "rec", "attn"),
)
