"""Qwen3-32B [hf:Qwen/Qwen3-8B family; hf] — dense, qk-norm, GQA kv=8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936, qk_norm=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, qk_norm=True, rope_theta=1e6,
)
