"""Assigned input-shape set (per-arch cells) + applicability rules."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k runs only for sub-quadratic archs (SSM / hybrid / SWA);
# pure full-attention archs skip it (DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"rwkv6-3b", "recurrentgemma-9b", "mixtral-8x22b"}


def applicable(arch_name: str, family: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_name in LONG_CONTEXT_ARCHS
    return True


def cells(arch_name: str, family: str) -> list[str]:
    return [s for s in SHAPES if applicable(arch_name, family, s)]
