"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf] — MoE 128 experts top-8,
GQA kv=4, qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, vocab=512, qk_norm=True, n_experts=8, top_k=2, capacity_factor=4.0,
)
