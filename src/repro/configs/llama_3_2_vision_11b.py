"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified] —
cross-attn image layers every 5th layer; vision frontend stubbed
(P²M frontend integration point — DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=500000.0,
    cross_attn_period=5, n_image_tokens=1600,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, cross_attn_period=2, n_image_tokens=8,
)
