"""Fused delta-gated P²M stem kernel (DESIGN.md §3.6).

The streaming-video engine's temporal delta gate (`video/delta.py`)
decides per slot whether this tick's frame needs the stem re-run.  The
original engine path computed the stem for **every** slot and discarded
the skipped results with a host-visible ``jnp.where`` — shape-stable,
but the opposite of the event-driven skipping the gate models
(Neuromorphic-P2M, arXiv:2301.09111): every masked-off slot still paid
the full stem FLOPs.

`p2m_conv_pallas_gated` fuses the select into the conv kernel itself.
The per-slot rerun mask rides as a **scalar-prefetch** operand
(`pltpu.PrefetchScalarGridSpec` — available in SMEM before the tile
body runs), expanded host-side to one int32 per row tile.  Inside the
kernel each (rows, N) tile branches on its mask scalar:

* mask 0 — the tile's slot is gated off: skip the power expansion and
  the MXU dot entirely (``pl.when`` — a real branch, no wasted stem
  FLOPs) and copy the cached tile to the output;
* mask 1 — compute the tile exactly like the dense kernel (same
  accumulate order) and run the epilogue.

One launch, no host round-trip, and bitwise-identical to
``dense-kernel + jnp.where`` by construction (computed rows run the
same tile compute in the same order; skipped rows copy the same cache)
— pinned by test and gated at 1.0 in the bench.

``block_h`` is clamped to a divisor of ``Ho`` (`aligned_block_h`) so a
row tile never straddles two slots: every tile is then all-rerun or
all-skip, the scalar mask is exact, and the FLOPs actually skipped
equal the mask's skip fraction (the ``stem_flops_skipped_ratio`` the
bench records).  The tile's input block is still DMA'd by the pipeline
— the win is stem *FLOPs*; the readout *bits* the gate models are
metered separately by the stream ledger (`core/bandwidth.py`).

`p2m_conv_gated_jnp` is the XLA twin — compute-all + where-select, the
reference path the engine keeps (``stem_path="where"``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.p2m_conv.conv import (
    _accumulate_step,
    _epilogue_values,
    ceil_to,
    conv_out_spatial,
    default_conv_blocks,
    p2m_conv_jnp,
    premix_weights,
)


def aligned_block_h(ho: int, bh: int) -> int:
    """Largest divisor of ``ho`` that is ≤ ``bh`` — the slot-aligned row
    tile: with ``bh | Ho`` a tile's rows all belong to one image, so the
    per-slot mask is uniform across the tile and a skip skips the whole
    tile's FLOPs."""
    bh = max(1, min(bh, ho))
    while ho % bh:
        bh -= 1
    return bh


def _gated_tail(mask, shift_ref, cached_ref, out_ref, acc_ref, *, last,
                mode: str, v_lsb: float, max_count: int):
    """Per-tile select: fresh epilogue where the slot reran, cache copy
    where it was gated off (the copy runs every kernel-row step it's
    cheap and keeps the skip path free of the acc scratch, which holds
    stale values for skipped tiles)."""

    @pl.when(mask & last)
    def _epilogue():
        raw = acc_ref[...]
        shift = shift_ref[...].astype(jnp.float32)
        out = _epilogue_values(raw, shift, mode=mode, v_lsb=v_lsb,
                               max_count=max_count)
        out_ref[...] = out.reshape(out_ref.shape)

    @pl.when(jnp.logical_not(mask) & last)
    def _copy_cache():
        out_ref[...] = cached_ref[...]


def _gated_kernel_fast(mask_ref, a_ref, wmix_ref, shift_ref, cached_ref,
                       out_ref, acc_ref, *, k: int, dx: int, mode: str,
                       v_lsb: float, max_count: int):
    """stride == kernel; a_ref is (bh, 1, Wo, kC); mask_ref is the
    scalar-prefetch per-row-tile rerun vector."""
    mi, ki = pl.program_id(0), pl.program_id(2)
    mask = mask_ref[mi] != 0

    @pl.when(mask)  # a gated-off tile issues no MXU work at all
    def _compute():
        bh, _, wo, kc = a_ref.shape
        x2d = a_ref[...].reshape(bh * wo, kc)
        wmix2d = wmix_ref[...].reshape(wmix_ref.shape[1], wmix_ref.shape[2])
        _accumulate_step(x2d, wmix2d, acc_ref, dx=dx, first=ki == 0)

    _gated_tail(mask, shift_ref, cached_ref, out_ref, acc_ref,
                last=ki == k - 1, mode=mode, v_lsb=v_lsb,
                max_count=max_count)


def _gated_kernel_general(mask_ref, band_ref, wmix_ref, shift_ref,
                          cached_ref, out_ref, acc_ref, *, k: int,
                          stride: int, wo: int, dx: int, mode: str,
                          v_lsb: float, max_count: int):
    """General stride; band_ref is (1, bh, Wband, C) — see conv.py §3.2."""
    mi, ki = pl.program_id(0), pl.program_id(2)
    mask = mask_ref[mi] != 0

    @pl.when(mask)
    def _compute():
        _, bh, wpad, c = band_ref.shape
        band = band_ref[...].reshape(bh, wpad, c)
        parts = []
        for dw in range(k):
            win = band[:, dw : dw + wo * stride, :]
            parts.append(win.reshape(bh, wo, stride, c)[:, :, 0, :])
        x = jnp.stack(parts, axis=2)
        x2d = x.reshape(bh * wo, k * c)
        wmix2d = wmix_ref[...].reshape(wmix_ref.shape[1], wmix_ref.shape[2])
        _accumulate_step(x2d, wmix2d, acc_ref, dx=dx, first=ki == 0)

    _gated_tail(mask, shift_ref, cached_ref, out_ref, acc_ref,
                last=ki == k - 1, mode=mode, v_lsb=v_lsb,
                max_count=max_count)


@functools.partial(
    jax.jit,
    static_argnames=("kernel", "stride", "coeffs", "mode", "v_lsb",
                     "max_count", "block_h", "block_n", "interpret"),
)
def p2m_conv_pallas_gated(
    images,
    w,
    shift,
    cached,
    rerun,
    *,
    kernel: int,
    stride: int,
    coeffs: tuple,
    mode: str = "relu",
    v_lsb: float = 1.0 / 255.0,
    max_count: int = 255,
    block_h: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
):
    """Delta-gated fused conv: one launch computes the stem only where
    ``rerun`` says to and returns the cached activations elsewhere.

    images: (B, H, W, C); w/shift as `p2m_conv_pallas`; cached:
    (B, Ho, Wo, N) — the slot-resident stem cache; rerun: (B,) bool.
    Inference-only (no VJP): the serving hot path never differentiates
    through the gate.
    """
    b, h, w_dim, c = images.shape
    k, s = kernel, stride
    ho = conv_out_spatial(h, k, s)
    wo = conv_out_spatial(w_dim, k, s)
    kc = k * c
    n = w.shape[1]
    assert cached.shape == (b, ho, wo, n), (cached.shape, (b, ho, wo, n))
    assert rerun.shape == (b,), rerun.shape
    dx = len(coeffs[0])

    wmix = premix_weights(w, coeffs)
    wmix = wmix.reshape(dx, k, kc, n).transpose(1, 0, 2, 3).reshape(
        k, dx * kc, n)

    bh_default, bn_default = default_conv_blocks(b, ho, wo, n, dx * kc)
    # Slot alignment: bh | Ho ⇒ every row tile belongs to one image and
    # mh = B·Ho needs no row padding.
    bh = aligned_block_h(ho, block_h or bh_default)
    bn = min(block_n or bn_default, ceil_to(n, 128))

    mh = b * ho
    n_pad = ceil_to(n, bn)

    wmix = jnp.pad(wmix, ((0, 0), (0, 0), (0, n_pad - n)))
    sp = jnp.pad(jnp.asarray(shift, jnp.float32), (0, n_pad - n)).reshape(
        1, n_pad)
    # One int32 per row tile (scalar prefetch): tile mi belongs to image
    # mi·bh // Ho, i.e. repeat each slot's flag Ho/bh times.
    tile_mask = jnp.repeat(jnp.asarray(rerun, jnp.int32), ho // bh)
    cached_p = jnp.pad(cached.astype(jnp.float32).reshape(mh, wo, n),
                       ((0, 0), (0, 0), (0, n_pad - n)))

    grid = (mh // bh, n_pad // bn, k)
    common = dict(mode=mode, v_lsb=v_lsb, max_count=max_count)
    if s == k:
        a = images[:, : ho * k, : wo * k, :].reshape(mh, k, wo, kc)
        kernel_fn = functools.partial(_gated_kernel_fast, k=k, dx=dx,
                                      **common)
        x_spec = pl.BlockSpec((bh, 1, wo, kc),
                              lambda mi, ni, ki, m: (mi, ki, 0, 0))
        x_arr = a
    else:
        rows = jnp.stack(
            [images[:, dh : dh + (ho - 1) * s + 1 : s, :, :]
             for dh in range(k)],
            axis=0,
        ).reshape(k, mh, w_dim, c)
        w_band = wo * s + k
        rows = jnp.pad(rows, ((0, 0), (0, 0), (0, w_band - w_dim), (0, 0)))
        kernel_fn = functools.partial(_gated_kernel_general, k=k, stride=s,
                                      wo=wo, dx=dx, **common)
        x_spec = pl.BlockSpec((1, bh, w_band, c),
                              lambda mi, ni, ki, m: (ki, mi, 0, 0))
        x_arr = rows

    out = pl.pallas_call(
        kernel_fn,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                x_spec,
                pl.BlockSpec((1, dx * kc, bn),
                             lambda mi, ni, ki, m: (ki, 0, ni)),
                pl.BlockSpec((1, bn), lambda mi, ni, ki, m: (0, ni)),
                pl.BlockSpec((bh, wo, bn), lambda mi, ni, ki, m: (mi, 0, ni)),
            ],
            out_specs=pl.BlockSpec((bh, wo, bn),
                                   lambda mi, ni, ki, m: (mi, 0, ni)),
            scratch_shapes=[pltpu.VMEM((bh * wo, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((mh, wo, n_pad), jnp.float32),
        interpret=interpret,
    )(tile_mask, x_arr, wmix, sp, cached_p)
    return out[:, :, :n].reshape(b, ho, wo, n)


def p2m_conv_gated_jnp(images, w, shift, cached, rerun, *, kernel: int,
                       stride: int, coeffs, mode: str = "relu",
                       v_lsb: float = 1.0 / 255.0, max_count: int = 255):
    """XLA twin: dense stem + where-select — the reference path.  Shape-
    stable XLA cannot branch on the traced mask, so every slot pays the
    stem FLOPs; only the Pallas kernel genuinely skips them."""
    stem = p2m_conv_jnp(images, w, shift, kernel=kernel, stride=stride,
                        coeffs=coeffs, mode=mode, v_lsb=v_lsb,
                        max_count=max_count)
    return jnp.where(jnp.asarray(rerun, bool)[:, None, None, None],
                     stem, cached.astype(jnp.float32))
