from repro.kernels.p2m_conv.ops import p2m_matmul, p2m_matmul_jnp
from repro.kernels.p2m_conv.ref import p2m_matmul_ref

__all__ = ["p2m_matmul", "p2m_matmul_jnp", "p2m_matmul_ref"]
