from repro.kernels.p2m_conv.backward import (
    p2m_backward,
    p2m_backward_jnp,
    p2m_bwd_dx_pallas,
    p2m_bwd_dw_pallas,
)
from repro.kernels.p2m_conv.conv import (
    conv_out_spatial,
    im2col_matrix,
    p2m_conv_pallas,
    premix_weights,
)
from repro.kernels.p2m_conv.gated import (
    aligned_block_h,
    p2m_conv_gated_jnp,
    p2m_conv_pallas_gated,
)
from repro.kernels.p2m_conv.ops import (
    p2m_conv,
    p2m_conv_jnp,
    p2m_matmul,
    p2m_matmul_jnp,
)
from repro.kernels.p2m_conv.ref import p2m_matmul_ref

__all__ = [
    "aligned_block_h",
    "conv_out_spatial",
    "im2col_matrix",
    "p2m_backward",
    "p2m_backward_jnp",
    "p2m_bwd_dx_pallas",
    "p2m_bwd_dw_pallas",
    "p2m_conv",
    "p2m_conv_gated_jnp",
    "p2m_conv_jnp",
    "p2m_conv_pallas",
    "p2m_conv_pallas_gated",
    "p2m_matmul",
    "p2m_matmul_jnp",
    "p2m_matmul_ref",
    "premix_weights",
]
