"""Pallas backward kernels for the P²M basis sum (DESIGN.md §4).

The VJP of the premixed accumulation ``raw = Σ_j (X^∘j) @ W̃_j`` is itself
a short sum of matmuls against powered operands, so it reuses the same
(M, N, K) tiling machinery as the forward:

    dX = Σ_j j·X^∘(j-1) ⊙ (G @ W̃_jᵀ)          (one MXU dot per tile step)
    dW = Σ_{i,j} a_ij · i·|W|^∘(i-1) ⊙ T_j,   T_j = (X^∘j)ᵀ @ G

Both kernels accumulate the *matmul* part across the contracted grid
dimension in a VMEM scratch laid out as ``dx`` stacked blocks, and apply
the powered-operand elementwise factors once, in the epilogue — the
powered operands are never materialized in HBM.

The epilogue mask (ReLU/saturation clamp, STE for quant) is elementwise
and cheap, so it is applied to ``g`` by the caller (`ops.py`) in XLA
where it fuses for free; these kernels differentiate the raw basis sum.

`p2m_backward_jnp` is the identical closed form in XLA ops — the CPU/GPU
fallback registered in the `custom_vjp` off-TPU.  Either way, training no
longer pays the old fallback of re-tracing `jax.vjp` through the full
dw·dx forward expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.p2m_conv.conv import _power_concat, ceil_to, premix_weights


# ---------------------------------------------------------------------------
# dX kernel: dX = Σ_j j·X^(j-1) ∘ (G @ W̃_jᵀ), tiled (M, K) with N contracted.
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, wt_ref, x_ref, out_ref, acc_ref, *, dx: int, nn: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)                      # (bm, bn)
    wt = wt_ref[...].reshape(wt_ref.shape[0], -1)           # (bn, dx·bk)
    acc_ref[...] += jax.lax.dot_general(
        g, wt.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ni == nn - 1)
    def _epilogue():
        x = x_ref[...].astype(jnp.float32)                  # (bm, bk)
        bk = x.shape[1]
        acc = acc_ref[...]
        total = jnp.zeros_like(x)
        xpow = jnp.ones_like(x)                              # x^(j-1)
        for j in range(1, dx + 1):
            total = total + float(j) * xpow * acc[:, (j - 1) * bk : j * bk]
            if j < dx:
                xpow = xpow * x
        out_ref[...] = total


@functools.partial(
    jax.jit,
    static_argnames=("coeffs", "block_m", "block_n", "block_k", "interpret"),
)
def p2m_bwd_dx_pallas(g, w, x, *, coeffs: tuple, block_m: int = 256,
                      block_n: int = 128, block_k: int = 128,
                      interpret: bool = False):
    """dX of the raw basis sum. g: (M, N) cotangent (epilogue mask already
    applied), w: (K, N), x: (M, K) → (M, K) float32."""
    m, n = g.shape
    k = w.shape[0]
    dx = len(coeffs[0])
    bm = min(block_m, ceil_to(m, 8))
    bn = min(block_n, ceil_to(n, 128))
    bk = min(block_k, ceil_to(k, 128))
    mp, np_, kp = ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk)

    # (N, dx, K): blocks reshape to the (bn, dx·bk) premixed-transpose tile.
    wt = premix_weights(w, coeffs).transpose(2, 0, 1)
    wt = jnp.pad(wt, ((0, np_ - n), (0, 0), (0, kp - k)))
    gp = jnp.pad(g.astype(jnp.float32), ((0, mp - m), (0, np_ - n)))
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))

    nn = np_ // bn
    grid = (mp // bm, kp // bk, nn)
    out = pl.pallas_call(
        functools.partial(_dx_kernel, dx=dx, nn=nn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda mi, ki, ni: (mi, ni)),
            pl.BlockSpec((bn, dx, bk), lambda mi, ki, ni: (ni, 0, ki)),
            pl.BlockSpec((bm, bk), lambda mi, ki, ni: (mi, ki)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda mi, ki, ni: (mi, ki)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, dx * bk), jnp.float32)],
        interpret=interpret,
    )(gp, wt, xp)
    return out[:m, :k]


# ---------------------------------------------------------------------------
# dW kernel: T_j = (X^∘j)ᵀ @ G accumulated over M; epilogue folds a_ij·i·|W|^(i-1).
# ---------------------------------------------------------------------------


def _dw_kernel(x_ref, g_ref, w_ref, out_ref, acc_ref, *, coeffs, nm: int):
    mi = pl.program_id(2)
    dw = len(coeffs)
    dx = len(coeffs[0])

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                      # (bm, bk)
    g = g_ref[...].astype(jnp.float32)                      # (bm, bn)
    xcat = _power_concat(x, dx)                              # (bm, dx·bk)
    acc_ref[...] += jax.lax.dot_general(                     # (dx·bk, bn)
        xcat, g,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(mi == nm - 1)
    def _epilogue():
        aw = jnp.abs(w_ref[...].astype(jnp.float32))        # (bk, bn)
        bk = aw.shape[0]
        acc = acc_ref[...]
        total = jnp.zeros_like(aw)
        wpow = jnp.ones_like(aw)                             # |w|^(i-1)
        for i in range(1, dw + 1):
            u_i = jnp.zeros_like(aw)
            for j in range(1, dx + 1):
                a_ij = float(coeffs[i - 1][j - 1])
                if a_ij != 0.0:
                    u_i = u_i + a_ij * acc[(j - 1) * bk : j * bk, :]
            total = total + float(i) * wpow * u_i
            if i < dw:
                wpow = wpow * aw
        out_ref[...] = total


@functools.partial(
    jax.jit,
    static_argnames=("coeffs", "block_m", "block_n", "block_k", "interpret"),
)
def p2m_bwd_dw_pallas(g, w, x, *, coeffs: tuple, block_m: int = 256,
                      block_n: int = 128, block_k: int = 128,
                      interpret: bool = False):
    """dW of the raw basis sum. g: (M, N) masked cotangent, w: (K, N),
    x: (M, K) → (K, N) float32."""
    m, n = g.shape
    k = w.shape[0]
    dx = len(coeffs[0])
    bm = min(block_m, ceil_to(m, 8))
    bn = min(block_n, ceil_to(n, 128))
    bk = min(block_k, ceil_to(k, 128))
    mp, np_, kp = ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk)

    gp = jnp.pad(g.astype(jnp.float32), ((0, mp - m), (0, np_ - n)))
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    nm = mp // bm
    grid = (kp // bk, np_ // bn, nm)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, coeffs=coeffs, nm=nm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ki, ni, mi: (mi, ki)),
            pl.BlockSpec((bm, bn), lambda ki, ni, mi: (mi, ni)),
            pl.BlockSpec((bk, bn), lambda ki, ni, mi: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda ki, ni, mi: (ki, ni)),
        out_shape=jax.ShapeDtypeStruct((kp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dx * bk, bn), jnp.float32)],
        interpret=interpret,
    )(xp, gp, wp)
    return out[:k, :n]


# ---------------------------------------------------------------------------
# Closed-form XLA fallback (identical math, for CPU/GPU custom_vjp).
# ---------------------------------------------------------------------------


def p2m_backward_jnp(g, w, x, coeffs):
    """Closed-form (dX, dW) of the raw basis sum in XLA ops.

    Same premixed decomposition as the Pallas kernels: dx matmuls total
    instead of re-differentiating the dw·dx forward expansion.
    """
    g = g.astype(jnp.float32)
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    dw = len(coeffs)
    dx = len(coeffs[0])
    wmix = premix_weights(w, coeffs)                         # (dx, K, N)

    gx = jnp.zeros_like(x)
    xpow = jnp.ones_like(x)
    t_list = []
    xp = x
    for j in range(1, dx + 1):
        gx = gx + float(j) * xpow * (g @ wmix[j - 1].T)
        t_list.append(xp.T @ g)                              # T_j (K, N)
        if j < dx:
            xpow = xpow * x
            xp = xp * x

    aw = jnp.abs(w)
    gw = jnp.zeros_like(w)
    wpow = jnp.ones_like(aw)
    for i in range(1, dw + 1):
        u_i = jnp.zeros_like(w)
        for j in range(1, dx + 1):
            a_ij = float(coeffs[i - 1][j - 1])
            if a_ij != 0.0:
                u_i = u_i + a_ij * t_list[j - 1]
        gw = gw + float(i) * wpow * u_i
        if i < dw:
            wpow = wpow * aw
    return gx, gw


def epilogue_mask(raw, shift, *, mode: str, full_scale: float):
    """d out / d (raw) of the CDS/ADC epilogue, elementwise.

    "raw" passes gradients through; "relu" masks the clamp's saturated
    regions; "quant" uses the straight-through estimator — the gradient of
    the soft-clipped ("relu") path, the convention used throughout.
    """
    if mode == "raw":
        return jnp.ones_like(raw)
    v = raw + jnp.asarray(shift, jnp.float32)
    return ((v > 0.0) & (v < full_scale)).astype(jnp.float32)


def p2m_backward(g, w, x, coeffs, *, use_pallas: bool, interpret: bool = False,
                 blocks: tuple[int, int, int] | None = None):
    """Dispatch (dX, dW): Pallas kernels on TPU (or forced interpret),
    closed-form XLA otherwise."""
    if use_pallas:
        bm, bn, bk = blocks or (256, 128, 128)
        gx = p2m_bwd_dx_pallas(g, w, x, coeffs=coeffs, block_m=bm,
                               block_n=bn, block_k=bk, interpret=interpret)
        gw = p2m_bwd_dw_pallas(g, w, x, coeffs=coeffs, block_m=bm,
                               block_n=bn, block_k=bk, interpret=interpret)
        return gx, gw
    return p2m_backward_jnp(g, w, x, coeffs)
