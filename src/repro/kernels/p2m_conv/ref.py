"""Pure-jnp oracle for the P²M non-ideal convolution inner product.

This is the *faithful elementwise* formulation — exactly what the paper's
own PyTorch framework computes (§4.1): every multiply in the im2col matmul
is replaced by the behavioral pixel function ``g``, with the CDS sign
split applied per weight, then the ADC epilogue.

    out[m, n] = epilogue( Σ_k  sign(W[k,n]) · g(|W[k,n]|, X[m,k]) )

It materializes an (chunk, K, N) broadcast product, so it is the slow
oracle used for correctness only; `ops.py` / `kernel.py` hold the fast
basis-decomposed versions.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.adc import ADCConfig, adc_counts, adc_dequant, shifted_relu
from repro.core.pixel_model import PixelModel


def _g_poly(coeffs, w, x):
    """Elementwise ``g(w,x) = Σ_{i,j≥1} a_ij w^i x^j`` in fp32."""
    acc = jnp.zeros(jnp.broadcast_shapes(w.shape, x.shape), dtype=jnp.float32)
    dw, dx = coeffs.shape
    for i in range(1, dw + 1):
        for j in range(1, dx + 1):
            acc = acc + coeffs[i - 1, j - 1] * (w**i) * (x**j)
    return acc


def p2m_matmul_ref(
    x,
    w,
    model: PixelModel,
    shift=None,
    adc: ADCConfig | None = None,
    *,
    quantize: bool = False,
    chunk: int = 128,
):
    """Oracle P²M inner product.

    Args:
      x: (M, K) im2col activation patches, values in [0, 1].
      w: (K, N) signed weights, |w| in [0, 1].
      model: fitted pixel model (polynomial coefficients).
      shift: optional (N,) BN shift term (volts); None ⇒ 0.
      adc: ADC config for the epilogue; None ⇒ raw accumulation returned.
      quantize: if True, run the integer-exact counter path.
      chunk: rows of ``x`` per broadcast block (memory control).

    Returns: (M, N) float32.
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    coeffs = jnp.asarray(model.coeffs, jnp.float32)
    sgn = jnp.sign(w)
    aw = jnp.abs(w)

    outs = []
    for m0 in range(0, x.shape[0], chunk):
        xb = x[m0 : m0 + chunk]  # (c, K)
        # (c, K, N): g(|w|, x) per (patch-element, channel) pair, signed.
        prod = sgn[None, :, :] * _g_poly(coeffs, aw[None, :, :], xb[:, :, None])
        outs.append(prod.sum(axis=1))
    raw = jnp.concatenate(outs, axis=0)

    if adc is None:
        return raw if shift is None else raw + jnp.asarray(shift, jnp.float32)
    s = jnp.zeros((w.shape[1],), jnp.float32) if shift is None else jnp.asarray(shift, jnp.float32)
    if quantize:
        preset = jnp.round(s / adc.v_lsb).astype(jnp.int32)
        return adc_dequant(adc_counts(raw, adc, preset_counts=preset), adc)
    return shifted_relu(raw, s, adc)
