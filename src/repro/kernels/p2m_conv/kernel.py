"""Pallas TPU kernel for the P²M non-ideal convolution (basis-decomposed).

TPU-native formulation (DESIGN.md §2): with the pixel non-ideality fit as
``g(w,x) = Σ_{i,j≥1} a_ij w^i x^j``, the P²M im2col product

    out[m,n] = Σ_k sign(W[k,n]) · g(|W[k,n]|, X[m,k])

factorizes into ``Σ_ij a_ij · (X^∘j) @ (sign(W) ⊙ |W|^∘i)`` — dw·dx MXU
matmuls over elementwise powers.  The kernel tiles (M, N, K) into VMEM
blocks, computes the power expansion *in VMEM* (the powered operands are
never materialized in HBM), accumulates in an fp32 VMEM scratch across the
K grid dimension, and applies the CDS/ADC epilogue (BN shift pre-load,
ReLU clamp at the counter, optional integer-exact quantization) on the
final K step.

Zero padding is exact: every basis term carries a ``w^i x^j`` factor with
i, j ≥ 1, so padded rows/cols contribute exactly 0 to the accumulation.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.p2m_conv.conv import _epilogue_values, ceil_to


def _p2m_kernel(
    x_ref,        # (bm, bk) activation patch tile
    w_ref,        # (bk, bn) signed weight tile
    shift_ref,    # (1, bn) BN shift term (volts)
    *refs,        # out (bm, bn) [, raw (bm, bn)], then acc scratch
    coeffs: Sequence[Sequence[float]],
    nk: int,
    mode: str,
    v_lsb: float,
    max_count: int,
):
    if len(refs) == 3:
        out_ref, raw_ref, acc_ref = refs
    else:
        (out_ref, acc_ref), raw_ref = refs, None
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    sgn = jnp.sign(w)
    aw = jnp.abs(w)

    dw = len(coeffs)
    dx = len(coeffs[0])
    acc = acc_ref[...]
    # Incremental powers: wp_i = |w|^i (sign applied once per dot), xp_j = x^j.
    wp = aw
    for i in range(1, dw + 1):
        wsig = sgn * wp  # sign(w)·|w|^i
        xp = x
        for j in range(1, dx + 1):
            a_ij = coeffs[i - 1][j - 1]
            if a_ij != 0.0:
                acc = acc + a_ij * jax.lax.dot_general(
                    xp,
                    wsig,
                    dimension_numbers=(((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
            if j < dx:
                xp = xp * x
        if i < dw:
            wp = wp * aw
    acc_ref[...] = acc

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        raw = acc_ref[...]
        shift = shift_ref[...].astype(jnp.float32)  # (1, bn), broadcasts
        out = _epilogue_values(raw, shift, mode=mode, v_lsb=v_lsb,
                               max_count=max_count)
        out_ref[...] = out.astype(out_ref.dtype)
        if raw_ref is not None:
            raw_ref[...] = raw


@functools.partial(
    jax.jit,
    static_argnames=(
        "coeffs",
        "mode",
        "v_lsb",
        "max_count",
        "block_m",
        "block_n",
        "block_k",
        "want_raw",
        "interpret",
    ),
)
def p2m_matmul_pallas(
    x,
    w,
    shift,
    *,
    coeffs: tuple,
    mode: str = "relu",
    v_lsb: float = 1.0 / 255.0,
    max_count: int = 255,
    block_m: int = 256,
    block_n: int = 128,
    block_k: int = 128,
    want_raw: bool = False,
    interpret: bool = False,
):
    """Tiled Pallas forward. x: (M, K), w: (K, N), shift: (N,) → (M, N) f32.

    ``want_raw=True`` additionally returns the pre-epilogue accumulation
    (saved as the training residual for the backward mask, `backward.py`).

    VMEM budget per step (fp32 equivalents): x tile bm·bk + w tile bk·bn +
    acc bm·bn + out bm·bn ≈ (256·128 + 128·128 + 2·256·128)·4 B ≈ 0.6 MB —
    comfortably inside the ~16 MB v5e VMEM, leaving room for the pipeline's
    double buffering.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(block_m, ceil_to(m, 8))
    bn = min(block_n, ceil_to(n, 128))
    bk = min(block_k, ceil_to(k, 128))
    mp, np_, kp = ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    sp = jnp.pad(jnp.asarray(shift, x.dtype), (0, np_ - n)).reshape(1, np_)

    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    kernel = functools.partial(
        _p2m_kernel,
        coeffs=coeffs,
        nk=nk,
        mode=mode,
        v_lsb=v_lsb,
        max_count=max_count,
    )
    out_specs = [pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni))]
    out_shapes = [jax.ShapeDtypeStruct((mp, np_), jnp.float32)]
    if want_raw:
        out_specs.append(pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)))
        out_shapes.append(jax.ShapeDtypeStruct((mp, np_), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, sp)
    if want_raw:
        return outs[0][:m, :n], outs[1][:m, :n]
    return outs[0][:m, :n]
