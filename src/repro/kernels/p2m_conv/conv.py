"""Fused implicit-im2col P²M convolution (DESIGN.md §3).

The patch-materializing path (`core.p2m_conv.extract_patches` +
`p2m_matmul`) round-trips a ``(B, P, k·k·C)`` patch tensor through HBM —
a ~``k²/s²`` blow-up of the input for overlapping strides, and an extra
O(input) transpose copy even in the paper's non-overlapping ``s == k``
geometry.  The kernels here take NHWC images directly and gather each
activation tile *in VMEM* via the block index map, so no patch tensor
ever exists in HBM:

* **fast path** (``stride == kernel``): the im2col matrix is a pure
  reshape of the (cropped) image — ``(B·Ho, k, Wo, k·C)`` with the K
  dimension split across the ``k`` kernel rows.  Zero-copy; the grid's
  third dimension walks kernel rows ``dh`` and the block index map picks
  ``A[mi·bh : , dh, :, :]`` straight out of the image.

* **general path** (any ``stride < kernel``): a per-kernel-row band of
  image rows (``k·B·Ho·W·C`` total — ≤ ``k/s``× the input, vs ``k²/s²``×
  for im2col) is streamed through VMEM; the ``k`` sliding windows along W
  are sliced out of the resident band with static strided views.

Both paths share the **basis-premix** tile compute (DESIGN.md §2.3): with
``g(w,x) = Σ_ij a_ij w^i x^j`` the accumulation is

    raw = Σ_j (X^∘j) @ W̃_j,   W̃_j := Σ_i a_ij · sign(W) ⊙ |W|^∘i

``W̃`` is precomputed outside the kernel (it is weight-sized, O(dx·K·N)),
so each grid step issues ONE MXU dot of ``[X, X², …] @ [W̃_1; W̃_2; …]``
instead of dw·dx separate passes.  The CDS/ADC epilogue (BN pre-load
shift, counter ReLU clamp, optional integer-exact quantization) runs on
the final kernel-row step, in VMEM.

`p2m_conv_jnp` is the same decomposition expressed in XLA ops
(differentiable, patch-free) — the CPU/GPU fallback and the autodiff
reference for the Pallas backward kernels in `backward.py`.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def conv_out_spatial(size: int, kernel: int, stride: int) -> int:
    """VALID conv output extent."""
    return (size - kernel) // stride + 1


def ceil_to(x: int, m: int) -> int:
    """Round ``x`` up to a multiple of the tile quantum ``m`` — the one
    copy shared by the forward/backward kernels and the tuner, so padding
    and candidate enumeration can never disagree."""
    return -(-x // m) * m


def premix_weights(w, coeffs) -> jax.Array:
    """Fold the pixel-polynomial w-powers into the weights.

    w: (K, N) signed weights; coeffs: (dw, dx) nested floats.
    Returns W̃ of shape (dx, K, N) with ``W̃[j-1] = Σ_i a_ij sign(w)|w|^i``
    — after this, the P²M product is ``Σ_j X^∘j @ W̃_j`` (DESIGN.md §2.3).
    """
    w = jnp.asarray(w, jnp.float32)
    dw = len(coeffs)
    dx = len(coeffs[0])
    sgn = jnp.sign(w)
    aw = jnp.abs(w)
    pow_i = []  # sign(w)·|w|^i for i = 1..dw
    wp = aw
    for i in range(1, dw + 1):
        pow_i.append(sgn * wp)
        if i < dw:
            wp = wp * aw
    return jnp.stack(
        [
            sum(float(coeffs[i][j]) * pow_i[i] for i in range(dw))
            for j in range(dx)
        ],
        axis=0,
    )


def _power_concat(x, dx: int):
    """[x, x∘x, …, x^∘dx] along the last axis; x is fp32 (bm, kc)."""
    xs = [x]
    xp = x
    for _ in range(dx - 1):
        xp = xp * x
        xs.append(xp)
    return jnp.concatenate(xs, axis=-1) if dx > 1 else x


def _epilogue_values(raw, shift, *, mode: str, v_lsb: float, max_count: int):
    """Shared CDS/ADC epilogue on an fp32 accumulation tile."""
    if mode == "raw":
        return raw + shift
    if mode == "relu":
        return jnp.clip(raw + shift, 0.0, max_count * v_lsb)
    if mode == "quant":
        counts = jnp.round(raw / v_lsb) + jnp.round(shift / v_lsb)
        return jnp.clip(counts, 0.0, float(max_count)) * v_lsb
    raise ValueError(f"unknown mode {mode!r}")


def _accumulate_step(x2d, wmix2d, acc_ref, *, dx: int, first: jax.Array):
    """One grid step: acc += [x, x², …] @ W̃-tile (single MXU dot)."""

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xcat = _power_concat(x2d.astype(jnp.float32), dx)
    acc_ref[...] += jax.lax.dot_general(
        xcat,
        wmix2d.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _write_outputs(shift_ref, out_ref, raw_ref, acc_ref, *, last, mode,
                   v_lsb, max_count):
    @pl.when(last)
    def _epilogue():
        raw = acc_ref[...]
        shift = shift_ref[...].astype(jnp.float32)  # (1, bn), broadcasts
        out = _epilogue_values(raw, shift, mode=mode, v_lsb=v_lsb,
                               max_count=max_count)
        out_ref[...] = out.reshape(out_ref.shape).astype(out_ref.dtype)
        if raw_ref is not None:
            raw_ref[...] = raw.reshape(raw_ref.shape)


def _conv_kernel_fast(a_ref, wmix_ref, shift_ref, *refs, k: int, dx: int,
                      mode: str, v_lsb: float, max_count: int):
    """stride == kernel: a_ref is (bh, 1, Wo, kC) — a zero-copy image view."""
    out_ref, raw_ref, acc_ref = _split_refs(refs)
    ki = pl.program_id(2)
    bh, _, wo, kc = a_ref.shape
    x2d = a_ref[...].reshape(bh * wo, kc)
    wmix2d = wmix_ref[...].reshape(wmix_ref.shape[1], wmix_ref.shape[2])
    _accumulate_step(x2d, wmix2d, acc_ref, dx=dx, first=ki == 0)
    _write_outputs(shift_ref, out_ref, raw_ref, acc_ref, last=ki == k - 1,
                   mode=mode, v_lsb=v_lsb, max_count=max_count)


def _conv_kernel_general(band_ref, wmix_ref, shift_ref, *refs, k: int,
                         stride: int, wo: int, dx: int, mode: str,
                         v_lsb: float, max_count: int):
    """General strided case: band_ref is (1, bh, Wpad, C) — one kernel-row
    band of image rows; the k sliding windows are sliced out in VMEM."""
    out_ref, raw_ref, acc_ref = _split_refs(refs)
    ki = pl.program_id(2)
    _, bh, wpad, c = band_ref.shape
    band = band_ref[...].reshape(bh, wpad, c)
    # Strided window gather, entirely on the VMEM-resident band: for each
    # in-row kernel offset dw, rows ow·s + dw for ow ∈ [0, Wo).
    parts = []
    for dw in range(k):
        win = band[:, dw : dw + wo * stride, :]
        parts.append(win.reshape(bh, wo, stride, c)[:, :, 0, :])
    x = jnp.stack(parts, axis=2)  # (bh, Wo, k, C) — (dw, c) fastest-varying
    x2d = x.reshape(bh * wo, k * c)
    wmix2d = wmix_ref[...].reshape(wmix_ref.shape[1], wmix_ref.shape[2])
    _accumulate_step(x2d, wmix2d, acc_ref, dx=dx, first=ki == 0)
    _write_outputs(shift_ref, out_ref, raw_ref, acc_ref, last=ki == k - 1,
                   mode=mode, v_lsb=v_lsb, max_count=max_count)


def _split_refs(refs):
    """(out, acc) or (out, raw, acc) depending on want_raw."""
    if len(refs) == 2:
        out_ref, acc_ref = refs
        return out_ref, None, acc_ref
    out_ref, raw_ref, acc_ref = refs
    return out_ref, raw_ref, acc_ref


# ---------------------------------------------------------------------------
# Pipelined (manual double-buffered DMA) kernel bodies — DESIGN.md §3.5
# ---------------------------------------------------------------------------
#
# The grid-path kernels above lean on Pallas's automatic pipeline, which
# double-buffers every operand uniformly.  The pipelined variants below
# take the activation/weight arrays as HBM-resident (`memory_space=ANY`)
# refs and stream the per-kernel-row tiles into an explicit `depth`-slot
# VMEM ring with `pltpu.make_async_copy`: while kernel row ``ki`` is on
# the MXU, rows ``ki+1 … ki+depth-1`` are already in flight HBM→VMEM —
# the Helium-guide prefetch discipline, with depth as a tunable knob
# (autotuner axis, `tune.py`).  The k-loop is unrolled in Python (k ≤ 7
# in every supported geometry), so slot indices are static and the same
# body lowers identically under interpret mode.
#
# Accumulation order is identical to the grid path (zeros, then one
# ``[x, x², …] @ W̃[ki]`` add per kernel row, ki ascending), so outputs
# are bitwise-identical to the non-pipelined kernel — pinned by test and
# gated at 1.0 in the bench.


def _pipelined_body(x_tile_2d, wbuf, shift_ref, out_ref, raw_ref, *, k: int,
                    depth: int, dx: int, mode: str, v_lsb: float,
                    max_count: int, x_dma, w_dma):
    """Shared ring-buffer driver: ``x_tile_2d(slot) -> (rows, kC)`` view of
    the x ring slot; ``x_dma/w_dma(slot, ki)`` build the async copies."""
    nbuf = min(depth, k)
    for ki in range(nbuf):  # warm-up: fill the ring
        x_dma(ki, ki).start()
        w_dma(ki, ki).start()
    acc = None
    for ki in range(k):
        slot = ki % nbuf
        x_dma(slot, ki).wait()
        w_dma(slot, ki).wait()
        xcat = _power_concat(x_tile_2d(slot).astype(jnp.float32), dx)
        term = jax.lax.dot_general(
            xcat,
            wbuf[slot].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # Same fp-add order as the grid path's (init-zeros, then +=).
        acc = term if ki == 0 else acc + term
        nxt = ki + nbuf
        if nxt < k:  # refill the slot we just drained
            x_dma(slot, nxt).start()
            w_dma(slot, nxt).start()
    shift = shift_ref[...].astype(jnp.float32)  # (1, bn), broadcasts
    out = _epilogue_values(acc, shift, mode=mode, v_lsb=v_lsb,
                           max_count=max_count)
    out_ref[...] = out.reshape(out_ref.shape).astype(out_ref.dtype)
    if raw_ref is not None:
        raw_ref[...] = acc.reshape(raw_ref.shape)


def _conv_kernel_fast_pipelined(a_hbm, wmix_hbm, shift_ref, *refs, k: int,
                                depth: int, bh: int, bn: int, wo: int,
                                kc: int, dx: int, mode: str, v_lsb: float,
                                max_count: int):
    """stride == kernel, manual pipeline: a_hbm is the whole (mh, k, Wo,
    kC) image view in HBM; tile (mi, ki) streams into the x ring."""
    out_ref, raw_ref = (refs[0], refs[1]) if len(refs) == 6 else (refs[0], None)
    xbuf, wbuf, xsem, wsem = refs[-4:]
    mi, ni = pl.program_id(0), pl.program_id(1)

    def x_dma(slot, ki):
        return pltpu.make_async_copy(
            a_hbm.at[pl.ds(mi * bh, bh), ki], xbuf.at[slot], xsem.at[slot])

    def w_dma(slot, ki):
        return pltpu.make_async_copy(
            wmix_hbm.at[ki, :, pl.ds(ni * bn, bn)], wbuf.at[slot],
            wsem.at[slot])

    _pipelined_body(lambda slot: xbuf[slot].reshape(bh * wo, kc), wbuf,
                    shift_ref, out_ref, raw_ref, k=k, depth=depth, dx=dx,
                    mode=mode, v_lsb=v_lsb, max_count=max_count,
                    x_dma=x_dma, w_dma=w_dma)


def _conv_kernel_general_pipelined(rows_hbm, wmix_hbm, shift_ref, *refs,
                                   k: int, stride: int, depth: int, bh: int,
                                   bn: int, wo: int, dx: int, mode: str,
                                   v_lsb: float, max_count: int):
    """General stride, manual pipeline: rows_hbm is the (k, mh, Wband, C)
    kernel-row band stack in HBM; band (ki, mi) streams into the x ring
    and the k sliding windows are sliced out of the VMEM-resident slot."""
    out_ref, raw_ref = (refs[0], refs[1]) if len(refs) == 6 else (refs[0], None)
    xbuf, wbuf, xsem, wsem = refs[-4:]
    mi, ni = pl.program_id(0), pl.program_id(1)
    c = rows_hbm.shape[-1]

    def x_dma(slot, ki):
        return pltpu.make_async_copy(
            rows_hbm.at[ki, pl.ds(mi * bh, bh)], xbuf.at[slot],
            xsem.at[slot])

    def w_dma(slot, ki):
        return pltpu.make_async_copy(
            wmix_hbm.at[ki, :, pl.ds(ni * bn, bn)], wbuf.at[slot],
            wsem.at[slot])

    def x_tile_2d(slot):
        band = xbuf[slot]  # (bh, Wband, C), resident
        parts = []
        for dw in range(k):
            win = band[:, dw : dw + wo * stride, :]
            parts.append(win.reshape(bh, wo, stride, c)[:, :, 0, :])
        x = jnp.stack(parts, axis=2)  # (bh, Wo, k, C)
        return x.reshape(bh * wo, k * c)

    _pipelined_body(x_tile_2d, wbuf, shift_ref, out_ref, raw_ref, k=k,
                    depth=depth, dx=dx, mode=mode, v_lsb=v_lsb,
                    max_count=max_count, x_dma=x_dma, w_dma=w_dma)





def default_conv_blocks(b: int, ho: int, wo: int, n: int,
                        kc_dx: int) -> tuple[int, int]:
    """(block_h, block_n) heuristic: bh·Wo ≈ 2048 rows per tile, full-N
    blocks up to 128 — see DESIGN.md §3.3 for the VMEM budget math."""
    bh = max(1, min(b * ho, max(1, 2048 // max(wo, 1))))
    bn = min(128, ceil_to(n, 128))
    return bh, bn


@functools.partial(
    jax.jit,
    static_argnames=("kernel", "stride", "coeffs", "mode", "v_lsb",
                     "max_count", "block_h", "block_n", "want_raw",
                     "interpret", "pipeline_depth"),
)
def p2m_conv_pallas(
    images,
    w,
    shift,
    *,
    kernel: int,
    stride: int,
    coeffs: tuple,
    mode: str = "relu",
    v_lsb: float = 1.0 / 255.0,
    max_count: int = 255,
    block_h: int | None = None,
    block_n: int | None = None,
    want_raw: bool = False,
    interpret: bool = False,
    pipeline_depth: int = 0,
):
    """Fused P²M conv: NHWC images in, (B, Ho, Wo, N) activations out.

    images: (B, H, W, C) in [0, 1]; w: (k·k·C, N) signed flat weights with
    (kh, kw, C) fastest-varying K order (the `extract_patches` layout);
    shift: (N,) BN counter pre-load in volts.

    ``want_raw=True`` additionally returns the pre-epilogue accumulation
    (the training residual for the backward mask — see `backward.py`).

    ``pipeline_depth``: 0 uses the grid-path kernels (Pallas's automatic
    pipeline); ≥ 2 switches to the manual double-buffered kernels, which
    stream the next ``depth-1`` input/weight kernel-row tiles HBM→VMEM
    while the current tile is on the MXU (DESIGN.md §3.5) — an autotuner
    axis (`tune.py`).  Outputs are bitwise-identical either way.

    VMEM per step (fp32 words): x-tile ``bh·Wo·dx·kC`` (power concat) +
    W̃-tile ``dx·kC·bn`` + acc/out ``2·bh·Wo·bn``.  At the paper geometry
    (Wo=112, kC=75, dx=3, bh=8, bn=128) that is ≈ 1.3 MB — double-buffered
    comfortably inside the ~16 MB v5e VMEM (DESIGN.md §3.3; the manual
    path charges ``depth ×`` the streamed tiles explicitly).
    """
    if pipeline_depth == 1 or pipeline_depth < 0:
        raise ValueError("pipeline_depth must be 0 (grid path) or >= 2 "
                         f"(double-buffered ring), got {pipeline_depth}")
    b, h, w_dim, c = images.shape
    k, s = kernel, stride
    ho = conv_out_spatial(h, k, s)
    wo = conv_out_spatial(w_dim, k, s)
    kc = k * c
    kk = k * k * c
    assert w.shape[0] == kk, (w.shape, kk)
    n = w.shape[1]
    dx = len(coeffs[0])

    # Host-side (XLA) weight prep: O(dx·K·N), weight-sized.
    wmix = premix_weights(w, coeffs)  # (dx, K, N)
    # Per-kernel-row layout: (k, dx·kC, N), rows ordered (j, dw, c) to match
    # the kernel's power-concat column order.
    wmix = wmix.reshape(dx, k, kc, n).transpose(1, 0, 2, 3).reshape(
        k, dx * kc, n)

    bh_default, bn_default = default_conv_blocks(b, ho, wo, n, dx * kc)
    bh = min(block_h or bh_default, b * ho)
    bn = min(block_n or bn_default, ceil_to(n, 128))

    mh = b * ho
    mh_pad = ceil_to(mh, bh)
    n_pad = ceil_to(n, bn)

    wmix = jnp.pad(wmix, ((0, 0), (0, 0), (0, n_pad - n)))
    sp = jnp.pad(jnp.asarray(shift, jnp.float32), (0, n_pad - n)).reshape(
        1, n_pad)

    common = dict(mode=mode, v_lsb=v_lsb, max_count=max_count)
    pipelined = pipeline_depth >= 2
    if s == k:
        # Zero-copy implicit im2col: crop the valid region and view it as
        # (B·Ho, k, Wo, k·C); the grid's k-dimension walks kernel rows.
        a = images[:, : ho * k, : wo * k, :].reshape(mh, k, wo, kc)
        x_arr = jnp.pad(a, ((0, mh_pad - mh), (0, 0), (0, 0), (0, 0)))
        if pipelined:
            kernel_fn = functools.partial(
                _conv_kernel_fast_pipelined, k=k, depth=pipeline_depth,
                bh=bh, bn=bn, wo=wo, kc=kc, dx=dx, **common)
            x_tile_shape = (bh, wo, kc)
        else:
            kernel_fn = functools.partial(_conv_kernel_fast, k=k, dx=dx,
                                          **common)
            x_spec = pl.BlockSpec((bh, 1, wo, kc),
                                  lambda mi, ni, ki: (mi, ki, 0, 0))
    else:
        # Kernel-row band stack: (k, B·Ho, Wpad, C) — ≤ k/s × the input.
        rows = jnp.stack(
            [images[:, dh : dh + (ho - 1) * s + 1 : s, :, :]
             for dh in range(k)],
            axis=0,
        ).reshape(k, mh, w_dim, c)
        w_band = wo * s + k  # every dw window slice stays in-bounds
        x_arr = jnp.pad(rows, ((0, 0), (0, mh_pad - mh),
                               (0, w_band - w_dim), (0, 0)))
        if pipelined:
            kernel_fn = functools.partial(
                _conv_kernel_general_pipelined, k=k, stride=s,
                depth=pipeline_depth, bh=bh, bn=bn, wo=wo, dx=dx, **common)
            x_tile_shape = (bh, w_band, c)
        else:
            kernel_fn = functools.partial(_conv_kernel_general, k=k,
                                          stride=s, wo=wo, dx=dx, **common)
            x_spec = pl.BlockSpec((1, bh, w_band, c),
                                  lambda mi, ni, ki: (ki, mi, 0, 0))

    if pipelined:
        # 2-D grid: the kernel-row loop (and its HBM→VMEM streaming) lives
        # inside the kernel as an explicit depth-slot ring (DESIGN.md §3.5).
        nbuf = min(pipeline_depth, k)
        grid = (mh_pad // bh, n_pad // bn)
        out_shapes = [jax.ShapeDtypeStruct((mh_pad, wo, n_pad), jnp.float32)]
        out_specs = [pl.BlockSpec((bh, wo, bn), lambda mi, ni: (mi, 0, ni))]
        if want_raw:
            out_shapes.append(jax.ShapeDtypeStruct((mh_pad, wo, n_pad),
                                                   jnp.float32))
            out_specs.append(
                pl.BlockSpec((bh, wo, bn), lambda mi, ni: (mi, 0, ni)))
        outs = pl.pallas_call(
            kernel_fn,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((1, bn), lambda mi, ni: (0, ni)),
            ],
            out_specs=out_specs,
            out_shape=out_shapes,
            scratch_shapes=[
                pltpu.VMEM((nbuf,) + x_tile_shape, jnp.float32),
                pltpu.VMEM((nbuf, dx * kc, bn), jnp.float32),
                pltpu.SemaphoreType.DMA((nbuf,)),
                pltpu.SemaphoreType.DMA((nbuf,)),
            ],
            interpret=interpret,
        )(x_arr, wmix, sp)
    else:
        grid = (mh_pad // bh, n_pad // bn, k)
        out_shapes = [jax.ShapeDtypeStruct((mh_pad, wo, n_pad), jnp.float32)]
        out_specs = [pl.BlockSpec((bh, wo, bn),
                                  lambda mi, ni, ki: (mi, 0, ni))]
        if want_raw:
            out_shapes.append(jax.ShapeDtypeStruct((mh_pad, wo, n_pad),
                                                   jnp.float32))
            out_specs.append(
                pl.BlockSpec((bh, wo, bn), lambda mi, ni, ki: (mi, 0, ni)))
        outs = pl.pallas_call(
            kernel_fn,
            grid=grid,
            in_specs=[
                x_spec,
                pl.BlockSpec((1, dx * kc, bn), lambda mi, ni, ki: (ki, 0, ni)),
                pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
            ],
            out_specs=out_specs,
            out_shape=out_shapes,
            scratch_shapes=[pltpu.VMEM((bh * wo, bn), jnp.float32)],
            interpret=interpret,
        )(x_arr, wmix, sp)

    def _unpad(o):
        return o[:mh, :, :n].reshape(b, ho, wo, n)

    if want_raw:
        return _unpad(outs[0]), _unpad(outs[1])
    return _unpad(outs[0])


def im2col_slices(images, kernel: int, stride: int):
    """Per-kernel-row im2col slices, without materializing the patch tensor.

    Yields k arrays of shape (M, k·C) — each a (strided-)sliced view the
    compiler can fuse; at ``stride == kernel`` they are pure reshapes.
    """
    b, h, w_dim, c = images.shape
    k, s = kernel, stride
    ho = conv_out_spatial(h, k, s)
    wo = conv_out_spatial(w_dim, k, s)
    m = b * ho * wo
    if s == k:
        a = images[:, : ho * k, : wo * k, :].reshape(b * ho, k, wo, k * c)
        for dh in range(k):
            yield a[:, dh].reshape(m, k * c)
        return
    # General stride: same row-band structure as the Pallas kernel — one
    # strided row gather per dh, then contiguous slice + reshape-subsample
    # for the k in-row windows (cheaper than k strided gathers).
    w_band = wo * s + k
    for dh in range(k):
        rows = images[:, dh : dh + (ho - 1) * s + 1 : s, :, :]  # (B,Ho,W,C)
        rows = jnp.pad(rows, ((0, 0), (0, 0), (0, w_band - w_dim), (0, 0)))
        cols = [rows[:, :, dw : dw + wo * s, :]
                .reshape(b, ho, wo, s, c)[:, :, :, 0, :]
                for dw in range(k)]
        x = jnp.stack(cols, axis=3)  # (B, Ho, Wo, k, C)
        yield x.reshape(m, k * c)


def im2col_matrix(images, kernel: int, stride: int):
    """Materialized (M, k·k·C) im2col matrix, (kh, kw, C) fastest-varying.

    Built from `im2col_slices`, so at ``stride == kernel`` the only data
    movement is the final concat.  Used by the backward pass (which needs
    X for the power factors) and as a fallback patch extractor; the fused
    forward never calls this.
    """
    return jnp.concatenate(list(im2col_slices(images, kernel, stride)),
                           axis=1)


def p2m_conv_raw_jnp(images, w, *, kernel: int, stride: int, coeffs):
    """Pre-epilogue fused conv accumulation in XLA (differentiable).

    Same basis-premix decomposition as the Pallas kernel — one
    ``(M, dx·kC) @ (dx·kC, N)`` contraction per kernel row, never a
    ``(M, k²C)`` patch tensor.
    """
    k, c = kernel, images.shape[-1]
    kc = k * c
    n = w.shape[1]
    dx = len(coeffs[0])
    wmix = premix_weights(w, coeffs)  # (dx, K, N)
    wmix = wmix.reshape(dx, k, kc, n).transpose(1, 0, 2, 3).reshape(
        k, dx * kc, n)
    raw = None
    for dh, x in enumerate(im2col_slices(images, kernel, stride)):
        xcat = _power_concat(x.astype(jnp.float32), dx)
        term = xcat @ wmix[dh]
        raw = term if raw is None else raw + term
    return raw  # (M, N)


def p2m_conv_jnp(images, w, shift, *, kernel: int, stride: int, coeffs,
                 mode: str = "relu", v_lsb: float = 1.0 / 255.0,
                 max_count: int = 255):
    """XLA fused conv: same contract as `p2m_conv_pallas`, differentiable."""
    b, h, w_dim, _ = images.shape
    ho = conv_out_spatial(h, kernel, stride)
    wo = conv_out_spatial(w_dim, kernel, stride)
    raw = p2m_conv_raw_jnp(images, w, kernel=kernel, stride=stride,
                           coeffs=coeffs)
    shift = jnp.asarray(shift, jnp.float32)
    out = _epilogue_values(raw, shift, mode=mode, v_lsb=v_lsb,
                           max_count=max_count)
    return out.reshape(b, ho, wo, w.shape[1])
