"""Jit'd wrappers for the P²M inner product and the fused P²M conv.

Tiers, all computing the same math (see `ref.py` for the oracle):

* :func:`p2m_matmul_jnp` — basis-decomposed XLA version (dw·dx matmuls)
  on pre-extracted im2col patches, fully differentiable through autodiff.
  The reference fallback.
* :func:`p2m_matmul` — Pallas kernel forward (VMEM-fused power expansion
  + epilogue) on patches, with a custom VJP whose backward runs the
  closed-form premixed kernels in `backward.py` (Pallas on TPU, XLA
  closed form elsewhere) instead of re-differentiating the forward.
* :func:`p2m_conv` — the fused implicit-im2col convolution (`conv.py`):
  NHWC images in, no HBM patch tensor, same custom-VJP treatment.  The
  hot path for both training and deployment.
* mode="quant" uses an STE backward (gradient of the soft-clipped path).

Forward Pallas calls route their block sizes through the autotuner
(`tune.py`; off-TPU it returns the static defaults instantly), and the
backward kernels reuse the forward winner for the same (M, K, N)
signature — the tile dims are driven by the same operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.pixel_model import PixelModel
from repro.kernels.p2m_conv import tune
from repro.kernels.p2m_conv.backward import (
    epilogue_mask,
    p2m_backward,
    p2m_backward_jnp,
)
from repro.kernels.p2m_conv.conv import (
    _epilogue_values,
    conv_out_spatial,
    im2col_matrix,
    p2m_conv_jnp as _conv_jnp_impl,
    p2m_conv_pallas,
)
from repro.kernels.p2m_conv.kernel import p2m_matmul_pallas

_DEFAULT_ADC = ADCConfig()


def _coeff_tuple(model: PixelModel) -> tuple:
    return tuple(tuple(float(v) for v in row) for row in model.coeffs)


def _resolve_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _use_pallas_bwd(bwd_impl: str | None, interpret: bool) -> bool:
    """Backward dispatch: Pallas kernels on TPU, closed-form XLA off-TPU
    (timing interpret-mode kernels in the train loop would be absurd);
    ``bwd_impl`` in {"pallas", "jnp"} forces either — tests force "pallas"
    with interpret=True to cover the kernels everywhere."""
    if bwd_impl is not None:
        return bwd_impl == "pallas"
    return not interpret


def p2m_matmul_jnp(x, w, shift, model: PixelModel, adc: ADCConfig | None = None,
                   mode: str = "relu"):
    """Basis-decomposed P²M product in plain jnp (differentiable).

    x: (M, K) in [0,1]; w: (K, N) signed; shift: (N,) volts.
    mode: "raw" (accumulation + shift), "relu" (shifted ReLU with full-scale
    saturation), "quant" (integer-exact counter emulation, STE-friendly
    only through :func:`p2m_matmul`).
    """
    adc = adc or _DEFAULT_ADC
    coeffs = model.coeffs
    dw, dx = coeffs.shape
    x32 = x.astype(jnp.float32)
    sgn = jnp.sign(w).astype(jnp.float32)
    aw = jnp.abs(w).astype(jnp.float32)

    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    wp = aw
    for i in range(1, dw + 1):
        wsig = sgn * wp
        xp = x32
        for j in range(1, dx + 1):
            a_ij = float(coeffs[i - 1, j - 1])
            if a_ij != 0.0:
                acc = acc + a_ij * (xp @ wsig)
            if j < dx:
                xp = xp * x32
        if i < dw:
            wp = wp * aw
    return _epilogue_jnp(acc, shift, adc, mode)


def _epilogue_jnp(acc, shift, adc: ADCConfig, mode: str):
    # Single source of truth for the epilogue semantics (conv.py) — the
    # Pallas kernels run the same function inside VMEM.
    return _epilogue_values(acc, jnp.asarray(shift, jnp.float32),
                            mode=mode, v_lsb=adc.v_lsb,
                            max_count=adc.max_count)


# ---------------------------------------------------------------------------
# Patch-level Pallas op with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def p2m_matmul(x, w, shift, model: PixelModel, adc: ADCConfig | None = None,
               mode: str = "relu", interpret: bool | None = None,
               bwd_impl: str | None = None):
    """Pallas-kernel P²M product; differentiable via custom VJP.

    ``interpret=None`` auto-selects interpret mode off-TPU (the kernel body
    then runs as reference Python, validating the TPU lowering path).
    ``bwd_impl`` forces the backward implementation ("pallas" | "jnp");
    None auto-selects like the forward.
    """
    return _matmul_fwd_only(x, w, shift, model, adc, mode, interpret)


def _matmul_fwd_only(x, w, shift, model, adc, mode, interpret,
                     want_raw: bool = False):
    adc = adc or _DEFAULT_ADC
    interpret = _resolve_interpret(interpret)
    coeffs = _coeff_tuple(model)
    bm, bn, bk = tune.get_matmul_blocks(x.shape[0], x.shape[1], w.shape[1],
                                        coeffs, mode, interpret=interpret)
    return p2m_matmul_pallas(
        x,
        w,
        shift,
        coeffs=coeffs,
        mode=mode,
        v_lsb=adc.v_lsb,
        max_count=adc.max_count,
        block_m=bm,
        block_n=bn,
        block_k=bk,
        want_raw=want_raw,
        interpret=interpret,
    )


def _p2m_fwd(x, w, shift, model, adc, mode, interpret, bwd_impl):
    out, raw = _matmul_fwd_only(x, w, shift, model, adc, mode, interpret,
                                want_raw=True)
    return out, (x, w, shift, raw)


def _p2m_bwd(model, adc, mode, interpret, bwd_impl, res, g):
    x, w, shift, raw = res
    adc = adc or _DEFAULT_ADC
    interpret = _resolve_interpret(interpret)
    coeffs = _coeff_tuple(model)
    mask = epilogue_mask(raw, shift, mode=mode, full_scale=adc.full_scale)
    g_eff = g.astype(jnp.float32) * mask
    # Reuse the forward-tuned blocks (cache hit — the fwd ran first).
    blocks = tune.get_matmul_blocks(x.shape[0], x.shape[1], w.shape[1],
                                    coeffs, mode, interpret=interpret)
    gx, gw = p2m_backward(g_eff, w, x, coeffs,
                          use_pallas=_use_pallas_bwd(bwd_impl, interpret),
                          interpret=interpret, blocks=blocks)
    gs = g_eff.sum(axis=0)
    return (gx.astype(x.dtype), gw.astype(w.dtype),
            gs.astype(jnp.asarray(shift).dtype))


p2m_matmul.defvjp(_p2m_fwd, _p2m_bwd)


# ---------------------------------------------------------------------------
# Fused implicit-im2col conv op with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def p2m_conv(images, w, shift, model: PixelModel,
             adc: ADCConfig | None = None, mode: str = "relu",
             kernel: int = 5, stride: int = 5,
             interpret: bool | None = None, bwd_impl: str | None = None,
             pipeline_depth: int | None = None):
    """Fused P²M convolution: (B, H, W, C) images → (B, Ho, Wo, N).

    Forward is the implicit-im2col Pallas kernel (`conv.py`) — no HBM
    patch tensor in either the ``stride == kernel`` fast path (zero-copy
    image view) or the general strided path (per-kernel-row VMEM bands).

    Backward runs the premixed closed-form kernels (`backward.py`); the
    col2im scatter back to image space is a pure reshape at
    ``stride == kernel`` and an XLA scatter-add otherwise.

    ``pipeline_depth`` overrides the autotuner's depth axis (DESIGN.md
    §3.5): ``None`` defers to the tuned winner, 0 forces the automatic
    grid pipeline, ≥2 forces the explicit double-buffered DMA ring —
    tests and benches pin both to prove parity.
    """
    return _conv_fwd_only(images, w, shift, model, adc, mode, kernel,
                          stride, interpret, pipeline_depth=pipeline_depth)


def _conv_fwd_only(images, w, shift, model, adc, mode, kernel, stride,
                   interpret, want_raw: bool = False,
                   pipeline_depth: int | None = None):
    adc = adc or _DEFAULT_ADC
    interpret = _resolve_interpret(interpret)
    coeffs = _coeff_tuple(model)
    b, h, w_dim, c = images.shape
    bh, bn, depth = tune.get_conv_blocks(b, h, w_dim, c, w.shape[1], kernel,
                                         stride, coeffs, mode,
                                         interpret=interpret)
    if pipeline_depth is not None:
        depth = pipeline_depth
    return p2m_conv_pallas(
        images,
        w,
        shift,
        kernel=kernel,
        stride=stride,
        coeffs=coeffs,
        mode=mode,
        v_lsb=adc.v_lsb,
        max_count=adc.max_count,
        block_h=bh,
        block_n=bn,
        pipeline_depth=depth,
        want_raw=want_raw,
        interpret=interpret,
    )


def p2m_conv_jnp(images, w, shift, model: PixelModel,
                 adc: ADCConfig | None = None, mode: str = "relu",
                 kernel: int = 5, stride: int = 5):
    """Fused conv in XLA ops (differentiable; patch-free) — the off-TPU
    twin of :func:`p2m_conv` and its autodiff reference."""
    adc = adc or _DEFAULT_ADC
    return _conv_jnp_impl(images, w, shift, kernel=kernel, stride=stride,
                          coeffs=_coeff_tuple(model), mode=mode,
                          v_lsb=adc.v_lsb, max_count=adc.max_count)


def _conv_fwd(images, w, shift, model, adc, mode, kernel, stride, interpret,
              bwd_impl, pipeline_depth):
    out, raw = _conv_fwd_only(images, w, shift, model, adc, mode, kernel,
                              stride, interpret, want_raw=True,
                              pipeline_depth=pipeline_depth)
    return out, (images, w, shift, raw)


def _conv_bwd(model, adc, mode, kernel, stride, interpret, bwd_impl,
              pipeline_depth, res, g):
    images, w, shift, raw = res
    adc = adc or _DEFAULT_ADC
    interpret = _resolve_interpret(interpret)
    coeffs = _coeff_tuple(model)
    n = w.shape[1]
    m = raw.shape[0] * raw.shape[1] * raw.shape[2]

    raw2d = raw.reshape(m, n)
    mask = epilogue_mask(raw2d, shift, mode=mode, full_scale=adc.full_scale)
    g_eff = g.reshape(m, n).astype(jnp.float32) * mask

    # Backward needs X values for the power factors: materialize the patch
    # matrix once (zero-copy reshapes at stride == kernel; a gather
    # otherwise).  Training-only cost — the forward stays patch-free.
    x, im2col_vjp = jax.vjp(
        lambda im: im2col_matrix(im, kernel, stride), images)
    blocks = tune.get_matmul_blocks(x.shape[0], x.shape[1], w.shape[1],
                                    coeffs, mode, interpret=interpret)
    gx, gw = p2m_backward(g_eff, w, x, coeffs,
                          use_pallas=_use_pallas_bwd(bwd_impl, interpret),
                          interpret=interpret, blocks=blocks)
    (gimages,) = im2col_vjp(gx.astype(x.dtype))  # col2im scatter
    gs = g_eff.sum(axis=0)
    return (gimages.astype(images.dtype), gw.astype(w.dtype),
            gs.astype(jnp.asarray(shift).dtype))


p2m_conv.defvjp(_conv_fwd, _conv_bwd)
