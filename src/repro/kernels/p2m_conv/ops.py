"""Jit'd wrappers for the P²M inner product.

Three tiers, all computing the same math (see `ref.py` for the oracle):

* :func:`p2m_matmul_jnp` — basis-decomposed XLA version (dw·dx matmuls),
  fully differentiable.  This is the training workhorse on any backend.
* :func:`p2m_matmul` — Pallas kernel forward (VMEM-fused power expansion +
  epilogue) with a custom VJP whose backward reuses the jnp path, so the
  kernel is trainable.  On CPU the kernel runs in interpret mode.
* mode="quant" uses an STE backward (gradient of the soft-clipped path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.pixel_model import PixelModel
from repro.kernels.p2m_conv.kernel import p2m_matmul_pallas

_DEFAULT_ADC = ADCConfig()


def _coeff_tuple(model: PixelModel) -> tuple:
    return tuple(tuple(float(v) for v in row) for row in model.coeffs)


def p2m_matmul_jnp(x, w, shift, model: PixelModel, adc: ADCConfig | None = None,
                   mode: str = "relu"):
    """Basis-decomposed P²M product in plain jnp (differentiable).

    x: (M, K) in [0,1]; w: (K, N) signed; shift: (N,) volts.
    mode: "raw" (accumulation + shift), "relu" (shifted ReLU with full-scale
    saturation), "quant" (integer-exact counter emulation, STE-friendly
    only through :func:`p2m_matmul`).
    """
    adc = adc or _DEFAULT_ADC
    coeffs = model.coeffs
    dw, dx = coeffs.shape
    x32 = x.astype(jnp.float32)
    sgn = jnp.sign(w).astype(jnp.float32)
    aw = jnp.abs(w).astype(jnp.float32)

    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    wp = aw
    for i in range(1, dw + 1):
        wsig = sgn * wp
        xp = x32
        for j in range(1, dx + 1):
            a_ij = float(coeffs[i - 1, j - 1])
            if a_ij != 0.0:
                acc = acc + a_ij * (xp @ wsig)
            if j < dx:
                xp = xp * x32
        if i < dw:
            wp = wp * aw

    s = jnp.asarray(shift, jnp.float32)
    if mode == "raw":
        return acc + s
    if mode == "relu":
        return jnp.clip(acc + s, 0.0, adc.full_scale)
    if mode == "quant":
        counts = jnp.round(acc / adc.v_lsb) + jnp.round(s / adc.v_lsb)
        return jnp.clip(counts, 0.0, float(adc.max_count)) * adc.v_lsb
    raise ValueError(f"unknown mode {mode!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def p2m_matmul(x, w, shift, model: PixelModel, adc: ADCConfig | None = None,
               mode: str = "relu", interpret: bool | None = None):
    """Pallas-kernel P²M product; differentiable via custom VJP.

    ``interpret=None`` auto-selects interpret mode off-TPU (the kernel body
    then runs as reference Python, validating the TPU lowering path).
    """
    return _fwd_only(x, w, shift, model, adc, mode, interpret)


def _fwd_only(x, w, shift, model, adc, mode, interpret):
    adc = adc or _DEFAULT_ADC
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return p2m_matmul_pallas(
        x,
        w,
        shift,
        coeffs=_coeff_tuple(model),
        mode=mode,
        v_lsb=adc.v_lsb,
        max_count=adc.max_count,
        interpret=bool(interpret),
    )


def _p2m_fwd(x, w, shift, model, adc, mode, interpret):
    out = _fwd_only(x, w, shift, model, adc, mode, interpret)
    return out, (x, w, shift)


def _p2m_bwd(model, adc, mode, interpret, res, g):
    x, w, shift = res
    # Backward = VJP of the jnp path.  "quant" uses the soft-clip ("relu")
    # path as a straight-through estimator.
    bwd_mode = "relu" if mode == "quant" else mode
    _, vjp = jax.vjp(lambda xx, ww, ss: p2m_matmul_jnp(xx, ww, ss, model, adc, bwd_mode),
                     x, w, shift)
    gx, gw, gs = vjp(g.astype(jnp.float32))
    return gx.astype(x.dtype), gw.astype(w.dtype), gs.astype(jnp.asarray(shift).dtype)


p2m_matmul.defvjp(_p2m_fwd, _p2m_bwd)
