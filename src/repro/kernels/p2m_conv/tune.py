"""Block-size autotuner for the P²M kernels (DESIGN.md §5).

Picks ``(block_m, block_n, block_k)`` for `p2m_matmul_pallas` and
``(block_h, block_n, pipeline_depth)`` for `p2m_conv_pallas` by
enumerating the legal candidates under the VMEM budget and timing each
once on synthetic data.  ``pipeline_depth`` is the manual double-buffer
ring of DESIGN.md §3.5: depth 0 lets the automatic grid pipeline stream
(budget charges the implicit ×2 against half VMEM), depth ≥ 2 allocates
``depth ×`` explicit input+weight slot buffers, so the budget charges
those buffers directly (DESIGN.md §3.3).

Cache semantics: winners are memoized **per signature** — the problem
shape, the coefficient table (its nonzero pattern changes the kernel's
instruction mix), the epilogue mode, the **backend** the timing ran on,
and (for conv) the depth axis swept.  A signature is timed at most
once per process; every later call is a dict lookup, so the tuner adds
one-off JIT-warmup-style latency, never steady-state cost.  The cache can
be exported as JSON (`cache_dump`) so benchmark runs can record winners.

Autotuning is **off by default off-TPU** (timing interpret-mode kernels
would measure the Python interpreter): `get_*_blocks` then returns the
static heuristic defaults instantly, and emits a one-time structured log
per (kind, backend) naming the backend and the defaults served.  Set
``REPRO_P2M_AUTOTUNE=1`` (or pass ``enable=True``) to force tuning —
tests do, with toy shapes, to exercise the machinery.
"""
from __future__ import annotations

import json
import logging
import os
import time
from typing import Callable, Iterable

import jax
import numpy as np

from repro.obs.log import structured
from repro.obs.metrics import default_registry

logger = logging.getLogger(__name__)

# Half of a v5e core's ~16 MB VMEM, leaving the other half for the
# pipeline's double buffering (DESIGN.md §3.3).
VMEM_BUDGET_BYTES = 8 * 2**20

# Pipeline depths swept for the conv kernel: 0 = automatic grid pipeline,
# ≥2 = explicit DMA ring with that many slot buffers (depth 1 would stall
# every step and is rejected by the kernel).
CONV_PIPELINE_DEPTHS: tuple[int, ...] = (0, 2, 3)

_CACHE: dict[tuple, dict] = {}

# One-time "autotune disabled, serving defaults" notices, per (kind, backend).
_DISABLED_LOGGED: set[tuple[str, str]] = set()


def _log_disabled_defaults(kind: str, backend: str, default) -> None:
    """One-shot notice (per kind × backend) that the static defaults are
    being served because autotuning is disabled on this backend — routed
    through the stack's structured-logging helper (`obs.log`, DESIGN.md
    §13.4) so the record shares the one machine-parseable schema.  Every
    disabled-default *serve* also counts into the metrics registry
    (``autotune.disabled_default``), one-shot or not."""
    default_registry().counter("autotune.disabled_default").inc()
    token = (kind, backend)
    if token in _DISABLED_LOGGED:
        return
    _DISABLED_LOGGED.add(token)
    structured(
        logger, "p2m_autotune_disabled_defaults",
        kind=kind,
        backend=backend,
        default=list(default),
        hint="set REPRO_P2M_AUTOTUNE=1 or pass enable=True to tune",
    )


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def enabled(enable: bool | None = None) -> bool:
    if enable is not None:
        return enable
    if os.environ.get("REPRO_P2M_AUTOTUNE", "") == "1":
        return True
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Candidate enumeration under the VMEM budget
# ---------------------------------------------------------------------------


def matmul_vmem_bytes(bm: int, bn: int, bk: int, dx: int = 3) -> int:
    """fp32 working set of one `p2m_matmul_pallas` grid step: x tile +
    w tile + acc scratch + out tile (+ the dx-power temps live in
    registers/VPU, bounded by the x tile)."""
    words = bm * bk * dx + bk * bn + 2 * bm * bn
    return 4 * words


def conv_vmem_bytes(bh: int, wo: int, kc: int, bn: int, dx: int = 3,
                    depth: int = 0) -> int:
    """fp32 working set of one `p2m_conv_pallas` grid step (power concat
    dominates the activation side).

    ``depth == 0``: the automatic grid pipeline — one streamed x tile and
    one streamed wmix tile (the implicit ×2 double buffer is what the
    half-VMEM budget leaves room for, DESIGN.md §3.3).  ``depth >= 2``:
    the explicit DMA ring holds ``depth`` raw input-tile slots plus
    ``depth`` premixed-weight slots in VMEM scratch, and those are charged
    directly; the power-concat temp and acc/out tiles ride on top."""
    if depth >= 2:
        streamed = depth * (bh * wo * kc + dx * kc * bn)
    else:
        streamed = bh * wo * kc + dx * kc * bn
    words = streamed + bh * wo * kc * dx + 2 * bh * wo * bn
    return 4 * words


def matmul_candidates(m: int, k: int, n: int, *, dx: int = 3,
                      budget: int = VMEM_BUDGET_BYTES
                      ) -> list[tuple[int, int, int]]:
    """Legal (bm, bn, bk) grid-block shapes, deduped after clamping to the
    (tile-quantum-padded) problem dims."""
    out = []
    seen = set()
    for bm in (128, 256, 512, 1024):
        for bn in (128, 256):
            for bk in (128, 256, 512):
                cand = (min(bm, _ceil_to(m, 8)), min(bn, _ceil_to(n, 128)),
                        min(bk, _ceil_to(k, 128)))
                if cand in seen:
                    continue
                seen.add(cand)
                if matmul_vmem_bytes(*cand, dx=dx) <= budget:
                    out.append(cand)
    return out


def conv_candidates(b: int, ho: int, wo: int, n: int, kc: int, *, dx: int = 3,
                    depths: tuple[int, ...] = CONV_PIPELINE_DEPTHS,
                    budget: int = VMEM_BUDGET_BYTES
                    ) -> list[tuple[int, int, int]]:
    """Legal (block_h, block_n, pipeline_depth) for the fused conv kernel.
    Depth ≥ 2 candidates charge ``depth ×`` explicit slot buffers against
    the budget, so deep rings are only offered where they fit."""
    out = []
    seen = set()
    for bh in (1, 2, 4, 8, 16, 32, 64):
        for bn in (128, 256):
            for depth in depths:
                cand = (min(bh, b * ho), min(bn, _ceil_to(n, 128)), depth)
                if cand in seen:
                    continue
                seen.add(cand)
                if conv_vmem_bytes(cand[0], wo, kc, cand[1], dx=dx,
                                   depth=depth) <= budget:
                    out.append(cand)
    return out


# ---------------------------------------------------------------------------
# Timing + memoization
# ---------------------------------------------------------------------------


def _time_once(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds, blocking on outputs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _coeff_sig(coeffs) -> tuple:
    return tuple(tuple(float(v) for v in row) for row in coeffs)


def autotune(key: tuple, candidates: Iterable, run: Callable,
             *, iters: int = 3, vmem: Callable | None = None) -> dict:
    """Generic: time `run(candidate)` for each candidate, cache the winner.

    Returns ``{"best": candidate, "timings": {candidate: seconds},
    "decision": record}``.  Failures (e.g. a block shape the backend
    rejects) are recorded as inf and skipped, so one bad candidate never
    kills a tuning pass.

    Observability (DESIGN.md §13.2): every call counts
    ``autotune.cache_hit`` / ``autotune.cache_miss`` into the metrics
    registry, and a miss stores a **decision record** — the candidate
    set with its VMEM charges (``vmem`` maps candidate → bytes), the
    chosen blocks, and the winning time — retrievable via
    :func:`decision_records` and logged as one structured
    ``p2m_autotune_decision`` record.
    """
    if key in _CACHE:
        default_registry().counter("autotune.cache_hit").inc()
        return _CACHE[key]
    default_registry().counter("autotune.cache_miss").inc()
    timings: dict = {}
    for cand in candidates:
        try:
            timings[cand] = _time_once(run, cand, iters=iters)
        except Exception:  # noqa: BLE001 - per-candidate isolation
            timings[cand] = float("inf")
    if not timings or all(np.isinf(list(timings.values()))):
        raise RuntimeError(f"autotune: no viable candidate for {key}")
    best = min(timings, key=timings.get)
    decision = {
        "key": repr(key),
        "kind": key[0] if key and isinstance(key[0], str) else "?",
        "candidates": [list(c) for c in timings],
        "vmem_bytes": ([int(vmem(c)) for c in timings]
                       if vmem is not None else None),
        "best": list(best),
        "best_s": timings[best],
        "n_viable": sum(1 for t in timings.values() if np.isfinite(t)),
    }
    result = {"best": best, "timings": timings, "decision": decision}
    _CACHE[key] = result
    structured(logger, "p2m_autotune_decision",
               kind=decision["kind"], best=decision["best"],
               n_candidates=len(timings), n_viable=decision["n_viable"])
    return result


def decision_records() -> list[dict]:
    """Every autotune decision taken this process (cache misses only —
    a hit serves the recorded decision's winner)."""
    return [v["decision"] for v in _CACHE.values() if "decision" in v]


def get_matmul_blocks(m: int, k: int, n: int, coeffs, mode: str,
                      *, enable: bool | None = None, interpret: bool = False,
                      iters: int = 3) -> tuple[int, int, int]:
    """(block_m, block_n, block_k) for `p2m_matmul_pallas` — tuned when
    enabled, heuristic defaults otherwise."""
    default = (256, 128, 128)
    backend = jax.default_backend()
    # `interpret` and `backend` are part of the key: winners timed in
    # interpret mode (or on another backend) must never be served to
    # compiled calls with the same shape signature.
    key = ("matmul", m, k, n, _coeff_sig(coeffs), mode, bool(interpret),
           backend)
    if key in _CACHE:
        default_registry().counter("autotune.cache_hit").inc()
        return _CACHE[key]["best"]
    if not enabled(enable):
        _log_disabled_defaults("matmul", backend, default)
        return default
    from repro.kernels.p2m_conv.kernel import p2m_matmul_pallas

    rng = np.random.default_rng(0)
    x = jax.numpy.asarray(rng.random((m, k)), jax.numpy.float32)
    w = jax.numpy.asarray(rng.uniform(-1, 1, (k, n)), jax.numpy.float32)
    s = jax.numpy.zeros((n,), jax.numpy.float32)

    def run(cand):
        bm, bn, bk = cand
        return p2m_matmul_pallas(x, w, s, coeffs=_coeff_sig(coeffs),
                                 mode=mode, block_m=bm, block_n=bn,
                                 block_k=bk, interpret=interpret)

    dx = len(coeffs[0])
    cands = matmul_candidates(m, k, n, dx=dx) or [default]
    return autotune(key, cands, run, iters=iters,
                    vmem=lambda c: matmul_vmem_bytes(*c, dx=dx))["best"]


def get_conv_blocks(b: int, h: int, w: int, c: int, n: int, kernel: int,
                    stride: int, coeffs, mode: str, *,
                    enable: bool | None = None, interpret: bool = False,
                    depths: tuple[int, ...] = CONV_PIPELINE_DEPTHS,
                    iters: int = 3
                    ) -> tuple[int | None, int | None, int]:
    """(block_h, block_n, pipeline_depth) for `p2m_conv_pallas` — tuned
    when enabled, ``(None, None, 0)`` otherwise (the kernel's own
    heuristic blocks, automatic grid pipeline)."""
    default = (None, None, 0)
    backend = jax.default_backend()
    # Backend and the swept depth axis are in the key so a winner tuned on
    # one backend (or over a different depth menu) can't leak to another.
    key = ("conv", b, h, w, c, n, kernel, stride, _coeff_sig(coeffs), mode,
           bool(interpret), backend, tuple(depths))
    if key in _CACHE:
        default_registry().counter("autotune.cache_hit").inc()
        return _CACHE[key]["best"]
    if not enabled(enable):
        _log_disabled_defaults("conv", backend, default)
        return default
    from repro.kernels.p2m_conv.conv import conv_out_spatial, p2m_conv_pallas

    ho = conv_out_spatial(h, kernel, stride)
    wo = conv_out_spatial(w, kernel, stride)
    rng = np.random.default_rng(0)
    imgs = jax.numpy.asarray(rng.random((b, h, w, c)), jax.numpy.float32)
    wts = jax.numpy.asarray(
        rng.uniform(-1, 1, (kernel * kernel * c, n)), jax.numpy.float32)
    s = jax.numpy.zeros((n,), jax.numpy.float32)

    def run(cand):
        bh, bn, depth = cand
        return p2m_conv_pallas(imgs, wts, s, kernel=kernel, stride=stride,
                               coeffs=_coeff_sig(coeffs), mode=mode,
                               block_h=bh, block_n=bn,
                               pipeline_depth=depth, interpret=interpret)

    dx = len(coeffs[0])
    kc = kernel * c
    cands = conv_candidates(b, ho, wo, n, kc, dx=dx,
                            depths=tuple(depths)) or [(8, 128, 0)]
    return autotune(key, cands, run, iters=iters,
                    vmem=lambda cd: conv_vmem_bytes(
                        cd[0], wo, kc, cd[1], dx=dx, depth=cd[2]))["best"]


# ---------------------------------------------------------------------------
# Cache management
# ---------------------------------------------------------------------------


def cache_info() -> dict[str, tuple]:
    """{printable-signature: best-blocks} for every tuned entry."""
    return {repr(k): v["best"] for k, v in _CACHE.items()}


def cache_clear() -> None:
    _CACHE.clear()


def cache_dump(path: str) -> None:
    """Persist winners (not timings) as JSON, e.g. from a benchmark run."""
    payload = [
        {"key": list(map(repr, k)), "best": list(v["best"]),
         "timings_s": {repr(c): t for c, t in v["timings"].items()}}
        for k, v in _CACHE.items()
    ]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
