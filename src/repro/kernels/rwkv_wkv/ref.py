"""XLA reference twin of the chunked WKV kernel (DESIGN.md §12.1).

The RWKV-6 recurrence per head (state S is dk × dv, lw = log decay ≤ 0):

    S_t = diag(exp(lw_t)) S_{t-1} + k_t v_tᵀ
    y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)

Chunk-parallel form (GLA-style): within a chunk of C tokens with
cumulative log decays L_t = Σ_{i≤t} lw_i,

    y_t = (r_t ∘ e^{L_{t-1}}) @ S_0                       (inter-chunk)
        + Σ_{s<t} (r_t · e^{L_{t-1}-L_s} ∘ k_s) v_s       (intra, masked)
        + (r_t · u ∘ k_t) v_t                             (bonus diagonal)
    S_C = e^{L_C} ∘ S_0 + Σ_s (e^{L_C-L_s} ∘ k_s) v_sᵀ

The intra term is one masked (C × C) matmul; chunks of ≤16 keep every
exp argument within fp32 range (|ΔL| ≤ 16·5 = 80 < 88, see
``LOG_DECAY_MIN`` in `models/rwkv6.py`).

Zero padding is exact in *both* the sequence tail and the head dim:
padded positions carry lw = 0 (decay e⁰ = 1 — identity on S) and
k = v = r = 0 (no kv outer product, no output contribution), so the
final state of a padded sequence equals the final state of the
unpadded one bit-for-bit — pinned by the property suite in
`tests/test_rwkv_wkv.py`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WKV_CHUNK = 16  # |ΔL| ≤ 16·|LOG_DECAY_MIN| = 80 < 88 ⇒ exp stays finite


def chunk_inputs(r, k, v, lw, chunk: int):
    """Zero-pad S to a chunk multiple and reshape (B,S,H,D) inputs to
    per-chunk scan operands (N, B, C, H, D).  Returns the operands plus
    (n_chunks, pad)."""
    b, s, h, d = r.shape
    pad = (-s) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, lw = z(r), z(k), z(v), z(lw)
    n = r.shape[1] // chunk
    resh = lambda a: a.reshape(b, n, chunk, h, d).transpose(1, 0, 2, 3, 4)
    return resh(r), resh(k), resh(v), resh(lw), n, pad


def unchunk(a, b: int, s: int, h: int, d: int, chunk: int):
    """(N, B, C, H, D) scan outputs back to (B, S, H, D), tail sliced."""
    n = a.shape[0]
    return a.transpose(1, 0, 2, 3, 4).reshape(b, n * chunk, h, d)[:, :s]


def chunk_fwd(s0, rt, kt, vt, lwt, u):
    """One chunk of the chunk-parallel WKV.  rt/kt/vt/lwt (B,C,H,D),
    s0 (B,H,D,D), u (H,D) → (S_C, y (B,C,H,D))."""
    cum = jnp.cumsum(lwt, axis=1)  # L_t (inclusive)
    cum_prev = cum - lwt  # L_{t-1}
    total = cum[:, -1:]  # L_C
    # inter: y_t += (r_t · exp(L_{t-1})) @ S0
    q = rt * jnp.exp(cum_prev)
    y = jnp.einsum("bchd,bhde->bche", q, s0)
    # intra: A[t,s] = Σ_d r_t exp(L_{t-1} − L_s) k_s  (s < t)
    kd = kt * jnp.exp(total - cum)  # k_s · exp(L_C − L_s)
    qd = rt * jnp.exp(cum_prev - total)  # r_t · exp(L_{t-1} − L_C)
    scores = jnp.einsum("bthd,bshd->bhts", qd, kd)
    mask = jnp.tril(jnp.ones((rt.shape[1], rt.shape[1]), bool), -1)
    scores = jnp.where(mask[None, None], scores, 0.0)
    y = y + jnp.einsum("bhts,bshe->bthe", scores, vt)
    # diagonal (bonus u)
    diag = jnp.einsum("bthd,hd,bthd->bth", rt, u, kt)
    y = y + diag[..., None] * vt
    # state: S_C = exp(L_C)·S0 + Σ_s exp(L_C − L_s) k_s v_s
    s_new = jnp.exp(total[:, 0])[..., None] * s0 + jnp.einsum(
        "bshd,bshe->bhde", kd, vt)
    return s_new, y


def wkv_chunked_ref(r, k, v, lw, u, state, chunk: int = WKV_CHUNK):
    """Chunk-parallel WKV in plain XLA (exact vs the per-token scan up
    to fp reassociation).  r/k/v/lw (B,S,H,D) f32; u (H,D);
    state (B,H,D,D) → (y (B,S,H,D), final state)."""
    b, s, h, d = r.shape
    rc, kc, vc, lwc, n, pad = chunk_inputs(r, k, v, lw, chunk)

    def step(s0, inp):
        rt, kt, vt, lwt = inp
        return chunk_fwd(s0, rt, kt, vt, lwt, u)

    state, ys = jax.lax.scan(step, state, (rc, kc, vc, lwc))
    return unchunk(ys, b, s, h, d, chunk), state


def chunk_start_states(k, v, lw, state, chunk: int):
    """Recompute every chunk's *entry* state with a state-only forward
    scan — the cheap residual the closed-form backward needs (`ops.py`).
    Returns (final state, per-chunk entry states (N,B,H,D,D))."""
    _, kc, vc, lwc, _, _ = chunk_inputs(k, k, v, lw, chunk)

    def step(s0, inp):
        kt, vt, lwt = inp
        cum = jnp.cumsum(lwt, axis=1)
        total = cum[:, -1:]
        kd = kt * jnp.exp(total - cum)
        s_new = jnp.exp(total[:, 0])[..., None] * s0 + jnp.einsum(
            "bshd,bshe->bhde", kd, vt)
        return s_new, s0

    return jax.lax.scan(step, state, (kc, vc, lwc))
