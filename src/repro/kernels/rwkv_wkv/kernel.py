"""Pallas TPU kernel for the chunked RWKV-6 WKV scan (DESIGN.md §12.2).

Grid: ``(batch·head, n_chunks)`` with the chunk dimension innermost —
TPU grids execute row-major, so for each (b, h) the chunk steps run
sequentially and the matrix-valued (dk × dv) running state lives in a
VMEM scratch across them: loaded from HBM once at chunk 0, updated in
VMEM every step, written back once at the last chunk.  Per chunk the
body is three MXU matmuls (inter-chunk ``q @ S0``, the strictly-masked
intra-chunk ``(C × C) @ V``, and the rank-C state update ``kdᵀ @ V``)
plus a triangular-matmul cumsum — no lax.cumsum / iota-1D, which Mosaic
does not lower.

Padding is exact (see `ref.py`): the head dim is zero-padded to the
128-lane quantum and the sequence to a chunk multiple; padded positions
carry lw = 0 (identity decay) and r = k = v = 0, so they neither move
the state nor contribute output.  fp32 throughout (`preferred_element_type`
on every dot) — the exp(ΔL) range argument needs fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rwkv_wkv.ref import WKV_CHUNK

_LANES = 128  # TPU lane quantum: last dim of every block padded to this


def ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def _dot(a, b, contract=((1,), (0,))):
    return jax.lax.dot_general(a, b, dimension_numbers=(contract, ((), ())),
                               preferred_element_type=jnp.float32)


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_scr, *, chunk: int, nc: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _load_state():
        s_scr[...] = s0_ref[0]

    rt, kt, vt, lwt = r_ref[0], k_ref[0], v_ref[0], lw_ref[0]  # (C, Dp)
    u = u_ref[...]  # (1, Dp)
    s0 = s_scr[...]  # (Dp, Dp) — running state, persists across chunks

    # Cumulative log decay via a lower-triangular ones matmul (Mosaic has
    # no cumsum primitive; iota must be ≥2D on TPU).
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril_incl = (si <= ti).astype(jnp.float32)
    cum = _dot(tril_incl, lwt)  # L_t (inclusive), (C, Dp)
    cum_prev = cum - lwt
    total = cum[chunk - 1:chunk, :]  # L_C along lanes, (1, Dp)
    # L_C along sublanes for the state decay, (Dp, 1): contraction-over-
    # tokens dot instead of a transpose.
    total_col = _dot(lwt, jnp.ones((chunk, 1), jnp.float32),
                     contract=((0,), (0,)))

    # inter-chunk: y_t += (r_t · exp(L_{t-1})) @ S0
    q = rt * jnp.exp(cum_prev)
    y = _dot(q, s0)
    # intra-chunk: scores[t,s] = Σ_d qd_t kd_s, strictly causal
    kd = kt * jnp.exp(total - cum)
    qd = rt * jnp.exp(cum_prev - total)
    scores = _dot(qd, kd, contract=((1,), (1,)))  # (C, C)
    scores = scores * (si < ti).astype(jnp.float32)
    y = y + _dot(scores, vt)
    # bonus diagonal
    diag = jnp.sum(rt * u * kt, axis=1, keepdims=True)  # (C, 1)
    y_ref[0] = y + diag * vt
    # state update: S_C = exp(L_C) ∘ S0 + kdᵀ @ V
    s_new = jnp.exp(total_col) * s0 + _dot(kd, vt, contract=((0,), (0,)))
    s_scr[...] = s_new

    @pl.when(c_idx == nc - 1)
    def _store_state():
        sout_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, lw, u, state, *, chunk: int = WKV_CHUNK,
               interpret: bool = False):
    """Pallas chunked WKV forward.  r/k/v/lw (B,S,H,D); u (H,D);
    state (B,H,D,D) → (y (B,S,H,D), final state), all fp32."""
    b, s, h, d = r.shape
    bh = b * h
    f32 = lambda a: a.astype(jnp.float32)
    to_bh = lambda a: f32(a).transpose(0, 2, 1, 3).reshape(bh, s, d)
    sp, dp = ceil_to(s, chunk), ceil_to(d, _LANES)
    pad_seq = lambda a: jnp.pad(a, ((0, 0), (0, sp - s), (0, dp - d)))
    rr, kk, vv, ll = (pad_seq(to_bh(a)) for a in (r, k, v, lw))
    s0 = jnp.pad(f32(state).reshape(bh, d, d),
                 ((0, 0), (0, dp - d), (0, dp - d)))
    # u rides per-(b,h) so the grid's flat index needs no modulo: rows
    # repeat [u_0 … u_{H-1}] per batch, matching the (B,H) flatten order.
    uu = jnp.pad(jnp.tile(f32(u), (b, 1)), ((0, 0), (0, dp - d)))

    nc = sp // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, nc=nc)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dp), lambda i, c: (i, c, 0)),  # r
            pl.BlockSpec((1, chunk, dp), lambda i, c: (i, c, 0)),  # k
            pl.BlockSpec((1, chunk, dp), lambda i, c: (i, c, 0)),  # v
            pl.BlockSpec((1, chunk, dp), lambda i, c: (i, c, 0)),  # lw
            pl.BlockSpec((1, dp), lambda i, c: (i, 0)),            # u
            pl.BlockSpec((1, dp, dp), lambda i, c: (i, 0, 0)),     # S_0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dp), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, dp, dp), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((bh, dp, dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dp, dp), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ll, uu, s0)

    y = y[:, :s, :d].reshape(b, h, s, d).transpose(0, 2, 1, 3)
    s_out = s_out[:, :d, :d].reshape(b, h, d, d)
    return y, s_out
