"""Chunked RWKV-6 WKV linear-attention kernel (DESIGN.md §12).

Tiers, all computing the same recurrence (`wkv_naive` in
`models/rwkv6.py` is the per-token oracle):

* :func:`ref.wkv_chunked_ref` — chunk-parallel XLA twin (masked matmul
  against cumulative decays, inter-chunk state through a ``lax.scan``).
  The reference the kernel is pinned to, and the building block the
  closed-form backward reuses.
* :func:`kernel.wkv_pallas` — the Pallas forward: grid over
  (batch·head, sequence chunks) with the matrix-valued (dk × dv)
  running state carried in a VMEM scratch across the sequence grid
  steps.
* :func:`ops.wkv` — the public op: Pallas forward with a closed-form
  chunked VJP registered as ``custom_vjp`` (no forward replay through
  autodiff), interpret-mode fallback off-TPU.
"""
from repro.kernels.rwkv_wkv.ops import wkv
from repro.kernels.rwkv_wkv.ref import wkv_chunked_ref
from repro.kernels.rwkv_wkv.kernel import wkv_pallas

__all__ = ["wkv", "wkv_chunked_ref", "wkv_pallas"]
