"""Public WKV op: Pallas forward + closed-form chunked VJP (DESIGN.md §12.3).

Mirrors `kernels/p2m_conv/ops.py`: the forward runs the Pallas kernel
(interpret mode auto-selected off-TPU), and the registered ``custom_vjp``
backward evaluates the *closed-form* chunked adjoints in XLA instead of
re-differentiating a forward replay.  Residuals are just the inputs —
the backward recomputes each chunk's entry state with a cheap state-only
forward scan, then runs one reverse ``lax.scan`` over chunks carrying
the state adjoint G = ∂L/∂S_C.

Per chunk (derivation in DESIGN.md §12.3; e_prev = e^{L_{t-1}},
e_kd = e^{L_C−L_s}, e_qd = e^{L_{t-1}−L_C}, Pm = strictly-masked dy·vᵀ):

    dv = scoresᵀ@dy + (r·u∘k · dy)            + kd@G
    dr = (dy@S0ᵀ)∘e_prev + (Pm@kd)∘e_qd       + (v·dy) u∘k
    dk = ((Pmᵀ@qd) + v@Gᵀ)∘e_kd               + (v·dy) u∘r
    du = Σ_t (v_t·dy_t) r_t∘k_t
    dS0 = qᵀ@dy + e^{L_C}∘G                    (→ carry to previous chunk)

and the log-decay gradient via the cumulative-sum structure
L_j = Σ_{i≤j} lw_i: the per-position sensitivity g[j] is the r-side
e^{+L_j} terms (shifted: they pair with r_{j+1}) minus the k-side
e^{−L_j} terms, plus Σ_e S_C∘G at j = C−1 (the e^{+L_C} state decay);
dlw_i = Σ_{j≥i} g[j] — a reversed cumsum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_wkv.kernel import wkv_pallas
from repro.kernels.rwkv_wkv.ref import (
    WKV_CHUNK,
    chunk_inputs,
    unchunk,
    wkv_chunked_ref,
)


def _resolve_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _wkv_op(r, k, v, lw, u, state, chunk: int, interpret: bool):
    return wkv_pallas(r, k, v, lw, u, state, chunk=chunk,
                      interpret=interpret)


def _wkv_fwd(r, k, v, lw, u, state, chunk, interpret):
    out = _wkv_op(r, k, v, lw, u, state, chunk, interpret)
    return out, (r, k, v, lw, u, state)


def _wkv_bwd(chunk, interpret, res, cts):
    del interpret  # backward always runs the closed-form XLA adjoints
    r, k, v, lw, u, state = res
    dy, dstate = cts
    b, s, h, d = r.shape
    rc, kc, vc, lwc, n, _ = chunk_inputs(r, k, v, lw, chunk)
    dyc = chunk_inputs(dy, dy, dy, dy, chunk)[0]
    strict = jnp.tril(jnp.ones((chunk, chunk), bool), -1)

    # Residual recompute: every chunk's entry state (state-only scan).
    def state_step(s0, inp):
        kt, vt, lwt = inp
        cum = jnp.cumsum(lwt, axis=1)
        total = cum[:, -1:]
        kd = kt * jnp.exp(total - cum)
        s_new = jnp.exp(total[:, 0])[..., None] * s0 + jnp.einsum(
            "bshd,bshe->bhde", kd, vt)
        return s_new, s0

    _, s0s = jax.lax.scan(state_step, state, (kc, vc, lwc))

    def bwd_step(G, inp):
        rt, kt, vt, lwt, dyt, s0 = inp
        cum = jnp.cumsum(lwt, axis=1)
        cum_prev = cum - lwt
        total = cum[:, -1:]
        e_prev = jnp.exp(cum_prev)
        e_kd = jnp.exp(total - cum)
        e_qd = jnp.exp(cum_prev - total)
        kd = kt * e_kd
        qd = rt * e_qd
        scores = jnp.einsum("bthd,bshd->bhts", qd, kd)
        scores = jnp.where(strict[None, None], scores, 0.0)
        s_new = jnp.exp(total[:, 0])[..., None] * s0 + jnp.einsum(
            "bshd,bshe->bhde", kd, vt)
        # pairwise/diagonal v·dy products
        Pm = jnp.einsum("bthe,bshe->bhts", dyt, vt)
        Pm = jnp.where(strict[None, None], Pm, 0.0)
        diagP = jnp.einsum("bthe,bthe->bth", dyt, vt)
        diag = jnp.einsum("bthd,hd,bthd->bth", rt, u, kt)
        ub = u[None, None]  # (1,1,H,D)
        # dv: intra + diagonal + state kv
        dv = (jnp.einsum("bhts,bthe->bshe", scores, dyt)
              + diag[..., None] * dyt
              + jnp.einsum("bshd,bhde->bshe", kd, G))
        # dr: inter + intra + diagonal
        dq = jnp.einsum("bthe,bhde->bthd", dyt, s0)
        dqd = jnp.einsum("bhts,bshd->bthd", Pm, kd)
        dr_exp = dq * e_prev + dqd * e_qd  # decay-carrying parts
        dr = dr_exp + diagP[..., None] * ub * kt
        # dk: intra + state kv (both through kd) + diagonal
        dkd = (jnp.einsum("bhts,bthd->bshd", Pm, qd)
               + jnp.einsum("bshe,bhde->bshd", vt, G))
        dk_exp = dkd * e_kd
        dk = dk_exp + diagP[..., None] * ub * rt
        # dlw via L_j = Σ_{i≤j} lw_i: g[j] = (r-side, shifted) − (k-side)
        # + the e^{+L_C} state-decay term at j = C−1; dlw = reversed cumsum.
        gl_r = rt * dr_exp
        gl_r = jnp.concatenate([gl_r[:, 1:], jnp.zeros_like(gl_r[:, :1])],
                               axis=1)
        g = gl_r - kt * dk_exp
        sterm = jnp.einsum("bhde,bhde->bhd", s_new, G)
        g = g.at[:, -1].add(sterm)
        dlw = jnp.flip(jnp.cumsum(jnp.flip(g, axis=1), axis=1), axis=1)
        # du (per chunk, summed over batch/time)
        du_c = jnp.einsum("bth,bthd->hd", diagP, rt * kt)
        # state adjoint for the previous chunk
        q = rt * e_prev
        dS0 = (jnp.einsum("bthd,bthe->bhde", q, dyt)
               + jnp.exp(total[:, 0])[..., None] * G)
        return dS0, (dr, dk, dv, dlw, du_c)

    G0, (drc, dkc, dvc, dlwc, dus) = jax.lax.scan(
        bwd_step, dstate, (rc, kc, vc, lwc, dyc, s0s), reverse=True)
    un = lambda a: unchunk(a, b, s, h, d, chunk)
    return un(drc), un(dkc), un(dvc), un(dlwc), dus.sum(0), G0


_wkv_op.defvjp(_wkv_fwd, _wkv_bwd)


def wkv(r, k, v, lw, u, state, *, chunk: int = WKV_CHUNK,
        impl: str = "pallas", interpret: bool | None = None):
    """Chunked WKV.  ``impl``: "pallas" (kernel forward + closed-form
    VJP; ``interpret=None`` auto-selects interpret mode off-TPU) or
    "xla" (the chunked `lax.scan` twin, differentiable via autodiff)."""
    if impl == "xla":
        return wkv_chunked_ref(r, k, v, lw, u, state, chunk)
    if impl != "pallas":
        raise ValueError(f"unknown WKV impl {impl!r} (want pallas|xla)")
    return _wkv_op(r, k, v, lw, u, state, chunk,
                   _resolve_interpret(interpret))
