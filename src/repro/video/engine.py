"""StreamEngine: multi-tick streaming-video detection over the shared
scheduler core (DESIGN.md §9).

A request here is a whole video **stream**: it occupies one slot of the
scheduler's fixed table for as many ticks as it has frames, advancing
one frame per engine tick — the first workload to use the multi-tick
slot lifetime the core was built for with *vision* compute in the slot
(the LM engine holds slots for many ticks; `VisionEngine` holds them
for exactly one).

Per-slot state the core's admit/recycle contract manages via
``_on_admit`` (the isolation invariant `tests/test_scheduler.py` pins):

* a `DeltaGate` — reference frame + measured-bandwidth ledger;
* cached stem activations — the P²M output of the reference frame;
* a `Tracker` — live tracks and the per-stream id counter.

Every tick is ONE compiled, shape-stable launch over the whole slot
table: the deploy-folded P²M stem runs on the padded image batch, a
per-slot ``rerun`` mask selects fresh stem activations or the cached
ones, and the backbone + CenterNet-lite heads + top-k decode ride the
same launch.  The stem select has two paths (``stem_path``):

* ``"where"`` — the reference: compute the stem for every slot, then a
  host-visible `jnp.where` discards skipped results.  Shape-stable, but
  every masked-off slot still pays the full stem FLOPs.
* ``"gated"`` — the fused kernel (`kernels/p2m_conv/gated.py`,
  DESIGN.md §3.6): the rerun mask and the cached stem ride INTO the
  Pallas kernel as operands and masked-off tiles short-circuit to a
  cache copy — one launch, no wasted stem FLOPs, no host round-trip.
  Bitwise-identical to the where-select by construction (bench-gated at
  1.0).  ``"auto"`` picks it on a TPU single-device engine and falls
  back to ``"where"`` elsewhere (interpret-mode gating would *measure*
  the Python interpreter; a mesh needs the where path's sharded XLA
  select).

Either way the thing the delta gate models is the **sensor readout**: a
skipped tick transmits no activation map, and the bits ledger measures
exactly that.  With ``threshold=0`` the gate only skips bit-identical
frames, so gated detections equal the dense engine's exactly (pinned by
test).

Scale-out mirrors `VisionEngine`: pass ``mesh=`` and the image batch,
cached-stem batch, and rerun mask shard over the data axes of the §7.1
vision plan while params/deploy/head trees replicate.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.p2m_vww import (
    SERVE_QUANT_BITS,
    STREAM_MAX_QUEUE,
    STREAM_MAX_SLOTS,
)
from repro.core.bandwidth import (
    FirstLayerGeom,
    StreamBandwidthLedger,
    frame_output_bits,
)
from repro.core.bn_fold import deploy_params
from repro.core.quant import QuantSpec, quantize_deploy
from repro.models.mobilenetv2 import (
    MNV2Config,
    apply_mnv2_backbone,
    apply_mnv2_stem,
)
from repro.obs.metrics import counted_lru_cache
from repro.parallel import vision_plan_for
from repro.parallel.sharding_utils import batch_shardings
from repro.serving.scheduler import ScheduledRequest, SlotEngine
from repro.video.delta import DeltaGate, DeltaGateConfig
from repro.video.detect import (
    DetectConfig,
    apply_detect_head,
    decode_detections,
    det_grid,
)
from repro.video.track import Tracker


@dataclasses.dataclass
class StreamRequest(ScheduledRequest):
    """One video stream = one multi-tick slot occupancy.

    Bandwidth numbers all read through ``ledger`` — the stream's
    `StreamBandwidthLedger`, owned by its slot's `DeltaGate` and
    attached on admit — so there is exactly one copy of the readout
    accounting (`core/bandwidth.py` defines the formulas)."""

    uid: int
    frames: np.ndarray  # (T, H, W, 3) float32 in [0, 1]
    gt_boxes: np.ndarray | None = None  # optional (T, N, 4) ground truth

    # Filled by the engine, one entry per served frame:
    frame_outputs: list = dataclasses.field(default_factory=list)  # (boxes, scores)
    tracks: list = dataclasses.field(default_factory=list)  # [(tid, box, score)]
    frames_done: int = 0
    ledger: StreamBandwidthLedger | None = None  # attached on admit

    @property
    def n_frames(self) -> int:
        return len(self.frames)

    @property
    def skip_count(self) -> int:
        """Frames that reused the cached stem (transmitted nothing)."""
        return self.ledger.frames - self.ledger.rerun_frames if self.ledger else 0

    @property
    def bits(self) -> int:
        """Measured transmitted bits over the stream so far."""
        return self.ledger.bits if self.ledger else 0

    @property
    def skip_rate(self) -> float:
        return self.ledger.skip_rate if self.ledger else 0.0

    @property
    def bits_per_frame(self) -> float:
        return self.ledger.bits_per_frame if self.ledger else 0.0

    @property
    def dense_frame_bits(self) -> int:
        return self.ledger.dense_bits_per_frame if self.ledger else 0

    @property
    def reduction_vs_dense(self) -> float:
        """Measured bandwidth reduction vs re-transmitting every frame."""
        return self.ledger.reduction_vs_dense if self.ledger else 0.0

    @property
    def frame_latency_us(self) -> float:
        """Mean per-frame launch wall-clock over the stream so far."""
        return self.launch_wall_us / self.frames_done if self.frames_done else 0.0


@counted_lru_cache("stream_forward")
def _stream_forward_for(cfg: MNV2Config, dcfg: DetectConfig,
                        mesh: Mesh | None, batch: int,
                        impl: str | None = None,
                        stem_path: str = "where",
                        interpret: bool | None = None):
    """One compiled launch: gated stem → backbone → heads → top-k decode.

    Params, BN, deploy, and detection-head trees ride as traced
    arguments so every engine on this (cfg, dcfg, mesh, batch, impl,
    stem_path) shares one compilation; under a mesh the batched operands
    shard over the data axes (§7.1 plan) and everything else replicates.
    ``impl`` selects the stem conv path on the ``"where"`` select —
    the degradation ladder requests ``"patches"`` after repeated kernel
    faults (DESIGN.md §10); ``stem_path="gated"`` instead runs the
    fused delta-gated Pallas stem (cache + mask in-kernel, §3.6) and
    requires ``mesh=None``.

    The cached stem is *validated on device*: a slot whose cache holds
    any non-finite value (a corrupted analog activation that slipped
    into state, arXiv:2304.02968's fault class) is forced to re-run, and
    the **effective** rerun mask returns to the host so the ledger
    meters what actually happened and the engine can drop that slot's
    gate to dense.  When every cache row is finite the effective mask
    equals the requested one, so the guard is bitwise-free in the
    fault-free path.
    """
    if stem_path not in ("where", "gated"):
        raise ValueError(f"unknown stem_path {stem_path!r}")
    if stem_path == "gated" and mesh is not None:
        raise ValueError("stem_path='gated' needs mesh=None: the fused "
                         "kernel takes the whole slot table in one launch; "
                         "sharded engines keep the where-select")

    grid = det_grid(cfg.p2m.out_spatial(cfg.image_size))
    if stem_path == "gated":
        from repro.core.pixel_model import default_pixel_model
        from repro.kernels.p2m_conv.gated import p2m_conv_pallas_gated
        from repro.kernels.p2m_conv.ops import _coeff_tuple

        gated_coeffs = _coeff_tuple(default_pixel_model())
        gated_interpret = (jax.default_backend() != "tpu"
                           if interpret is None else interpret)

    def forward(params, bn, dep, det, images, cached, rerun):
        cache_ok = jnp.isfinite(cached).all(axis=(1, 2, 3))
        rerun = rerun | ~cache_ok
        if stem_path == "gated":
            # deploy-form stem (conv → quantizing ADC epilogue, matching
            # apply_p2m_conv_deploy) with the select fused in-kernel
            stem = p2m_conv_pallas_gated(
                images, dep["w"], dep["shift"], cached, rerun,
                kernel=cfg.p2m.kernel, stride=cfg.p2m.stride,
                coeffs=gated_coeffs, mode="quant",
                v_lsb=cfg.p2m.adc.v_lsb, max_count=cfg.p2m.adc.max_count,
                interpret=gated_interpret)
        else:
            stem, _ = apply_mnv2_stem(params, bn, images, cfg, None,
                                      train=False, p2m_deploy=dep,
                                      p2m_impl=impl)
            stem = jnp.where(rerun[:, None, None, None], stem, cached)
        feats, _ = apply_mnv2_backbone(params, bn, stem, cfg, train=False)
        boxes, scores = decode_detections(
            apply_detect_head(det, feats, grid), dcfg.max_dets)
        return stem, boxes, scores, rerun

    if mesh is None:
        return jax.jit(forward)
    plan = vision_plan_for(mesh)
    h = w = cfg.image_size
    ho = cfg.p2m.out_spatial(h)
    wo = cfg.p2m.out_spatial(w)
    co = cfg.p2m.out_channels
    img = batch_shardings(
        jax.ShapeDtypeStruct((batch, h, w, 3), jnp.float32), plan)
    cach = batch_shardings(
        jax.ShapeDtypeStruct((batch, ho, wo, co), jnp.float32), plan)
    msk = batch_shardings(jax.ShapeDtypeStruct((batch,), jnp.bool_), plan)
    rep = NamedSharding(mesh, P())
    # the stem comes back *sharded* (it feeds straight into next tick's
    # cached-stem operand, same sharding — no per-tick gather/reshard);
    # the decoded boxes/scores and effective rerun mask replicate to the
    # host
    return jax.jit(forward, in_shardings=(rep, rep, rep, rep, img, cach, msk),
                   out_shardings=(cach, rep, rep, rep))


class StreamEngine(SlotEngine):
    """Multi-tick streaming detection engine; see module docstring."""

    request_type = StreamRequest

    def __init__(self, params, bn_state, cfg: MNV2Config, det_params, *,
                 det_cfg: DetectConfig = DetectConfig(),
                 gate: DeltaGateConfig = DeltaGateConfig(),
                 max_streams: int = STREAM_MAX_SLOTS,
                 max_queue: int | None = STREAM_MAX_QUEUE,
                 deploy_quant_bits: int | None = SERVE_QUANT_BITS,
                 iou_thresh: float = 0.3,
                 mesh: Mesh | None = None,
                 evict: str = "drop-newest",
                 degrade_after: int = 3,
                 stem_path: str = "auto",
                 stem_impl: str | None = None, **core):
        """``evict`` defaults to drop-newest: an admitted stream is a
        promise held for its whole lifetime (unlike single frames, where
        freshness beats fairness and the vision engine drops oldest).
        ``degrade_after``: launch-fault count after which the stem falls
        back to the patches reference conv; ``core`` forwards the
        scheduler's fault-tolerance knobs (DESIGN.md §10).

        ``stem_path``: ``"gated"`` fuses the delta-gate select into the
        stem kernel (one launch, skipped slots pay no stem FLOPs —
        DESIGN.md §3.6, single-device only); ``"where"`` is the
        compute-all reference select; ``"auto"`` picks gated on a TPU
        single-device engine, where otherwise.  ``stem_impl`` forces the
        where-path conv impl (tests pass ``"pallas"`` so the reference
        is the same kernel family the gated path fuses)."""
        if cfg.variant != "p2m":
            raise ValueError("StreamEngine requires the p2m variant: stem "
                             "caching and readout accounting are defined by "
                             "the in-pixel layer")
        super().__init__(max_streams, max_queue=max_queue, evict=evict,
                         **core)
        self.cfg = cfg
        self.degrade_after = degrade_after
        self._kernel_faults = 0
        self._gate_faults = 0
        self.det_cfg = det_cfg
        self.gate_cfg = gate
        self.mesh = mesh
        self._params = params
        self._bn = bn_state
        self._det = det_params
        dep = deploy_params(params["stem"], bn_state["stem"], cfg.p2m)
        if deploy_quant_bits is not None:
            dep = quantize_deploy(
                dep, QuantSpec(deploy_quant_bits, deploy_quant_bits))
        self._deploy = dep
        self.geom = FirstLayerGeom(
            image_size=cfg.image_size, kernel=cfg.p2m.kernel, padding=0,
            stride=cfg.p2m.stride, out_channels=cfg.p2m.out_channels,
            out_bits=cfg.p2m.n_bits)
        self._iou_thresh = iou_thresh

        if stem_path == "auto":
            stem_path = ("gated" if mesh is None
                         and jax.default_backend() == "tpu" else "where")
        if stem_path not in ("gated", "where"):
            raise ValueError(f"unknown stem_path {stem_path!r}")
        self.stem_path = stem_path
        self._stem_impl = stem_impl
        # in-kernel skip accounting over *active* slots (gated path only:
        # the where path computes every slot regardless)
        self._stem_total = 0
        self._stem_skipped = 0

        ho = cfg.p2m.out_spatial(cfg.image_size)
        co = cfg.p2m.out_channels
        # device-resident across ticks: _launch feeds the previous tick's
        # stem output straight back in (no host round-trip; under a mesh
        # it stays sharded — see _stream_forward_for's out_shardings)
        self._cached_stem = jnp.zeros((self.n_slots, ho, ho, co),
                                      jnp.float32)
        self._gates: list[DeltaGate | None] = [None] * self.n_slots
        self._trackers: list[Tracker | None] = [None] * self.n_slots
        self._fwd = _stream_forward_for(cfg, det_cfg, mesh, self.n_slots,
                                        stem_impl, stem_path)
        # stream-specific registry views alongside the core's
        # latency/health (DESIGN.md §13.2): the aggregate stream summary
        # and the per-slot delta-gate ledgers
        self.registry.register_view(self.metrics_scope, "stream",
                                    self.stream_summary)
        self.registry.register_view(self.metrics_scope, "gates",
                                    self._gate_ledgers)

    # ------------------------------------------------- adapter hooks

    def submit(self, req: StreamRequest) -> str:
        """Reject degenerate streams at the door: an empty stream would
        otherwise occupy a slot whose launch has no frame to read."""
        if req.n_frames == 0:
            raise ValueError(f"stream {req.uid} has no frames")
        return super().submit(req)

    def _on_admit(self, i: int, req: StreamRequest) -> None:
        """Recycle slot ``i`` for a new stream: fresh gate (no reference
        frame), fresh tracker (ids restart at 0), zeroed stem cache —
        nothing of the previous occupant may leak.  The request reads
        its bandwidth numbers through the gate's ledger."""
        self._gates[i] = DeltaGate(self.gate_cfg, self.geom)
        self._trackers[i] = Tracker(iou_thresh=self._iou_thresh)
        self._cached_stem = self._cached_stem.at[i].set(0.0)
        req.ledger = self._gates[i].ledger

    def _on_launch_fault(self, exc: Exception) -> None:
        """Degradation ladder, rung 1 (DESIGN.md §10): repeated kernel
        faults swap the fused stem conv for the patches reference path —
        the stream keeps serving on the slow-but-solid conv."""
        self._kernel_faults += 1
        if self.degraded is None and self._kernel_faults >= self.degrade_after:
            self.degraded = "patches"
            # the ladder lands on the compute-all where-select: a faulting
            # fused/gated kernel is exactly what it must route around
            self.stem_path = "where"
            self._fwd = _stream_forward_for(self.cfg, self.det_cfg,
                                            self.mesh, self.n_slots,
                                            "patches", "where")

    def _launch(self, active):
        h = w = self.cfg.image_size
        images = np.zeros((self.n_slots, h, w, 3), np.float32)
        rerun = np.zeros((self.n_slots,), bool)
        frames: dict[int, np.ndarray] = {}
        for i, req in active:
            frame = req.frames[req.frames_done]
            frames[i] = frame
            images[i] = frame
            gate = self._gates[i]
            was_disabled = gate.disabled
            rerun[i] = gate.should_rerun(frame)
            if gate.disabled and not was_disabled:
                self._gate_faults += 1  # reference failed validation
        stem, boxes, scores, rerun_eff = self._fwd(
            self._params, self._bn, self._deploy, self._det,
            jnp.asarray(images), self._cached_stem, jnp.asarray(rerun))
        jax.block_until_ready((stem, boxes, scores))
        self._cached_stem = stem  # stays on device (sharded under a mesh)
        rerun_eff = np.asarray(rerun_eff)
        if self.stem_path == "gated":
            # every active slot whose effective mask is False had its stem
            # tile short-circuited in-kernel — zero MXU work, by design
            self._stem_total += len(active)
            self._stem_skipped += sum(
                1 for i, _ in active if not rerun_eff[i])
        for i, req in active:  # the per-stream ledger meters the tick
            if rerun_eff[i] and not rerun[i]:
                # the on-device check caught a corrupted stem cache:
                # degradation ladder rung 2 — this stream's gate drops to
                # dense (every remaining frame re-runs; the ledger stays
                # honest because it meters the *effective* mask)
                self._gates[i].disable()
                self._gate_faults += 1
            self._gates[i].observe(frames[i], bool(rerun_eff[i]))
        return np.asarray(boxes), np.asarray(scores)

    def _absorb(self, i: int, req: StreamRequest, result) -> bool:
        boxes, scores = result
        req.frame_outputs.append((boxes[i].copy(), scores[i].copy()))
        keep = scores[i] >= self.det_cfg.score_thresh
        live = self._trackers[i].update(boxes[i][keep], scores[i][keep])
        req.tracks.append([(t.tid, t.box.copy(), t.score) for t in live])
        req.frames_done += 1
        return req.frames_done >= req.n_frames

    # ------------------------------------------------------ reporting

    def _gate_ledgers(self) -> list:
        """Per-slot delta-gate ledger summaries (None = free slot) — the
        registry view that puts the readout-bandwidth accounting on the
        same snapshot surface as the latency ledgers."""
        return [None if g is None else g.ledger.summary()
                for g in self._gates]

    def health(self) -> dict:
        """Core health report plus the stream-specific degradation
        counters: gates dropped to dense (corrupted cache or reference)
        and kernel faults absorbed by the conv fallback."""
        h = super().health()
        h["gate_faults"] = self._gate_faults
        h["kernel_faults"] = self._kernel_faults
        return h

    def stream_summary(self) -> dict:
        """Aggregate stream metrics over completed requests: mean stem
        skip rate, measured bits/frame vs dense, and the measured
        bandwidth reduction on the served traffic (summed over the
        per-stream ledgers)."""
        done: list[StreamRequest] = self.completed
        frames = sum(r.frames_done for r in done)
        skips = sum(r.skip_count for r in done)
        bits = sum(r.bits for r in done)
        dense = frame_output_bits(self.geom)
        bpf = bits / frames if frames else 0.0
        return {
            "streams": len(done),
            "frames": frames,
            "stem_skip_rate": skips / frames if frames else 0.0,
            "bits_per_frame": bpf,
            "dense_bits_per_frame": dense,
            "measured_reduction_vs_dense": dense / bpf if bpf else 0.0,
            "stem_path": self.stem_path,
            # gated path only: fraction of active-slot stem computations
            # the fused kernel short-circuited (0.0 on the where path,
            # which computes every slot)
            "stem_flops_skipped_ratio": (
                self._stem_skipped / self._stem_total
                if self._stem_total else 0.0),
        }
