"""Synthetic moving-object video source (the streaming counterpart of
`data/vww_synthetic.py`).

Each stream is a static textured background plus ``n_objects`` soft
figure-shaped blobs following parametric linear trajectories that
reflect off the frame edges.  Ground-truth boxes (normalized
``x0, y0, x1, y1``) and stable object ids come with every frame, so the
tracking workload has something to score against.

Temporal redundancy is a *parameter*, not an accident: object positions
advance every ``hold`` frames (quantized time), the background is frozen
per stream, and there is no per-frame noise by default — so within a
hold group consecutive frames are **bit-identical**.  That is the
redundancy the delta gate (`video/delta.py`) exploits, and it makes the
threshold-0 gate lossless by construction (DESIGN.md §9).

Deterministic in (seed, frame index); every frame is addressable without
materializing the stream (``frame_at``), and shapes are stable: always
``(H, W, 3)`` frames and ``(n_objects, 4)`` boxes.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.data.vww_synthetic import _background


@functools.lru_cache(maxsize=64)
def _stream_layout(image_size: int, n_objects: int, seed: int):
    """Per-stream randomized layout: background + object parameters."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x51DE0]))
    h = w = image_size
    bg = _background(h, w, rng)
    background = np.stack(
        [np.clip(bg * rng.uniform(0.7, 1.3), 0.0, 1.0) for _ in range(3)], -1
    ).astype(np.float32)
    objs = []
    for _ in range(n_objects):
        objs.append({
            # normalized center start + velocity (fraction of frame/frame)
            "p0": rng.uniform(0.25, 0.75, 2),
            "v": rng.uniform(0.01, 0.04, 2) * rng.choice([-1.0, 1.0], 2),
            # normalized half-extents (rx, ry) and a distinct color
            "r": rng.uniform(0.08, 0.16, 2),
            "color": rng.uniform(0.3, 1.0, 3).astype(np.float32),
        })
    return background, objs


def _reflect(p: np.ndarray, lo: float, hi: float) -> np.ndarray:
    """Reflect an unbounded coordinate into [lo, hi] (triangle wave)."""
    span = hi - lo
    q = np.mod(p - lo, 2 * span)
    return lo + np.where(q > span, 2 * span - q, q)


@dataclasses.dataclass(frozen=True)
class SyntheticVideo:
    """Parametric moving-object stream; see module docstring."""

    image_size: int = 40
    n_frames: int = 16
    n_objects: int = 2
    seed: int = 0
    hold: int = 2  # positions advance every `hold` frames (temporal redundancy)
    noise: float = 0.0  # per-frame noise; > 0 breaks bit-identical holds

    def _centers_at(self, t: int) -> list[tuple[np.ndarray, dict]]:
        _, objs = _stream_layout(self.image_size, self.n_objects, self.seed)
        tq = (t // max(1, self.hold)) * max(1, self.hold)
        out = []
        for o in objs:
            # keep the whole box inside the frame: reflect the center
            # within margins of the half-extents
            c = np.array([
                _reflect(o["p0"][i] + o["v"][i] * tq, o["r"][i],
                         1.0 - o["r"][i])
                for i in range(2)
            ])
            out.append((c, o))
        return out

    def boxes_at(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Ground truth at frame ``t``: (n_objects, 4) normalized
        ``x0, y0, x1, y1`` boxes and (n_objects,) stable ids."""
        boxes = np.empty((self.n_objects, 4), np.float32)
        for i, (c, o) in enumerate(self._centers_at(t)):
            boxes[i] = [c[0] - o["r"][0], c[1] - o["r"][1],
                        c[0] + o["r"][0], c[1] + o["r"][1]]
        return boxes, np.arange(self.n_objects, dtype=np.int32)

    def frame_at(self, t: int) -> dict[str, np.ndarray]:
        """``{"image": (H, W, 3) f32 in [0,1], "boxes": (N, 4), "ids": (N,)}``."""
        background, _ = _stream_layout(self.image_size, self.n_objects,
                                       self.seed)
        h = w = self.image_size
        img = background.copy()
        yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
        for c, o in self._centers_at(t):
            cx, cy = c[0] * w, c[1] * h
            rx, ry = o["r"][0] * w, o["r"][1] * h
            d = ((xx - cx) / rx) ** 2 + ((yy - cy) / ry) ** 2
            m = np.exp(-np.maximum(d - 0.6, 0.0) * 5.0)[..., None]
            img = img * (1 - 0.85 * m) + 0.85 * m * o["color"]
        if self.noise > 0.0:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 1 + t]))
            img = img + rng.normal(0.0, self.noise, img.shape)
        boxes, ids = self.boxes_at(t)
        return {"image": np.clip(img, 0.0, 1.0).astype(np.float32),
                "boxes": boxes, "ids": ids}

    def frames(self) -> np.ndarray:
        """Materialize the whole stream: (n_frames, H, W, 3)."""
        return np.stack([self.frame_at(t)["image"]
                         for t in range(self.n_frames)])

    def gt_boxes(self) -> np.ndarray:
        """(n_frames, n_objects, 4) ground-truth track boxes."""
        return np.stack([self.boxes_at(t)[0] for t in range(self.n_frames)])
