"""Greedy-IoU multi-object track association (P2M-DeTrack workload).

Host-side, per-stream state: the detector's per-frame (boxes, scores)
feed a greedy bipartite match against the live tracks — highest-IoU
pair first, matches below ``iou_thresh`` rejected — matched tracks
update in place, unmatched detections open new tracks, and tracks
unseen for ``max_age`` frames retire.  Track ids are allocated
per-tracker, so a recycled engine slot with a fresh ``Tracker`` restarts
at id 0 — the slot-state-isolation invariant `StreamEngine` pins in its
tests (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pairwise IoU of (N, 4) × (M, 4) normalized x0y0x1y1 boxes."""
    a = np.asarray(a, np.float32).reshape(-1, 4)
    b = np.asarray(b, np.float32).reshape(-1, 4)
    x0 = np.maximum(a[:, None, 0], b[None, :, 0])
    y0 = np.maximum(a[:, None, 1], b[None, :, 1])
    x1 = np.minimum(a[:, None, 2], b[None, :, 2])
    y1 = np.minimum(a[:, None, 3], b[None, :, 3])
    inter = np.clip(x1 - x0, 0, None) * np.clip(y1 - y0, 0, None)
    area_a = np.clip(a[:, 2] - a[:, 0], 0, None) * np.clip(
        a[:, 3] - a[:, 1], 0, None)
    area_b = np.clip(b[:, 2] - b[:, 0], 0, None) * np.clip(
        b[:, 3] - b[:, 1], 0, None)
    union = area_a[:, None] + area_b[None, :] - inter
    return np.where(union > 0, inter / np.maximum(union, 1e-12), 0.0)


@dataclasses.dataclass
class Track:
    tid: int
    box: np.ndarray  # (4,) normalized x0y0x1y1
    score: float
    age: int = 0  # frames since last matched detection
    hits: int = 1  # matched detections over the track's life


class Tracker:
    """Per-stream greedy-IoU association state; see module docstring."""

    def __init__(self, iou_thresh: float = 0.3, max_age: int = 3):
        self.iou_thresh = iou_thresh
        self.max_age = max_age
        self.tracks: list[Track] = []
        self._next_id = 0

    def update(self, boxes: np.ndarray, scores: np.ndarray) -> list[Track]:
        """Associate one frame's detections; returns the live tracks
        (matched + newborn) after ageing out stale ones."""
        boxes = np.asarray(boxes, np.float32).reshape(-1, 4)
        scores = np.asarray(scores, np.float32).reshape(-1)
        matched_t: set[int] = set()
        matched_d: set[int] = set()
        if self.tracks and len(boxes):
            ious = iou_matrix(np.stack([t.box for t in self.tracks]), boxes)
            while True:
                ti, di = np.unravel_index(np.argmax(ious), ious.shape)
                if ious[ti, di] < self.iou_thresh:
                    break
                trk = self.tracks[ti]
                trk.box = boxes[di].copy()
                trk.score = float(scores[di])
                trk.age = 0
                trk.hits += 1
                matched_t.add(int(ti))
                matched_d.add(int(di))
                ious[ti, :] = -1.0
                ious[:, di] = -1.0
        for ti, trk in enumerate(self.tracks):
            if ti not in matched_t:
                trk.age += 1
        for di in range(len(boxes)):
            if di not in matched_d:
                self.tracks.append(Track(tid=self._next_id,
                                         box=boxes[di].copy(),
                                         score=float(scores[di])))
                self._next_id += 1
        self.tracks = [t for t in self.tracks if t.age <= self.max_age]
        return [t for t in self.tracks if t.age == 0]
