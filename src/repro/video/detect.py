"""Anchor-free detection head on the P²M-MobileNetV2 backbone.

CenterNet-lite after P2M-DeTrack (arXiv:2205.14285): the deploy-folded
P²M stem + MobileNetV2 backbone (`models/mobilenetv2.py` —
``apply_mnv2_stem`` / ``apply_mnv2_backbone``, so the first layer stays
"what the sensor executes") feeds three small convolutional heads on the
pre-pool feature map:

* **heatmap** (B, h, w, 1): sigmoid objectness, peaks at object centers;
* **size** (B, h, w, 2): sigmoid-normalized box width/height;
* **offset** (B, h, w, 2): sub-cell center offset in [0, 1).

``decode_detections`` is shape-stable (fixed top-k) so it lives inside
the engine's one compiled launch: 3×3 local-max suppression on the
heatmap, top-k peaks, boxes assembled from the size/offset heads in
normalized ``x0, y0, x1, y1`` coordinates.  Host-side score filtering
and greedy-IoU association happen in `video/track.py`.

``detect_loss`` (penalty-reduced focal + masked L1, the CenterNet
recipe) and ``render_targets`` make the head trainable end-to-end on
`video/synthetic.py` ground truth; tests pin one descending step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DetectConfig:
    """Head hyperparameters (kept lite: one shared 3×3, three 1×1s)."""

    head_channels: int = 32
    max_dets: int = 8  # top-k peaks per frame (shape-stable decode)
    score_thresh: float = 0.3  # host-side filter before track association
    prior_logit: float = -2.19  # heatmap bias init: sigmoid ≈ 0.1 prior


def _conv_init(key, k, cin, cout):
    fan_in = k * k * cin
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * (
        2.0 / fan_in) ** 0.5


def init_detect_head(key: jax.Array, in_channels: int,
                     dcfg: DetectConfig) -> dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ch = dcfg.head_channels
    return {
        "shared": {"w": _conv_init(k1, 3, in_channels, ch),
                   "b": jnp.zeros((ch,), jnp.float32)},
        "heatmap": {"w": _conv_init(k2, 1, ch, 1),
                    "b": jnp.full((1,), dcfg.prior_logit, jnp.float32)},
        "size": {"w": _conv_init(k3, 1, ch, 2),
                 "b": jnp.zeros((2,), jnp.float32)},
        "offset": {"w": _conv_init(k4, 1, ch, 2),
                   "b": jnp.zeros((2,), jnp.float32)},
    }


def _conv(x, p):
    return jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]


def det_grid(stem_spatial: int) -> int:
    """Detection-grid side for a given P²M stem resolution: the backbone
    pools 32× below the stem, far too coarse to localize on — CenterNet
    recovers resolution with deconv stages; the lite version nearest-
    upsamples the final feature map to stem/2 (56² at paper geometry)."""
    return max(1, stem_spatial // 2)


def apply_detect_head(det_params: dict, feats: jax.Array,
                      grid: int) -> dict[str, jax.Array]:
    """(B, h, w, C) backbone features → raw head outputs on the
    ``grid``×``grid`` detection grid (pre-decode)."""
    b, _, _, c = feats.shape
    z = jax.image.resize(feats, (b, grid, grid, c), method="nearest")
    z = jax.nn.relu(_conv(z, det_params["shared"]))
    return {
        "heatmap": jax.nn.sigmoid(_conv(z, det_params["heatmap"])),
        "size": jax.nn.sigmoid(_conv(z, det_params["size"])),
        "offset": jax.nn.sigmoid(_conv(z, det_params["offset"])),
    }


def decode_detections(outs: dict[str, jax.Array],
                      k: int) -> tuple[jax.Array, jax.Array]:
    """Peak decode: (boxes (B, k, 4) normalized x0y0x1y1, scores (B, k)).

    3×3 local-max NMS on the heatmap (a peak survives iff it equals its
    neighborhood max), then top-k over the flattened grid — all
    shape-stable, so it compiles into the engine launch.
    """
    hm = outs["heatmap"][..., 0]  # (B, h, w)
    b, h, w = hm.shape
    local_max = jax.lax.reduce_window(
        hm, -jnp.inf, jax.lax.max, (1, 3, 3), (1, 1, 1), "SAME")
    peaks = jnp.where(hm == local_max, hm, 0.0)
    kk = min(k, h * w)  # tiny smoke grids can undercut the requested k
    scores, idx = jax.lax.top_k(peaks.reshape(b, h * w), kk)
    ys, xs = idx // w, idx % w  # (B, kk)

    def gather_bk(m):  # (B, h, w, 2) → (B, k, 2)
        flat = m.reshape(b, h * w, 2)
        return jnp.take_along_axis(flat, idx[..., None], axis=1)

    off = gather_bk(outs["offset"])
    wh = gather_bk(outs["size"])
    cx = (xs.astype(jnp.float32) + off[..., 0]) / w
    cy = (ys.astype(jnp.float32) + off[..., 1]) / h
    boxes = jnp.stack([cx - wh[..., 0] / 2, cy - wh[..., 1] / 2,
                       cx + wh[..., 0] / 2, cy + wh[..., 1] / 2], axis=-1)
    if kk < k:  # pad to the contracted (B, k, ·) shape; score 0 never
        boxes = jnp.pad(boxes, ((0, 0), (0, k - kk), (0, 0)))  # survives
        scores = jnp.pad(scores, ((0, 0), (0, k - kk)))  # the host filter
    return boxes, scores


# ------------------------------------------------------------------ training


def render_targets(boxes: np.ndarray, h: int, w: int) -> dict[str, np.ndarray]:
    """Ground-truth maps for one frame's (N, 4) normalized boxes:
    gaussian-splatted heatmap, size/offset at center cells, and the
    center-cell mask the regression losses are gated by."""
    hm = np.zeros((h, w, 1), np.float32)
    size = np.zeros((h, w, 2), np.float32)
    off = np.zeros((h, w, 2), np.float32)
    mask = np.zeros((h, w, 1), np.float32)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    for x0, y0, x1, y1 in np.asarray(boxes, np.float32):
        bw, bh = max(x1 - x0, 1e-4), max(y1 - y0, 1e-4)
        cx, cy = (x0 + x1) / 2 * w, (y0 + y1) / 2 * h
        ix, iy = min(int(cx), w - 1), min(int(cy), h - 1)
        sigma = max(1.0, (bw * w + bh * h) / 8.0)
        g = np.exp(-(((xx - ix) ** 2 + (yy - iy) ** 2) / (2 * sigma**2)))
        hm[..., 0] = np.maximum(hm[..., 0], g)
        size[iy, ix] = [bw, bh]
        off[iy, ix] = [cx - ix, cy - iy]
        mask[iy, ix] = 1.0
    return {"heatmap": hm, "size": size, "offset": off, "mask": mask}


def detect_loss(outs: dict[str, jax.Array],
                targets: dict[str, jax.Array]) -> jax.Array:
    """Penalty-reduced focal loss on the heatmap + masked L1 on
    size/offset (CenterNet Eq. 1/2/3), mean over the batch."""
    eps = 1e-6
    p = jnp.clip(outs["heatmap"], eps, 1.0 - eps)
    t = targets["heatmap"]
    pos = (t >= 1.0 - 1e-6).astype(p.dtype)
    focal_pos = -pos * ((1 - p) ** 2) * jnp.log(p)
    focal_neg = -(1 - pos) * ((1 - t) ** 4) * (p**2) * jnp.log(1 - p)
    n_pos = jnp.maximum(pos.sum(), 1.0)
    loss = (focal_pos + focal_neg).sum() / n_pos
    m = targets["mask"]
    loss += (jnp.abs(outs["size"] - targets["size"]) * m).sum() / jnp.maximum(
        m.sum(), 1.0)
    loss += (jnp.abs(outs["offset"] - targets["offset"]) * m).sum() / (
        jnp.maximum(m.sum(), 1.0))
    return loss
