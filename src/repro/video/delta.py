"""Temporal delta gating: skip the P²M stem on redundant frames.

Frame-delta (event-style) readout after Neuromorphic-P2M
(arXiv:2301.09111): an always-on sensor watching a mostly static scene
re-transmits a mostly identical activation map every frame.  The gate
compares each incoming frame against the **reference frame** — the one
whose stem activations are cached — and only re-runs (and re-transmits)
the stem when the mean absolute pixel delta crosses ``threshold``.
Comparing against the reference rather than the previous frame means
slow drift accumulates until it crosses the threshold instead of
slipping under it one frame at a time.

``threshold=0.0`` is *lossless* gating: only bit-identical frames skip,
so gated output is exactly the dense output (pinned by test).
``threshold=None`` disables gating (the dense baseline).  Either way
every tick lands in a `core.bandwidth.StreamBandwidthLedger`, so the
bandwidth reduction the bench reports is measured on the live stream,
not the Eq. 2 closed form (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bandwidth import FirstLayerGeom, StreamBandwidthLedger


@dataclasses.dataclass(frozen=True)
class DeltaGateConfig:
    """``threshold``: mean |Δ| (pixels in [0, 1]) above which the stem
    re-runs; 0.0 skips only bit-identical frames (lossless); None
    disables gating entirely — every frame re-runs (dense baseline)."""

    threshold: float | None = 0.0

    @property
    def enabled(self) -> bool:
        return self.threshold is not None


def frame_delta(ref: np.ndarray, cur: np.ndarray) -> float:
    """Mean absolute pixel difference between two (H, W, 3) frames."""
    return float(np.mean(np.abs(np.asarray(cur, np.float32)
                                - np.asarray(ref, np.float32))))


class DeltaGate:
    """Per-stream gate state: the reference frame whose stem activations
    are cached, plus the stream's measured-bandwidth ledger."""

    def __init__(self, cfg: DeltaGateConfig, geom: FirstLayerGeom):
        self.cfg = cfg
        self.ledger = StreamBandwidthLedger(geom)
        self._ref: np.ndarray | None = None
        self.disabled = False

    def disable(self) -> None:
        """Drop to dense for the rest of the stream (DESIGN.md §10,
        degradation ladder rung 2): every remaining frame re-runs.  The
        engine calls this when the cached stem fails on-device
        validation — trusting the gate further would keep serving stale
        or corrupted activations."""
        self.disabled = True
        self._ref = None

    def should_rerun(self, frame: np.ndarray) -> bool:
        """Decide this tick: True ⇒ the stem re-runs on ``frame``."""
        if self.disabled:
            return True
        if self._ref is not None and self._ref.shape != np.shape(frame):
            # a reference that no longer matches the stream's frames is
            # corrupted gate state — fail safe to dense, don't compare
            self.disable()
            return True
        if self._ref is None or not self.cfg.enabled:
            return True
        if not np.isfinite(self._ref).all():
            self.disable()
            return True
        return frame_delta(self._ref, frame) > self.cfg.threshold

    def observe(self, frame: np.ndarray, reran: bool) -> int:
        """Record the decision's outcome; returns bits transmitted.

        On a re-run the frame becomes the new reference (its stem
        activations are what the engine cached)."""
        if reran:
            self._ref = np.array(frame, np.float32, copy=True)
        return self.ledger.record(reran)
