"""Streaming-video P²M detection subsystem (DESIGN.md §9).

The always-on-sensor workload the paper targets, made continuous:
synthetic moving-object streams (`synthetic`), a CenterNet-lite
detection head on the deploy-folded P²M-MobileNetV2 backbone
(`detect`), greedy-IoU tracking (`track`), temporal delta gating with
measured readout-bandwidth accounting (`delta`), and the multi-tick
`StreamEngine` over the shared scheduler core (`engine`).
"""
from repro.video.delta import DeltaGate, DeltaGateConfig, frame_delta
from repro.video.detect import (
    DetectConfig,
    apply_detect_head,
    decode_detections,
    detect_loss,
    init_detect_head,
    render_targets,
)
from repro.video.engine import StreamEngine, StreamRequest
from repro.video.synthetic import SyntheticVideo
from repro.video.track import Track, Tracker, iou_matrix

__all__ = [
    "DeltaGate", "DeltaGateConfig", "frame_delta",
    "DetectConfig", "apply_detect_head", "decode_detections",
    "detect_loss", "init_detect_head", "render_targets",
    "StreamEngine", "StreamRequest", "SyntheticVideo",
    "Track", "Tracker", "iou_matrix",
]
