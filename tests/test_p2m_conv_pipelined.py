"""Pipelined double-buffered conv + fused delta-gated stem
(DESIGN.md §3.5-§3.6).

Three property families through the hypothesis shim:

* the explicit DMA-ring pipelined kernel is **bitwise** identical to the
  automatic grid pipeline (same per-tile dot shapes in the same order)
  and matches the XLA premix twin to fp32 tolerance — forward and, via
  the custom-VJP `pipeline_depth` override, both gradients — over random
  geometries including the ``s == k`` zero-copy fast path;
* the delta-gated stem kernel is **bitwise** identical to
  ``dense Pallas + jnp.where`` under random per-slot rerun masks (the
  reference path the engine keeps);
* a recycled slot on the gated engine path leaks nothing from its
  previous occupant (the StreamEngine isolation invariant, re-pinned on
  the fused path).

Plus the tuner satellites: the conv cache key distinguishes pipeline
depth menus and backend, and the disabled-off-TPU default fallback logs
exactly once per (kind, backend).

``REPRO_P2M_NO_INTERPRET=1`` (the ci.sh accelerator lane) drops the
interpret pins so the kernels compile for real on a TPU/GPU backend.
"""
import json
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.adc import ADCConfig
from repro.core.pixel_model import default_pixel_model
from repro.kernels.p2m_conv import (
    aligned_block_h,
    p2m_conv,
    p2m_conv_gated_jnp,
    p2m_conv_jnp,
    p2m_conv_pallas,
    p2m_conv_pallas_gated,
)
from repro.kernels.p2m_conv import tune
from repro.kernels.p2m_conv.ops import _coeff_tuple

MODEL = default_pixel_model()
ADC = ADCConfig()
COEFFS = _coeff_tuple(MODEL)
MODES = ("raw", "relu", "quant")
N_OUT = 5  # off the lane quantum on purpose
INTERPRET = os.environ.get("REPRO_P2M_NO_INTERPRET", "") != "1"


def _geometry(h, w_dim, k):
    return max(h, k), max(w_dim, k)


def _data(h, w_dim, c, k, seed, b=2):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.random((b, h, w_dim, c)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1, 1, (k * k * c, N_OUT)), jnp.float32)
    sh = jnp.asarray(rng.uniform(-0.2, 0.2, (N_OUT,)), jnp.float32)
    return imgs, w, sh


def _out_spatial(h, k, s):
    return (h - k) // s + 1


# --------------------------------------------------- pipelined kernel parity


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 14), st.integers(4, 14), st.integers(1, 3),
       st.integers(2, 5), st.integers(0, 4), st.integers(0, 1),
       st.integers(0, 2))
def test_pipelined_forward_parity_random_geometry(h, w_dim, c, k, s_raw,
                                                  d_i, mode_i):
    """Explicit DMA ring == automatic grid pipeline bitwise, == XLA premix
    to fp32 tolerance.  ``s_raw == 0`` draws the s == k zero-copy fast
    path; otherwise the general strided path."""
    h, w_dim = _geometry(h, w_dim, k)
    s = k if s_raw == 0 else min(max(s_raw, 1), k)
    depth = (2, 3)[d_i]
    mode = MODES[mode_i]
    imgs, w, sh = _data(h, w_dim, c, k, seed=h * 31 + w_dim * 7 + k + s)

    grid = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s, coeffs=COEFFS,
                           mode=mode, pipeline_depth=0, interpret=INTERPRET)
    pipe = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s, coeffs=COEFFS,
                           mode=mode, pipeline_depth=depth,
                           interpret=INTERPRET)
    np.testing.assert_array_equal(np.asarray(grid), np.asarray(pipe))

    xla = p2m_conv_jnp(imgs, w, sh, MODEL, ADC, mode, k, s)
    np.testing.assert_allclose(np.asarray(pipe), np.asarray(xla),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(4, 12), st.integers(1, 2), st.integers(2, 4),
       st.integers(0, 2), st.integers(0, 1))
def test_pipelined_grad_parity_random_geometry(h, c, k, s_raw, d_i):
    """The custom-VJP conv with the pipelined forward produces bitwise
    the same gradients as with the grid forward (grads flow through the
    saved raw accumulation, which the ring reproduces bit-for-bit), and
    matches autodiff of the XLA premix twin to tolerance."""
    h, _ = _geometry(h, h, k)
    s = k if s_raw == 0 else min(max(s_raw, 1), k)
    depth = (2, 3)[d_i]
    imgs, w, sh = _data(h, h, c, k, seed=h * 13 + c + k * s)

    def loss(depth_):
        def f(im, ww, ss):
            out = p2m_conv(im, ww, ss, MODEL, ADC, "relu", k, s, INTERPRET,
                           "pallas", depth_)
            return (out ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2))

    g_grid = loss(0)(imgs, w, sh)
    g_pipe = loss(depth)(imgs, w, sh)
    for a, b in zip(g_grid, g_pipe):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def loss_xla(im, ww, ss):
        return (p2m_conv_jnp(im, ww, ss, MODEL, ADC, "relu", k, s) ** 2).sum()

    g_xla = jax.grad(loss_xla, argnums=(0, 1, 2))(imgs, w, sh)
    for a, b in zip(g_pipe, g_xla):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_pipeline_depth_one_rejected():
    """Depth 1 would stall on its own DMA every step; the kernel refuses
    it (and negatives) rather than silently degrading."""
    imgs, w, sh = _data(10, 10, 3, 5, seed=0)
    for bad in (1, -2):
        with pytest.raises(ValueError):
            p2m_conv_pallas(imgs, w, sh, kernel=5, stride=5, coeffs=COEFFS,
                            pipeline_depth=bad, interpret=INTERPRET)


def test_pipeline_depth_deeper_than_k_clamps():
    """depth > k just fills the ring once — still bitwise the grid path."""
    imgs, w, sh = _data(15, 15, 3, 5, seed=4)
    grid = p2m_conv_pallas(imgs, w, sh, kernel=5, stride=5, coeffs=COEFFS,
                           pipeline_depth=0, interpret=INTERPRET)
    deep = p2m_conv_pallas(imgs, w, sh, kernel=5, stride=5, coeffs=COEFFS,
                           pipeline_depth=8, interpret=INTERPRET)
    np.testing.assert_array_equal(np.asarray(grid), np.asarray(deep))


# ----------------------------------------------------- gated stem parity


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 14), st.integers(1, 3), st.integers(2, 5),
       st.integers(0, 3), st.integers(0, 2), st.integers(0, 99))
def test_gated_stem_bitwise_vs_where_random_masks(h, c, k, s_raw, mode_i,
                                                  mask_seed):
    """The fused delta-gated kernel == dense Pallas + jnp.where bitwise
    under random per-slot rerun masks (including all-skip and all-rerun
    draws), and == the XLA gated twin to fp32 tolerance."""
    h, _ = _geometry(h, h, k)
    s = k if s_raw == 0 else min(max(s_raw, 1), k)
    mode = MODES[mode_i]
    b = 4
    imgs, w, sh = _data(h, h, c, k, seed=h * 11 + c * 5 + k, b=b)
    ho = _out_spatial(h, k, s)
    wo = _out_spatial(h, k, s)
    rng = np.random.default_rng(mask_seed)
    cached = jnp.asarray(rng.normal(0, 1, (b, ho, wo, N_OUT)), jnp.float32)
    rerun = jnp.asarray(rng.integers(0, 2, (b,)), bool)
    if mask_seed % 3 == 1:
        rerun = jnp.zeros((b,), bool)  # all-skip: pure cache copy
    elif mask_seed % 3 == 2:
        rerun = jnp.ones((b,), bool)  # all-rerun: dense kernel equivalent

    got = p2m_conv_pallas_gated(imgs, w, sh, cached, rerun, kernel=k,
                                stride=s, coeffs=COEFFS, mode=mode,
                                interpret=INTERPRET)
    dense = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s, coeffs=COEFFS,
                            mode=mode, interpret=INTERPRET)
    want = jnp.where(rerun[:, None, None, None], dense, cached)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    xla = p2m_conv_gated_jnp(imgs, w, sh, cached, rerun, kernel=k, stride=s,
                             coeffs=COEFFS, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(xla),
                               rtol=1e-5, atol=1e-5)


def test_aligned_block_h_divides_ho():
    """The slot-alignment clamp: largest divisor of Ho ≤ the requested
    block, so a row tile never straddles two slots and the per-tile mask
    is exact."""
    assert aligned_block_h(4, 3) == 2
    assert aligned_block_h(7, 7) == 7
    assert aligned_block_h(7, 6) == 1
    assert aligned_block_h(12, 8) == 6
    assert aligned_block_h(1, 64) == 1
    for ho in range(1, 30):
        for bh in range(1, 70):
            got = aligned_block_h(ho, bh)
            assert ho % got == 0 and got <= max(1, min(bh, ho))


# ------------------------------------------------ gated engine invariants


def _stream_fixtures():
    from repro.models.mobilenetv2 import MNV2Config, init_mnv2
    from repro.video import DetectConfig, init_detect_head

    cfg = MNV2Config(variant="p2m", image_size=20, width=0.25,
                     head_channels=16)
    dcfg = DetectConfig(head_channels=8, max_dets=4)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    det = init_detect_head(jax.random.PRNGKey(1), 16, dcfg)
    return cfg, dcfg, params, bn, det


def _gated_engine(max_streams=1, **kw):
    from repro.video import DeltaGateConfig, StreamEngine

    cfg, dcfg, params, bn, det = _stream_fixtures()
    return StreamEngine(params, bn, cfg, det, det_cfg=dcfg,
                        gate=DeltaGateConfig(threshold=0.0),
                        max_streams=max_streams, **kw)


def test_gated_engine_bitwise_matches_where_reference():
    """The acceptance pin: the fused gated-stem engine path is
    bit-identical to the where-select reference (same kernel family
    forced via stem_impl='pallas') on hold-redundant streams, while
    actually skipping stem FLOPs in-kernel."""
    from repro.video import StreamRequest, SyntheticVideo

    cfg, *_ = _stream_fixtures()

    def streams():
        return [StreamRequest(
            uid=i, frames=SyntheticVideo(image_size=cfg.image_size,
                                         n_frames=6, hold=2,
                                         seed=i).frames())
            for i in range(3)]

    gated = _gated_engine(max_streams=2, stem_path="gated")
    where = _gated_engine(max_streams=2, stem_path="where",
                          stem_impl="pallas")
    done_g = gated.run(streams())
    done_w = where.run(streams())
    assert [r.uid for r in done_g] == [r.uid for r in done_w]
    for g, w in zip(done_g, done_w):
        for (bg, sg), (bw, sw) in zip(g.frame_outputs, w.frame_outputs):
            np.testing.assert_array_equal(bg, bw)
            np.testing.assert_array_equal(sg, sw)
    sg = gated.stream_summary()
    assert sg["stem_path"] == "gated"
    # hold=2, noise=0 → half the frames are bit-identical repeats, and
    # every one of them short-circuited in-kernel
    assert sg["stem_flops_skipped_ratio"] == pytest.approx(0.5)
    assert where.stream_summary()["stem_flops_skipped_ratio"] == 0.0


def test_gated_engine_recycled_slot_cache_isolation():
    """Isolation invariant on the fused path: two identical streams back
    to back through ONE gated slot produce identical results — a leaked
    cached-stem row or gate reference from the previous occupant would
    skew the recycled stream's first frames."""
    from repro.video import StreamRequest, SyntheticVideo

    cfg, *_ = _stream_fixtures()
    eng = _gated_engine(max_streams=1, stem_path="gated")
    vid = SyntheticVideo(image_size=cfg.image_size, n_frames=5, hold=2,
                         seed=3)
    a = StreamRequest(uid=0, frames=vid.frames())
    b = StreamRequest(uid=1, frames=vid.frames())
    done = eng.run([a, b])
    assert [r.uid for r in done] == [0, 1]
    ra, rb = done
    assert ra.skip_count == rb.skip_count
    assert rb.frame_outputs and ra.frames_done == rb.frames_done
    for (ba, sa), (bb, sb) in zip(ra.frame_outputs, rb.frame_outputs):
        np.testing.assert_array_equal(ba, bb)
        np.testing.assert_array_equal(sa, sb)


def test_gated_engine_rejects_mesh():
    from repro.video.engine import _stream_forward_for

    cfg, dcfg, *_ = _stream_fixtures()
    with pytest.raises(ValueError, match="mesh"):
        _stream_forward_for.__wrapped__(cfg, dcfg, "mesh-sentinel", 2,
                                        None, "gated")


# ------------------------------------------------------- tuner satellites


def test_conv_cache_key_distinguishes_depth_menu_and_backend():
    """A winner tuned over one depth menu (or on one backend) must never
    be served for another: both ride in the cache key."""
    tune.cache_clear()
    args = (1, 12, 12, 3, 8, 3, 3, COEFFS, "relu")
    tune.get_conv_blocks(*args, enable=True, interpret=True, iters=1,
                         depths=(0,))
    tune.get_conv_blocks(*args, enable=True, interpret=True, iters=1,
                         depths=(0, 2))
    keys = [k for k in tune._CACHE if k[0] == "conv"]
    assert len(keys) == 2  # distinct depth menus → distinct entries
    backend = jax.default_backend()
    for key in keys:
        assert backend in key  # backend is part of the signature
    assert {key[-1] for key in keys} == {(0,), (0, 2)}
    # the (0,)-menu winner can never carry a pipelined depth
    (only_grid,) = [tune._CACHE[k]["best"] for k in keys if k[-1] == (0,)]
    assert only_grid[2] == 0
    tune.cache_clear()


def test_autotune_disabled_logs_defaults_once(caplog):
    """Disabled-off-TPU fallback is no longer silent: exactly one
    structured log per (kind, backend) names the backend and the
    defaults served."""
    tune.cache_clear()
    tune._DISABLED_LOGGED.clear()
    with caplog.at_level(logging.INFO, logger=tune.logger.name):
        assert tune.get_conv_blocks(1, 12, 12, 3, 8, 3, 3, COEFFS, "relu",
                                    enable=False) == (None, None, 0)
        tune.get_conv_blocks(2, 16, 16, 3, 8, 5, 5, COEFFS, "quant",
                             enable=False)  # second call: no second log
    msgs = [r.message for r in caplog.records
            if "p2m_autotune_disabled_defaults" in r.message]
    assert len(msgs) == 1
    payload = json.loads(msgs[0])
    assert payload["kind"] == "conv"
    assert payload["backend"] == jax.default_backend()
    assert payload["default"] == [None, None, 0]
    tune._DISABLED_LOGGED.clear()
