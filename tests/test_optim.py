"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, constant, cosine_warmup, sgd, step_decay
from repro.optim.optimizers import clip_by_global_norm, global_norm
from repro.train.compression import compress_grads_int8_ef


def _optimize(optimizer, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([1.5])}
    state = optimizer.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for i in range(steps):
        g = jax.grad(loss)(params)
        params, state = optimizer.update(g, state, params,
                                         jnp.asarray(i, jnp.int32))
    return float(loss(params))


def test_sgd_converges_quadratic():
    assert _optimize(sgd(constant(0.05), momentum=0.9)) < 1e-4


def test_adamw_converges_quadratic():
    assert _optimize(adamw(constant(0.05), weight_decay=0.0)) < 1e-4


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_step_decay_schedule():
    fn = step_decay(1.0, boundaries=(10, 20), factor=0.2)
    assert abs(float(fn(0)) - 1.0) < 1e-6
    assert abs(float(fn(10)) - 0.2) < 1e-6
    assert abs(float(fn(25)) - 0.04) < 1e-6


def test_cosine_warmup_schedule():
    fn = cosine_warmup(1.0, warmup=10, total=110, floor=0.1)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 1e-6
    assert float(fn(110)) <= 0.1 + 1e-6
    assert float(fn(5)) == 0.5


def test_int8_ef_compression_unbiased_longrun():
    """Error feedback: accumulated compressed grads track the true sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    comp_sum = np.zeros(64)
    err = None
    for _ in range(300):
        g = {"w": jnp.asarray(rng.normal(0, 1, 64), jnp.float32)}
        deq, err = compress_grads_int8_ef(g, err)
        true_sum += np.asarray(g["w"])
        comp_sum += np.asarray(deq["w"])
    # residual bounded by one quantization step, not growing with T
    assert np.abs(true_sum - comp_sum).max() < 0.1


def test_int8_ef_converges_on_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    opt = sgd(constant(0.05), momentum=0.9)
    state = opt.init(params)
    err = None
    for i in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        g, err = compress_grads_int8_ef(g, err)
        params, state = opt.update(g, state, params, jnp.asarray(i))
    assert float(jnp.sum(params["w"] ** 2)) < 1e-3
