"""Fault tolerance and overload control (DESIGN.md §10): deadline
eviction + admission control, the slot watchdog, launch-fault
containment, the NaN/Inf guard, the seeded fault injector (and its
bit-for-bit freeness when off), engine degradation ladders, and
front-door failure isolation."""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.launch.serve import FrontDoor
from repro.serving import (
    ADMITTED,
    REJECTED_DEADLINE,
    REJECTED_HALTED,
    REJECTED_QUEUE,
    FaultInjector,
    FaultPlan,
    Request,
    ScheduledRequest,
    ServeEngine,
    SlotEngine,
    SMOKE_PLAN,
    VisionEngine,
    VisionRequest,
    shed_deadline,
)

# ------------------------------------------------------------- dummy adapters


@dataclasses.dataclass
class _Req(ScheduledRequest):
    uid: int = 0


@dataclasses.dataclass
class _ReqB(ScheduledRequest):
    uid: int = 0


@dataclasses.dataclass
class _StreamReq(ScheduledRequest):
    uid: int = 0
    length: int = 1
    observed: list = dataclasses.field(default_factory=list)


class _OneTickEngine(SlotEngine):
    request_type = _Req

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return True


class _StatefulStreamEngine(SlotEngine):
    """Multi-tick adapter with observable per-slot state (the leak-probe
    from test_scheduler.py): the occupant sees its slot counter as
    exactly 1..length iff recycling is leak-free."""

    request_type = _StreamReq

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.slot_state = [0] * self.n_slots

    def _on_admit(self, i, req):
        self.slot_state[i] = 0

    def _launch(self, active):
        for i, _ in active:
            self.slot_state[i] += 1
        return None

    def _absorb(self, i, req, result):
        req.observed.append(self.slot_state[i])
        return len(req.observed) >= req.length


class _PoisonEngine(_StatefulStreamEngine):
    """Raises a slot-attributed fault whenever a poisoned uid occupies a
    slot — the shape of a per-request kernel fault."""

    def __init__(self, *a, poison=(), **kw):
        super().__init__(*a, **kw)
        self.poison = set(poison)

    def _launch(self, active):
        for i, r in active:
            if r.uid in self.poison:
                exc = RuntimeError(f"poisoned uid {r.uid}")
                exc.slot = i
                raise exc
        return super()._launch(active)


class _AnonFaultEngine(_OneTickEngine):
    """Raises an *anonymous* fault (no .slot) on the given ticks."""

    def __init__(self, *a, bad_ticks=(), **kw):
        super().__init__(*a, **kw)
        self.bad_ticks = set(bad_ticks)

    def _launch(self, active):
        if self.tick in self.bad_ticks:
            raise RuntimeError("anonymous launch fault")
        return None


class _FloatEngine(SlotEngine):
    """Launch result is a per-slot float array — NaN-guard territory."""

    request_type = _Req

    def _launch(self, active):
        return np.full((self.n_slots, 3), 0.5, np.float32)

    def _absorb(self, i, req, result):
        return True


class _BadAbsorbEngine(SlotEngine):
    """An adapter bug past launch containment: ``_absorb`` raises."""

    request_type = _ReqB

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        raise RuntimeError("absorb bug")


# --------------------------------------------------- deadline shedding policy


def test_shed_deadline_expired_waiter_first():
    q = [_Req(uid=0, deadline_tick=9), _Req(uid=1, deadline_tick=2),
         _Req(uid=2)]
    inc = _Req(uid=3)
    inc.submitted_tick = 3  # "now": uid1's deadline (2) already passed
    victim = shed_deadline(q, inc)
    assert victim.uid == 1
    assert [r.uid for r in q] == [0, 2]


def test_shed_deadline_lowest_priority_newest_within_class():
    q = [_Req(uid=0, priority=1), _Req(uid=1, priority=0),
         _Req(uid=2, priority=0)]
    inc = _Req(uid=3, priority=2)
    inc.submitted_tick = 0
    victim = shed_deadline(q, inc)  # lowest class {1, 2}; newest is uid2
    assert victim.uid == 2
    assert [r.uid for r in q] == [0, 1]


def test_shed_deadline_arrival_can_be_the_victim():
    q = [_Req(uid=0, priority=1)]
    inc = _Req(uid=1, priority=0)
    inc.submitted_tick = 0
    assert shed_deadline(q, inc) is inc
    assert [r.uid for r in q] == [0]


def test_engine_deadline_eviction_sheds_expired():
    """Through the engine: a bounded 'deadline' queue sheds the expired
    waiter for a fresh arrival, stamping its eviction tick."""
    eng = _StatefulStreamEngine(1, max_queue=2, evict="deadline")
    eng.submit(_StreamReq(uid=0, length=6))
    eng.step()  # uid0 admitted into the slot; queue empty, tick=1
    eng.submit(_StreamReq(uid=1, length=1, deadline_tick=2))
    eng.submit(_StreamReq(uid=2, length=1))
    eng.step()
    eng.step()  # now tick=3 > uid1's deadline
    assert eng.submit(_StreamReq(uid=3, length=1)) == ADMITTED
    assert [r.uid for r in eng.evicted] == [1]
    assert eng.evicted[0].evicted_tick == 3
    assert eng.evicted[0].queue_ticks == 2  # never negative
    assert eng.evicted[0].deadline_missed
    done = eng.run()
    assert {r.uid for r in done} == {0, 2, 3}


# ----------------------------------------------------------- admission control


def test_admission_control_rejects_projected_misses():
    eng = _OneTickEngine(1, admission="deadline")
    statuses = [eng.submit(_Req(uid=i, deadline_tick=2)) for i in range(6)]
    assert statuses[0] == ADMITTED and statuses[1] == ADMITTED
    assert statuses[2:] == [REJECTED_DEADLINE] * 4
    assert [r.uid for r in eng.rejected] == [2, 3, 4, 5]
    assert all(r.evicted and r.evicted_tick == 0 for r in eng.rejected)
    done = eng.run()
    assert [r.uid for r in done] == [0, 1]
    assert all(not r.deadline_missed for r in done)
    s = eng.latency_summary()
    assert s["rejections"] == 4 and s["rejected"] == 4


def test_admission_control_ignores_deadline_free_traffic():
    eng = _OneTickEngine(1, admission="deadline")
    assert all(eng.submit(_Req(uid=i)) == ADMITTED for i in range(10))
    assert len(eng.run()) == 10


def test_submit_status_on_queue_overflow():
    eng = _OneTickEngine(1, max_queue=1, evict="drop-newest")
    assert eng.submit(_Req(uid=0)) == ADMITTED
    assert eng.submit(_Req(uid=1)) == REJECTED_QUEUE  # arrival bounced
    old = _OneTickEngine(1, max_queue=1, evict="drop-oldest")
    assert old.submit(_Req(uid=0)) == ADMITTED
    assert old.submit(_Req(uid=1)) == ADMITTED  # the *waiter* was shed
    assert [r.uid for r in old.evicted] == [0]


def test_evicted_accounting_in_latency_summary():
    eng = _StatefulStreamEngine(1, max_queue=1, evict="drop-oldest")
    eng.submit(_StreamReq(uid=0, length=4))
    eng.step()  # uid0 admitted; queue empty
    eng.submit(_StreamReq(uid=1, length=1))
    eng.step()
    eng.submit(_StreamReq(uid=2, length=1))  # evicts uid1 at tick 2
    assert [r.uid for r in eng.evicted] == [1]
    assert eng.evicted[0].queue_ticks == 1  # submitted @1, shed @2
    assert all(r.queue_ticks >= 0
               for r in eng.evicted + eng.completed + eng.queue)
    eng.run()
    s = eng.latency_summary()
    assert s["evicted"] == 1 and s["evictions"] == 1
    assert s["failed"] == 0 and s["failures"] == 0


# ------------------------------------------------------------- slot watchdog


def test_watchdog_evicts_stuck_occupant_leak_free():
    """An injected stuck request holds its slot until ``max_serve_ticks``
    evicts it; the recycled slot serves the next stream with fresh state
    (observed == 1..length — nothing leaked)."""
    inj = FaultInjector(FaultPlan(stuck_uids=(0,)))
    eng = _StatefulStreamEngine(1, max_serve_ticks=3, faults=inj)
    done = eng.run([_StreamReq(uid=0, length=1),
                    _StreamReq(uid=1, length=2)])
    assert [r.uid for r in done] == [1]
    assert done[0].observed == [1, 2]
    assert [r.uid for r in eng.failed] == [0]
    assert eng.failed[0].failure == "watchdog"
    assert eng.failed[0].serve_ticks == 3
    assert eng.stats["watchdog_evictions"] == 1
    assert inj.counts["stuck"] == 1 and inj.poisoned_uids == {0}


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_watchdog_containment_property(seed, n_slots):
    """Property: random traffic with random stuck uids, a bounded queue,
    and the watchdog on — the engine always drains (no deadlock), every
    request is accounted exactly once, stuck uids land on the failed
    ledger, and survivors observe fresh per-slot state."""
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(4, 14))
    stuck = tuple(int(u) for u in rng.choice(n_req, n_req // 3,
                                             replace=False))
    inj = FaultInjector(FaultPlan(stuck_uids=stuck))
    eng = _StatefulStreamEngine(n_slots, max_queue=4, evict="drop-newest",
                                max_serve_ticks=4, faults=inj)
    reqs = [_StreamReq(uid=i, length=int(rng.integers(1, 4)),
                       arrival_tick=int(rng.integers(0, 6)))
            for i in range(n_req)]
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # an undrained replay fails loudly
        done = eng.run(reqs, max_ticks=400)
    assert all(s is None for s in eng.slots)
    seen = ([r.uid for r in done] + [r.uid for r in eng.failed]
            + [r.uid for r in eng.evicted])
    assert sorted(seen) == list(range(n_req))  # each accounted exactly once
    assert {r.uid for r in eng.failed} == set(stuck) - {
        r.uid for r in eng.evicted}
    for r in done:
        assert r.observed == list(range(1, r.length + 1)), (
            f"slot state leaked into request {r.uid}: {r.observed}")


# ------------------------------------------------------ drive() undrained


def test_drive_never_silently_truncates():
    inj = FaultInjector(FaultPlan(stuck_uids=(0,)))
    eng = _StatefulStreamEngine(1, faults=inj)  # no watchdog: uid0 sticks
    eng.submit(_StreamReq(uid=0, length=1))
    with pytest.warns(RuntimeWarning, match="1 slots occupied"):
        eng.run(max_ticks=5)
    eng2 = _StatefulStreamEngine(1, faults=FaultInjector(
        FaultPlan(stuck_uids=(0,))))
    eng2.submit(_StreamReq(uid=0, length=1))
    with pytest.raises(RuntimeError, match="undrained"):
        eng2.run(max_ticks=5, on_undrained="raise")


def test_drive_undrained_counts_unsubmitted_arrivals():
    eng = _OneTickEngine(1)
    with pytest.warns(RuntimeWarning, match="1 arrivals unsubmitted"):
        eng.run([_Req(uid=0, arrival_tick=50)], max_ticks=3)


# ------------------------------------------------------- launch containment


def test_slot_attributed_fault_quarantines_only_victim():
    eng = _PoisonEngine(2, poison={2, 4}, launch_retries=1)
    done = eng.run([_StreamReq(uid=i, length=2) for i in range(6)])
    assert {r.uid for r in done} == {0, 1, 3, 5}
    assert {r.uid for r in eng.failed} == {2, 4}
    assert all(r.failure == "launch" for r in eng.failed)
    # each poisoned cohort: 1 fault + 1 retry = 2 raises per poisoned uid
    assert eng.stats["launch_faults"] == 4
    for r in done:  # survivors' slots stayed clean through the retries
        assert r.observed == list(range(1, r.length + 1))


def test_anonymous_fault_quarantines_cohort_and_recovers():
    eng = _AnonFaultEngine(2, bad_ticks={1}, launch_retries=2)
    done = eng.run([_Req(uid=i) for i in range(4)])
    # tick 1's cohort (uids 0, 1) is quarantined whole — the launch
    # cannot say which occupant poisoned it; the next wave serves fine
    assert {r.uid for r in eng.failed} == {0, 1}
    assert {r.uid for r in done} == {2, 3}
    assert eng.stats["launch_faults"] == 3  # 1 fault + 2 retries


def test_transient_fault_cleared_by_retry():
    class _Transient(_OneTickEngine):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.raises_left = 1

        def _launch(self, active):
            if self.raises_left:
                self.raises_left -= 1
                raise RuntimeError("transient")
            return None

    eng = _Transient(2, launch_retries=2)
    done = eng.run([_Req(uid=i) for i in range(2)])
    assert {r.uid for r in done} == {0, 1}  # retry absorbed the fault
    assert eng.failed == []
    assert eng.stats["launch_faults"] == 1


# ------------------------------------------------------------- NaN/Inf guard


def test_nan_guard_fails_one_request_not_the_engine():
    inj = FaultInjector(FaultPlan(nan_ticks=(1,)))
    eng = _FloatEngine(2, faults=inj)
    done = eng.run([_Req(uid=i) for i in range(4)])
    assert len(done) == 3 and len(eng.failed) == 1
    assert eng.failed[0].failure == "nonfinite"
    assert eng.failed[0].uid in {0, 1}  # tick 1's cohort
    assert inj.counts["nan"] == 1


def test_serve_engine_validate_rejects_corrupted_token():
    nxt, adv = np.array([3, -1]), np.array([1, 1])
    assert ServeEngine._validate(None, 0, None, (nxt, adv))
    assert not ServeEngine._validate(None, 1, None, (nxt, adv))


# ------------------------------------------------- injector free when off


def test_zero_fault_injector_is_bitwise_free_on_schedule():
    """The same traffic through identical engines, one with a zero-rate
    injector attached: schedules, ledgers, and stats must be identical —
    the fault layer costs nothing when it injects nothing."""
    def run_one(faults):
        eng = _StatefulStreamEngine(2, max_queue=2, evict="deadline",
                                    max_serve_ticks=10, faults=faults)
        rng = np.random.default_rng(7)
        reqs = [_StreamReq(uid=i, length=int(rng.integers(1, 5)),
                           arrival_tick=int(rng.integers(0, 4)),
                           deadline_tick=20 + i, priority=i % 3)
                for i in range(9)]
        done = eng.run(reqs)
        return eng, [(r.uid, r.submitted_tick, r.served_tick,
                      r.finished_tick, r.serve_ticks, tuple(r.observed))
                     for r in done]

    bare, ledger_bare = run_one(None)
    inj = FaultInjector(FaultPlan())
    wrapped, ledger_wrapped = run_one(inj)
    assert ledger_bare == ledger_wrapped
    assert [r.uid for r in bare.evicted] == [r.uid for r in wrapped.evicted]
    for k in ("launches", "served", "evictions", "failures",
              "watchdog_evictions", "launch_faults", "slot_ticks",
              "busy_slot_ticks"):
        assert bare.stats[k] == wrapped.stats[k], k
    assert inj.counts == {"launch": 0, "nan": 0, "slow": 0, "stuck": 0}
    assert inj.poisoned_uids == set()


CFG = None  # initialized lazily by _vision_model


def _vision_model():
    global CFG
    from repro.models.mobilenetv2 import MNV2Config, init_mnv2

    if CFG is None:
        CFG = MNV2Config(variant="p2m", image_size=20, width=0.25,
                         head_channels=16)
        _vision_model.cache = init_mnv2(jax.random.PRNGKey(0), CFG)
    return _vision_model.cache


def _images(n, seed=0):
    from repro.data import SyntheticVWW

    return SyntheticVWW(image_size=20, batch=n, seed=seed).batch_at(0)["images"]


def test_zero_fault_injector_is_bitwise_free_on_real_outputs():
    """Real vision engine, same traffic with and without a zero-rate
    injector: per-request probability rows are bit-identical."""
    params, bn = _vision_model()
    imgs = _images(5)

    def run_one(faults):
        eng = VisionEngine(params, bn, CFG, max_batch=2, faults=faults)
        return eng.run([VisionRequest(uid=i, image=imgs[i])
                        for i in range(5)])

    bare = run_one(None)
    wrapped = run_one(FaultInjector(FaultPlan()))
    for a, b in zip(bare, wrapped):
        assert a.uid == b.uid and a.label == b.label
        np.testing.assert_array_equal(a.probs, b.probs)


# --------------------------------------------------------- degradation ladder


def test_vision_engine_degrades_to_patches_and_keeps_serving():
    params, bn = _vision_model()
    imgs = _images(4)
    inj = FaultInjector(FaultPlan(launch_error_ticks=(1,)))
    eng = VisionEngine(params, bn, CFG, max_batch=1, degrade_after=1,
                       launch_retries=0, faults=inj)
    done = eng.run([VisionRequest(uid=i, image=imgs[i]) for i in range(4)])
    assert eng.degraded == "patches"
    assert eng.health()["degraded"] == "patches"
    # tick 1's occupant was quarantined; the rest served on the
    # reference conv with valid probabilities
    assert {r.uid for r in eng.failed} == {0}
    assert {r.uid for r in done} == {1, 2, 3}
    for r in done:
        assert np.isfinite(r.probs).all() and r.label is not None


def test_stream_engine_gate_drops_to_dense_on_poisoned_cache():
    """Corrupt a stream's cached stem mid-flight: the on-device check
    forces a re-run (finite outputs), the gate drops to dense, and the
    remaining frames all re-run — the ledger meters the recovery."""
    import jax.numpy as jnp

    from repro.models.mobilenetv2 import head_out_channels
    from repro.video import (DetectConfig, StreamEngine, StreamRequest,
                             SyntheticVideo, init_detect_head)

    params, bn = _vision_model()
    det = init_detect_head(jax.random.PRNGKey(2), head_out_channels(CFG),
                           DetectConfig())
    eng = StreamEngine(params, bn, CFG, det, max_streams=1)
    frames = SyntheticVideo(image_size=20, n_frames=6, hold=6,
                            seed=0).frames()  # fully redundant: gate skips
    req = StreamRequest(uid=0, frames=frames)
    eng.submit(req)
    eng.step()  # frame 0: rerun (no reference yet), cache filled
    eng.step()  # frame 1: skipped (bit-identical)
    assert req.ledger.rerun_frames == 1
    # poison the cached stem — a corrupted analog activation in state
    eng._cached_stem = eng._cached_stem.at[0, 0, 0, 0].set(jnp.nan)
    eng.step()  # frame 2: forced re-run, gate disabled
    assert eng._gates[0].disabled
    assert eng._gate_faults == 1
    assert eng.health()["gate_faults"] == 1
    done = eng.run()
    assert [r.uid for r in done] == [0]
    # frames 2..5 all re-ran (dense after the fault); only frame 1 skipped
    assert req.ledger.rerun_frames == 5
    for boxes, scores in req.frame_outputs:
        assert np.isfinite(boxes).all() and np.isfinite(scores).all()


def test_delta_gate_disable_and_self_validation():
    from repro.core.bandwidth import FirstLayerGeom
    from repro.video.delta import DeltaGate, DeltaGateConfig

    geom = FirstLayerGeom(image_size=8, kernel=4, padding=0, stride=4,
                          out_channels=4, out_bits=8)
    frame = np.zeros((8, 8, 3), np.float32)
    gate = DeltaGate(DeltaGateConfig(threshold=1.0), geom)
    assert gate.should_rerun(frame)  # no reference yet
    gate.observe(frame, True)
    assert not gate.should_rerun(frame)  # identical + huge threshold
    gate.disable()
    assert gate.should_rerun(frame)  # disabled ⇒ dense forever

    # a reference that stopped matching the stream self-disables
    g2 = DeltaGate(DeltaGateConfig(threshold=1.0), geom)
    g2.observe(frame, True)
    assert g2.should_rerun(np.zeros((4, 4, 3), np.float32))
    assert g2.disabled

    # a non-finite reference self-disables
    g3 = DeltaGate(DeltaGateConfig(threshold=1.0), geom)
    g3.observe(np.full((8, 8, 3), np.nan, np.float32), True)
    assert g3.should_rerun(frame)
    assert g3.disabled


# ------------------------------------------------------ halt + front door


def test_halt_fails_all_traffic_visibly():
    eng = _StatefulStreamEngine(1)
    eng.submit(_StreamReq(uid=0, length=5))
    eng.submit(_StreamReq(uid=1, length=1))
    eng.step()
    eng.halt("test outage")
    assert not eng.busy()
    assert {r.uid for r in eng.failed} == {0, 1}
    assert all(r.failure == "halt:test outage" for r in eng.failed)
    assert eng.queue == [] and all(s is None for s in eng.slots)
    assert eng.submit(_StreamReq(uid=2, length=1)) == REJECTED_HALTED
    assert eng.step() == []
    assert eng.health()["halted"] == "test outage"


def test_front_door_isolates_failed_engine():
    """One engine's step blowing past launch containment (an adapter
    bug) halts that engine; the other keeps serving, submissions to the
    dead one bounce, and the health report names the outage."""
    good, bad = _OneTickEngine(2), _BadAbsorbEngine(2)
    door = FrontDoor(good=good, bad=bad)
    reqs = ([_Req(uid=i) for i in range(4)]
            + [_ReqB(uid=10 + i) for i in range(3)])
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        done = door.run(reqs)
    assert [n for n, _ in done] == ["good"] * 4
    assert "bad" in door.down and "absorb bug" in door.down["bad"]
    assert bad.halted is not None
    assert {r.uid for r in bad.failed} == {10, 11, 12}
    assert door.submit(_ReqB(uid=13)) == REJECTED_HALTED
    assert door.submit(_Req(uid=4)) == ADMITTED
    assert [r.uid for _, r in door.run()] == [0, 1, 2, 3, 4]
    health = door.health()
    assert health["down"] == door.down
    assert health["engines"]["bad"]["halted"] is not None
    assert health["engines"]["good"]["halted"] is None


def test_front_door_chaos_smoke_never_deadlocks():
    """Dummy-adapter chaos at SMOKE_PLAN rates through the front door:
    the replay always drains within the tick budget and every request is
    accounted exactly once — the acceptance no-deadlock property at
    scheduler scale (the real-model version runs in
    benchmarks/bench_serve_chaos.py, gated by scripts/bench_gate.py)."""
    rng = np.random.default_rng(0)
    a = _OneTickEngine(2, max_queue=4, evict="deadline",
                       admission="deadline", max_serve_ticks=6,
                       launch_retries=1,
                       faults=FaultInjector(SMOKE_PLAN))
    b = _StatefulStreamEngine(
        2, max_queue=4, evict="deadline", admission="deadline",
        max_serve_ticks=8, launch_retries=1,
        faults=FaultInjector(dataclasses.replace(SMOKE_PLAN, seed=1)))
    door = FrontDoor(a=a, b=b)
    reqs = [_Req(uid=i, arrival_tick=int(rng.integers(0, 6)),
                 deadline_tick=int(rng.integers(10, 40)))
            for i in range(12)]
    reqs += [_StreamReq(uid=100 + i, length=int(rng.integers(1, 5)),
                        arrival_tick=int(rng.integers(0, 6)),
                        deadline_tick=int(rng.integers(20, 60)))
             for i in range(12)]
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # undrained replay ⇒ loud failure
        door.run(reqs, max_ticks=400)
    for eng in (a, b):
        assert all(s is None for s in eng.slots)
        seen = [r.uid for r in
                eng.completed + eng.failed + eng.evicted + eng.rejected]
        assert sorted(seen) == sorted(set(seen))  # exactly-once accounting
    total = sum(len(e.completed) + len(e.failed) + len(e.evicted)
                + len(e.rejected) for e in (a, b))
    assert total == 24
    assert len(door.completed) > 0  # chaos never starved the floor


def test_serve_engine_contains_injected_corruption_end_to_end():
    """Real LM engine under an injected corrupted decode row: the -1
    token (the int analogue of NaN) fails its own request; the cohort's
    survivors finish with valid outputs."""
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models.families import get_family

    cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    params, _ = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    inj = FaultInjector(FaultPlan(nan_ticks=(2,)))
    eng = ServeEngine(params, cfg, max_batch=2, max_len=16, faults=inj)
    done = eng.run([Request(uid=i, prompt=[1 + i], max_new_tokens=2)
                    for i in range(3)])
    assert len(eng.failed) == 1 and eng.failed[0].failure == "nonfinite"
    assert {r.uid for r in done} == set(range(3)) - {eng.failed[0].uid}
    for r in done:
        assert len(r.output) == 2 and all(t >= 0 for t in r.output)


# ----------------------------- multi-device lane (scripts/ci.sh re-runs
# this test under XLA_FLAGS=--xla_force_host_platform_device_count=8)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (CI multi-device lane)")


@needs8
def test_sharded_engine_contains_faults_like_single_device():
    """Fault containment under a data mesh: the sharded vision engine
    quarantines the same requests and completes the same survivors as
    the single-device engine under an identical injection plan —
    containment is scheduler semantics, independent of the launch's
    device topology (DESIGN.md §10)."""
    from repro.launch.mesh import make_debug_mesh

    params, bn = _vision_model()
    imgs = _images(8)
    plan = FaultPlan(launch_error_ticks=(1,), nan_ticks=(3,))

    def run_one(mesh):
        eng = VisionEngine(params, bn, CFG, max_batch=8, mesh=mesh,
                           launch_retries=0, degrade_after=100,
                           faults=FaultInjector(plan))
        done = eng.run([VisionRequest(uid=i, image=imgs[i],
                                      arrival_tick=i // 4)
                        for i in range(8)])
        return eng, done

    single, d1 = run_one(None)
    sharded, d8 = run_one(make_debug_mesh(8))
    assert [r.uid for r in d1] == [r.uid for r in d8]
    assert ([(r.uid, r.failure) for r in single.failed]
            == [(r.uid, r.failure) for r in sharded.failed])
    assert single.stats["launch_faults"] == sharded.stats["launch_faults"]
    for a, b in zip(d1, d8):
        np.testing.assert_allclose(b.probs, a.probs, rtol=1e-4, atol=1e-3)
