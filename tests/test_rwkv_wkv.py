"""Chunked-RWKV6 WKV kernel: parity against the naive recurrence.

The naive per-token scan (`rwkv6.wkv_naive`) is the executable spec.
Everything here pins the chunked implementations — the XLA reference
twin, the Pallas kernel (interpret mode on CPU), and the `custom_vjp`
backward — to it, forward and gradients, including non-zero initial
states and sequence lengths off the chunk quantum (DESIGN.md §12).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.kernels.rwkv_wkv import ops as wkv_ops
from repro.kernels.rwkv_wkv.ref import wkv_chunked_ref
from repro.models import rwkv6

jax.config.update("jax_enable_x64", False)


def _rand_inputs(key, b, s, h, d, scale=1.0):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, s, h, d), jnp.float32) * scale
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32) * scale
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32) * scale
    # log-decays in the clamped band the model produces
    lw = -jax.random.uniform(ks[3], (b, s, h, d), jnp.float32,
                             1e-4, 4.0)
    u = jax.random.normal(ks[4], (h, d), jnp.float32) * 0.3
    s0 = jax.random.normal(ks[5], (b, h, d, d), jnp.float32) * scale
    return r, k, v, lw, u, s0


def _naive(r, k, v, lw, u, s0):
    return rwkv6.wkv_naive(r, k, v, lw, u, s0)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("shape", [(2, 45, 3, 16), (1, 16, 1, 8),
                                   (3, 7, 2, 32)])
def test_chunked_forward_matches_naive(impl, shape):
    b, s, h, d = shape
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(0), b, s, h, d)
    y_ref, s_ref = _naive(r, k, v, lw, u, s0)
    y, sf = wkv_ops.wkv(r, k, v, lw, u, s0, impl=impl)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sf, s_ref, rtol=1e-4, atol=1e-4)


def test_xla_twin_matches_ref():
    """ops.wkv(impl='xla') and the plain scan reference are the same
    math — any drift means the custom_vjp primal diverged from ref."""
    b, s, h, d = 2, 33, 2, 16
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(1), b, s, h, d)
    y1, sf1 = wkv_ops.wkv(r, k, v, lw, u, s0, impl="xla")
    y2, sf2 = wkv_chunked_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sf1, sf2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_chunked_grads_match_naive_autodiff(impl):
    """Closed-form VJP vs jax.grad through the naive scan — all six
    inputs, with a loss touching both outputs so dS0/dlw's state term
    is exercised."""
    b, s, h, d = 2, 21, 2, 16
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(2), b, s, h, d,
                                      scale=0.5)

    def loss(fn):
        def f(r, k, v, lw, u, s0):
            y, sf = fn(r, k, v, lw, u, s0)
            return (jnp.sin(y).sum() + 0.3 * jnp.cos(sf).sum())
        return f

    g_ref = jax.grad(loss(_naive), argnums=(0, 1, 2, 3, 4, 5))(
        r, k, v, lw, u, s0)
    g = jax.grad(loss(functools.partial(wkv_ops.wkv, impl=impl)),
                 argnums=(0, 1, 2, 3, 4, 5))(r, k, v, lw, u, s0)
    names = ["dr", "dk", "dv", "dlw", "du", "dS0"]
    for name, a, bref in zip(names, g, g_ref):
        np.testing.assert_allclose(a, bref, rtol=2e-3, atol=2e-4,
                                   err_msg=name)


def test_unknown_impl_raises():
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(3), 1, 4, 1, 8)
    with pytest.raises(ValueError, match="impl"):
        wkv_ops.wkv(r, k, v, lw, u, s0, impl="cuda")


def test_zero_length_padding_is_exact():
    """Tail chunk padding must be a no-op: S=chunk+1 and S=chunk give
    identical prefixes, and the padded final state equals the naive
    state at the true length."""
    b, h, d, c = 2, 2, 8, 16
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(4), b, c + 1, h, d)
    y_ref, s_ref = _naive(r, k, v, lw, u, s0)
    y, sf = wkv_ops.wkv(r, k, v, lw, u, s0, impl="xla", chunk=c)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sf, s_ref, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ property suite


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3),      # batch
       st.integers(1, 40),     # sequence length
       st.integers(2, 24),     # chunk size
       st.integers(0, 2 ** 31 - 1))
def test_property_chunked_equals_naive(b, s, chunk, seed):
    """Satellite 3: `wkv_chunked` == `wkv_naive` — output AND final
    state — over random lengths, chunk sizes, and non-zero initial
    states.  Runs the model-level dispatcher so the exact code path the
    LM forward uses is the one pinned."""
    h, d = 2, 8
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(seed), b, s, h, d)
    y_ref, s_ref = _naive(r, k, v, lw, u, s0)
    for impl in ("xla", "pallas"):
        y, sf = rwkv6.wkv_chunked(r, k, v, lw, u, s0, chunk=chunk,
                                  impl=impl)
        np.testing.assert_allclose(
            y, y_ref, rtol=1e-4, atol=1e-4,
            err_msg=f"{impl} output b={b} s={s} chunk={chunk}")
        np.testing.assert_allclose(
            sf, s_ref, rtol=1e-4, atol=1e-4,
            err_msg=f"{impl} state b={b} s={s} chunk={chunk}")


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 40), st.integers(2, 24),
       st.integers(0, 2 ** 31 - 1))
def test_property_grads_match(s, chunk, seed):
    """Gradient flavor of the property: closed-form VJP tracks the naive
    autodiff across random lengths/chunks (scalar loss over output and
    state keeps every gradient path live)."""
    b, h, d = 2, 1, 8
    r, k, v, lw, u, s0 = _rand_inputs(jax.random.PRNGKey(seed), b, s, h, d,
                                      scale=0.5)

    def mk(fn):
        return lambda *a: (fn(*a)[0].sum() + fn(*a)[1].sum())

    g_ref = jax.grad(mk(_naive), argnums=(0, 1, 2, 3, 4, 5))(
        r, k, v, lw, u, s0)
    g = jax.grad(mk(functools.partial(wkv_ops.wkv, chunk=chunk,
                                      impl="xla")),
                 argnums=(0, 1, 2, 3, 4, 5))(r, k, v, lw, u, s0)
    for name, a, bref in zip(["dr", "dk", "dv", "dlw", "du", "dS0"],
                             g, g_ref):
        np.testing.assert_allclose(
            a, bref, rtol=2e-3, atol=2e-4,
            err_msg=f"{name} s={s} chunk={chunk}")
