"""ADC counter semantics, BN folding, post-training quantization."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.adc import ADCConfig, adc_counts, adc_dequant, shifted_relu, ste_adc
from repro.core.bn_fold import bn_affine, deploy_params, fold_error
from repro.core.p2m_conv import (
    P2MConvConfig,
    apply_p2m_conv_deploy,
    apply_p2m_conv_train,
    extract_patches,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.pixel_model import default_pixel_model, fit_pixel_model
from repro.core.quant import QuantSpec, fake_quant, quantize_deploy, quantize_symmetric

ADC = ADCConfig()


def test_adc_counts_clamp_and_preset():
    v = jnp.array([-0.5, 0.0, 0.5, 2.0])
    c = adc_counts(v, ADC, preset_counts=10)
    assert c.dtype == jnp.int32
    # 0.5/Δ = 127.4999… in fp32 → 127 counts, +10 preset
    np.testing.assert_array_equal(np.asarray(c), [0, 10, 137, 255])


def test_shifted_relu_matches_counts_dequant():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.uniform(-1, 2, 1000), jnp.float32)
    shift = 0.1
    soft = shifted_relu(v, shift, ADC)
    hard = adc_dequant(adc_counts(v, ADC, preset_counts=round(shift / ADC.v_lsb)), ADC)
    assert float(jnp.abs(soft - hard).max()) <= ADC.v_lsb  # ≤ 1 LSB apart


def test_ste_adc_gradient_is_cliplinear():
    v = jnp.asarray([-0.5, 0.3, 1.5])
    g = jax.grad(lambda x: ste_adc(x, 0.0, ADC).sum())(v)
    np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 0.0], atol=1e-6)


def test_bn_affine():
    gamma, beta = jnp.asarray([2.0]), jnp.asarray([1.0])
    mean, var = jnp.asarray([0.5]), jnp.asarray([4.0])
    a, b = bn_affine(gamma, beta, mean, var, eps=0.0)
    x = jnp.linspace(-2, 2, 11)
    direct = gamma * (x - mean) / jnp.sqrt(var) + beta
    np.testing.assert_allclose(np.asarray(a * x + b), np.asarray(direct),
                               rtol=1e-6)


def _trained_like_params(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_p2m_conv(key, cfg)
    state = init_p2m_state(cfg)
    # make BN stats non-trivial
    state = {"bn_mean": state["bn_mean"] + 0.1, "bn_var": state["bn_var"] * 0.5}
    params["bn_gamma"] = params["bn_gamma"] * 0.8
    params["bn_beta"] = params["bn_beta"] + 0.05
    return params, state


def test_fold_exact_for_linear_pixel_model():
    cfg = P2MConvConfig()
    lin = fit_pixel_model(degree_w=1, degree_x=3)
    params, state = _trained_like_params(cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 20, 20, 3))
    patches = extract_patches(imgs, 5, 5).reshape(-1, 75)
    err = fold_error(params, state, cfg, lin, patches)
    assert err < 1e-5  # linear-in-w ⇒ the paper's fold is exact


def test_fold_error_small_for_degree3():
    cfg = P2MConvConfig()
    params, state = _trained_like_params(cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 20, 20, 3))
    patches = extract_patches(imgs, 5, 5).reshape(-1, 75)
    err = fold_error(params, state, cfg, default_pixel_model(), patches)
    assert err < 0.05  # nonlinear residual, quantified (≈ LSBs)


def test_train_vs_deploy_consistency():
    """Eval-mode train form ≈ deploy form (≤ fold error + 1 LSB)."""
    cfg = P2MConvConfig()
    params, state = _trained_like_params(cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 20, 20, 3))
    train_out, _ = apply_p2m_conv_train(params, state, imgs, cfg, train=False)
    dep = deploy_params(params, state, cfg)
    deploy_out = apply_p2m_conv_deploy(dep, imgs, cfg, quantize=True,
                                       use_pallas=False)
    assert float(jnp.abs(train_out - deploy_out).max()) < 0.08


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_quantize_idempotent(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-3, 3, (17, 5)), jnp.float32)
    q1 = fake_quant(x, bits, axis=1)
    q2 = fake_quant(q1, bits, axis=1)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-5, atol=1e-5)


def test_quantize_symmetric_range():
    x = jnp.asarray(np.random.default_rng(1).uniform(-2, 2, (64,)), jnp.float32)
    q, scale = quantize_symmetric(x, 8)
    assert int(jnp.abs(q).max()) <= 127
    err = jnp.abs(jnp.asarray(q, jnp.float32) * scale - x).max()
    assert float(err) <= float(scale) * 0.5 + 1e-7


def test_quantize_deploy_monotone_error():
    """Fig. 7(a) trend: fewer bits ⇒ more output deviation."""
    cfg = P2MConvConfig()
    params, state = _trained_like_params(cfg, seed=3)
    dep = deploy_params(params, state, cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(4), (2, 20, 20, 3))
    ref = apply_p2m_conv_deploy(dep, imgs, cfg, quantize=False, use_pallas=False)
    errs = []
    for bits in (8, 6, 4, 2):
        depq = quantize_deploy(dep, QuantSpec(w_bits=bits, out_bits=bits))
        cfgq = P2MConvConfig(n_bits=bits)
        out = apply_p2m_conv_deploy(depq, imgs, cfgq, quantize=True,
                                    use_pallas=False)
        errs.append(float(jnp.abs(out - ref).mean()))
    assert errs == sorted(errs)  # monotone non-decreasing as bits shrink
