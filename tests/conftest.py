import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_memory_maps():
    """Drop compiled executables between test modules.

    Every XLA-CPU compile mmaps several regions for its jitted code and
    keeps them for the life of the cache entry.  The full suite performs
    enough distinct compiles that a single pytest process crosses the
    kernel's default ``vm.max_map_count`` (65530) near the end of the
    run, and the *next* compile segfaults inside LLVM when mmap fails —
    deterministically, in whichever test file happens to sit past the
    ceiling alphabetically.  Clearing JAX's caches at module boundaries
    returns those maps (measured: ~65k maps at the crash point without
    this fixture; bounded well under the ceiling with it) at the cost of
    recompiling whatever a later module would have shared — little,
    since modules mostly use distinct configs.
    """
    yield
    jax.clear_caches()
