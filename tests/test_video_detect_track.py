"""Video subsystem units: the synthetic moving-object source
(determinism, ground truth, temporal redundancy), the CenterNet-lite
detection head (decode geometry, shape-stable top-k, trainability), and
greedy-IoU track association."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.video import (
    DetectConfig,
    SyntheticVideo,
    Tracker,
    apply_detect_head,
    decode_detections,
    detect_loss,
    init_detect_head,
    iou_matrix,
    render_targets,
)
from repro.video.detect import det_grid


# ------------------------------------------------------------- synthetic


def test_synthetic_video_deterministic_and_shape_stable():
    a = SyntheticVideo(image_size=24, n_frames=5, n_objects=2, seed=7)
    b = SyntheticVideo(image_size=24, n_frames=5, n_objects=2, seed=7)
    fa, fb = a.frames(), b.frames()
    assert fa.shape == (5, 24, 24, 3) and fa.dtype == np.float32
    np.testing.assert_array_equal(fa, fb)
    assert fa.min() >= 0.0 and fa.max() <= 1.0
    boxes, ids = a.boxes_at(3)
    assert boxes.shape == (2, 4) and ids.shape == (2,)
    assert (boxes >= 0).all() and (boxes <= 1).all()
    assert (boxes[:, 2:] > boxes[:, :2]).all()  # x1 > x0, y1 > y0
    # different seed -> different stream
    c = SyntheticVideo(image_size=24, n_frames=5, n_objects=2, seed=8)
    assert not np.array_equal(fa[0], c.frame_at(0)["image"])


def test_synthetic_video_hold_gives_bit_identical_frames():
    """Within a hold group frames are bit-identical (the temporal
    redundancy the delta gate exploits); across groups objects moved."""
    v = SyntheticVideo(image_size=24, n_frames=6, hold=3, seed=0)
    f = v.frames()
    np.testing.assert_array_equal(f[0], f[1])
    np.testing.assert_array_equal(f[1], f[2])
    assert not np.array_equal(f[2], f[3])
    # noise breaks redundancy
    vn = SyntheticVideo(image_size=24, n_frames=6, hold=3, seed=0,
                        noise=0.02)
    fn = vn.frames()
    assert not np.array_equal(fn[0], fn[1])


def test_synthetic_video_noise_breaks_every_hold_and_replays():
    """noise > 0 is per-frame (keyed on (seed, t)): every consecutive
    pair differs — including *within* hold groups, where the clean
    stream is bit-identical — yet the stream replays deterministically,
    stays in [0, 1], and leaves the ground truth untouched."""
    mk = lambda: SyntheticVideo(image_size=24, n_frames=8, hold=4, seed=3,
                                noise=0.05)
    fa, fb = mk().frames(), mk().frames()
    np.testing.assert_array_equal(fa, fb)  # deterministic replay
    for t in range(7):
        assert not np.array_equal(fa[t], fa[t + 1]), f"hold at t={t}"
    assert (fa >= 0.0).all() and (fa <= 1.0).all()
    # ground truth is noise-free: same boxes/ids as the clean stream
    clean = SyntheticVideo(image_size=24, n_frames=8, hold=4, seed=3)
    np.testing.assert_array_equal(mk().gt_boxes(), clean.gt_boxes())
    # distinct seeds draw distinct noise over the same layout seed space
    other = SyntheticVideo(image_size=24, n_frames=8, hold=4, seed=4,
                           noise=0.05)
    assert not np.array_equal(fa[0], other.frames()[0])


def test_synthetic_video_noise_defeats_lossless_gate():
    """With per-frame noise a threshold-0 ('lossless') delta gate never
    skips — the bit-level redundancy it exploits is gone — while a
    tolerant threshold above the noise floor still gates within holds."""
    from repro.core.bandwidth import FirstLayerGeom
    from repro.video.delta import DeltaGate, DeltaGateConfig

    geom = FirstLayerGeom(image_size=24, kernel=4, padding=0, stride=4,
                          out_channels=4, out_bits=8)

    def reruns(threshold, noise):
        v = SyntheticVideo(image_size=24, n_frames=6, hold=3, seed=0,
                           noise=noise)
        gate = DeltaGate(DeltaGateConfig(threshold=threshold), geom)
        out = []
        for f in v.frames():
            r = gate.should_rerun(f)
            gate.observe(f, r)
            out.append(r)
        return out

    assert reruns(0.0, 0.02) == [True] * 6  # noise kills lossless gating
    assert reruns(0.0, 0.0) == [True, False, False, True, False, False]
    tolerant = reruns(0.2, 0.02)
    assert tolerant[0] and not all(tolerant)  # above-noise threshold gates


def test_synthetic_video_objects_move_and_stay_inside():
    v = SyntheticVideo(image_size=32, n_frames=20, hold=1, seed=1)
    gt = v.gt_boxes()
    assert gt.shape == (20, 2, 4)
    assert (gt >= -1e-6).all() and (gt <= 1 + 1e-6).all()
    # trajectories actually move
    assert np.abs(gt[0] - gt[-1]).max() > 0.05


# ---------------------------------------------------------------- detect


def test_detect_head_decode_recovers_planted_peaks():
    """Hand-build head outputs with two gaussian-free peaks: decode must
    return them as the top detections at the right locations."""
    h = w = 8
    hm = np.full((1, h, w, 1), 0.05, np.float32)
    hm[0, 2, 3, 0] = 0.9
    hm[0, 6, 5, 0] = 0.7
    size = np.full((1, h, w, 2), 0.25, np.float32)
    off = np.full((1, h, w, 2), 0.5, np.float32)
    boxes, scores = decode_detections(
        {"heatmap": jnp.asarray(hm), "size": jnp.asarray(size),
         "offset": jnp.asarray(off)}, k=4)
    boxes, scores = np.asarray(boxes), np.asarray(scores)
    assert scores.shape == (1, 4) and boxes.shape == (1, 4, 4)
    assert scores[0, 0] == pytest.approx(0.9)
    assert scores[0, 1] == pytest.approx(0.7)
    # first peak at cell (y=2, x=3), offset 0.5 → center (3.5/8, 2.5/8)
    cx = (boxes[0, 0, 0] + boxes[0, 0, 2]) / 2
    cy = (boxes[0, 0, 1] + boxes[0, 0, 3]) / 2
    assert cx == pytest.approx(3.5 / 8, abs=1e-6)
    assert cy == pytest.approx(2.5 / 8, abs=1e-6)
    # width/height from the size head
    assert boxes[0, 0, 2] - boxes[0, 0, 0] == pytest.approx(0.25, abs=1e-6)


def test_detect_head_decode_local_max_suppression():
    """A plateau neighbor of a stronger peak is suppressed by the 3x3
    local-max rule."""
    h = w = 8
    hm = np.zeros((1, h, w, 1), np.float32)
    hm[0, 4, 4, 0] = 0.9
    hm[0, 4, 5, 0] = 0.8  # adjacent, weaker: must not appear as a peak
    hm[0, 1, 1, 0] = 0.5
    outs = {"heatmap": jnp.asarray(hm),
            "size": jnp.asarray(np.full((1, h, w, 2), 0.2, np.float32)),
            "offset": jnp.asarray(np.zeros((1, h, w, 2), np.float32))}
    _, scores = decode_detections(outs, k=3)
    s = np.asarray(scores)[0]
    assert s[0] == pytest.approx(0.9)
    assert s[1] == pytest.approx(0.5)  # 0.8 neighbor suppressed
    assert s[2] == pytest.approx(0.0)


def test_detect_head_topk_pads_on_tiny_grids():
    """k larger than the grid: decode clamps top-k and zero-pads to the
    contracted shape (smoke-size feature maps)."""
    h = w = 2
    outs = {"heatmap": jnp.asarray(np.random.default_rng(0).random(
        (1, h, w, 1)).astype(np.float32)),
            "size": jnp.zeros((1, h, w, 2)),
            "offset": jnp.zeros((1, h, w, 2))}
    boxes, scores = decode_detections(outs, k=8)
    assert boxes.shape == (1, 8, 4) and scores.shape == (1, 8)
    assert np.asarray(scores)[0, 4:].max() == 0.0


def test_detect_head_shapes_and_loss_step():
    """Head applies on backbone-shaped features; one SGD step on the
    CenterNet loss against rendered targets decreases it."""
    rng = jax.random.PRNGKey(0)
    dcfg = DetectConfig(head_channels=8, max_dets=4)
    feats = jax.random.uniform(rng, (2, 1, 1, 16))  # pooled-size features
    grid = det_grid(8)  # stem 8 → grid 4
    params = init_detect_head(rng, 16, dcfg)
    outs = apply_detect_head(params, feats, grid)
    assert outs["heatmap"].shape == (2, grid, grid, 1)
    assert outs["size"].shape == (2, grid, grid, 2)

    boxes = np.array([[0.2, 0.2, 0.5, 0.6]], np.float32)
    tgt_np = render_targets(boxes, grid, grid)
    tgt = {k: jnp.asarray(v)[None] for k, v in tgt_np.items()}

    def loss_fn(p):
        return detect_loss(apply_detect_head(p, feats[:1], grid), tgt)

    step = jax.jit(lambda p: jax.tree.map(
        lambda x, g: x - 0.01 * g, p, jax.grad(loss_fn)(p)))
    l0 = float(loss_fn(params))
    for _ in range(5):
        params = step(params)
    l1 = float(loss_fn(params))
    assert np.isfinite(l0) and l1 < l0


def test_render_targets_geometry():
    t = render_targets(np.array([[0.25, 0.25, 0.75, 0.75]], np.float32),
                       8, 8)
    assert t["heatmap"].max() == pytest.approx(1.0)
    assert t["mask"].sum() == 1.0
    iy, ix = np.unravel_index(t["heatmap"][..., 0].argmax(), (8, 8))
    assert (iy, ix) == (4, 4)
    np.testing.assert_allclose(t["size"][iy, ix], [0.5, 0.5])


# ----------------------------------------------------------------- track


def test_iou_matrix_values():
    a = np.array([[0.0, 0.0, 0.5, 0.5]], np.float32)
    b = np.array([[0.0, 0.0, 0.5, 0.5], [0.25, 0.25, 0.75, 0.75],
                  [0.6, 0.6, 0.9, 0.9]], np.float32)
    m = iou_matrix(a, b)
    assert m.shape == (1, 3)
    assert m[0, 0] == pytest.approx(1.0)
    assert m[0, 1] == pytest.approx(0.0625 / (0.5 - 0.0625), rel=1e-5)
    assert m[0, 2] == 0.0


def test_tracker_id_stability_and_birth():
    trk = Tracker(iou_thresh=0.3)
    b0 = np.array([[0.1, 0.1, 0.3, 0.3], [0.6, 0.6, 0.8, 0.8]], np.float32)
    live = trk.update(b0, np.array([0.9, 0.8], np.float32))
    assert sorted(t.tid for t in live) == [0, 1]
    # slight motion: same ids persist
    b1 = b0 + 0.02
    live = trk.update(b1, np.array([0.9, 0.8], np.float32))
    assert sorted(t.tid for t in live) == [0, 1]
    assert all(t.hits == 2 for t in live)
    # a new far-away detection births id 2
    b2 = np.vstack([b1, [[0.05, 0.7, 0.15, 0.9]]]).astype(np.float32)
    live = trk.update(b2, np.array([0.9, 0.8, 0.7], np.float32))
    assert sorted(t.tid for t in live) == [0, 1, 2]


def test_tracker_ages_out_stale_tracks():
    trk = Tracker(iou_thresh=0.3, max_age=1)
    trk.update(np.array([[0.1, 0.1, 0.3, 0.3]], np.float32),
               np.array([0.9], np.float32))
    # two empty frames: the track survives one, then retires
    assert trk.update(np.zeros((0, 4)), np.zeros((0,))) == []
    assert len(trk.tracks) == 1
    trk.update(np.zeros((0, 4)), np.zeros((0,)))
    assert trk.tracks == []


def test_tracker_greedy_prefers_highest_iou():
    trk = Tracker(iou_thresh=0.1)
    trk.update(np.array([[0.0, 0.0, 0.4, 0.4]], np.float32),
               np.array([0.9], np.float32))
    # two candidates overlap; the greedy match takes the higher-IoU one
    dets = np.array([[0.0, 0.0, 0.4, 0.4], [0.1, 0.1, 0.5, 0.5]],
                    np.float32)
    live = trk.update(dets, np.array([0.5, 0.6], np.float32))
    by_id = {t.tid: t for t in live}
    np.testing.assert_allclose(by_id[0].box, dets[0])  # exact match won
    assert 1 in by_id  # the other detection birthed a new track
