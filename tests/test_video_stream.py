"""StreamEngine: multi-tick stream slots over the scheduler core —
delta-gated vs dense exactness (the acceptance contract), measured
readout bandwidth, mixed-length slot occupancy, per-slot state
isolation across recycled streams, FrontDoor routing, and (on the CI
multi-device lane) data-mesh-sharded parity."""
import jax
import numpy as np
import pytest

from repro.core.bandwidth import frame_output_bits
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.video import (
    DeltaGateConfig,
    DetectConfig,
    StreamEngine,
    StreamRequest,
    SyntheticVideo,
    init_detect_head,
)

CFG = MNV2Config(variant="p2m", image_size=20, width=0.25, head_channels=16)
DCFG = DetectConfig(head_channels=8, max_dets=4)

_MODELS: dict = {}


def _model():
    if not _MODELS:
        params, bn = init_mnv2(jax.random.PRNGKey(0), CFG)
        det = init_detect_head(jax.random.PRNGKey(1), 16, DCFG)
        _MODELS["m"] = (params, bn, det)
    return _MODELS["m"]


def _engine(gate=DeltaGateConfig(threshold=0.0), **kw):
    params, bn, det = _model()
    kw.setdefault("max_streams", 2)
    return StreamEngine(params, bn, CFG, det, det_cfg=DCFG, gate=gate, **kw)


def _streams(n, n_frames=6, hold=2, **kw):
    return [StreamRequest(
        uid=i, frames=SyntheticVideo(image_size=CFG.image_size,
                                     n_frames=n_frames, hold=hold,
                                     seed=i, **kw).frames())
        for i in range(n)]


# ------------------------------------------------------ acceptance contract


def test_gated_threshold_zero_exactly_matches_dense():
    """The ISSUE acceptance pin: threshold-0 delta gating is lossless —
    per-frame detection output (boxes AND scores) is bit-identical to
    the dense engine on the same streams, while the gate demonstrably
    skipped stem re-runs on the hold-redundant frames."""
    gated = _engine(gate=DeltaGateConfig(threshold=0.0))
    dense = _engine(gate=DeltaGateConfig(threshold=None))
    done_g = gated.run(_streams(3))
    done_d = dense.run(_streams(3))
    assert [r.uid for r in done_g] == [r.uid for r in done_d]
    assert sum(r.skip_count for r in done_g) > 0  # the gate actually gated
    assert all(r.skip_count == 0 for r in done_d)
    for g, d in zip(done_g, done_d):
        assert g.frames_done == d.frames_done
        for (bg, sg), (bd, sd) in zip(g.frame_outputs, d.frame_outputs):
            np.testing.assert_array_equal(bg, bd)
            np.testing.assert_array_equal(sg, sd)


def test_measured_bits_below_dense_baseline():
    """Hold-2 streams: ~half the frames skip, so the measured bits/frame
    sit well below the dense readout and the ledger's reduction > 1."""
    eng = _engine()
    done = eng.run(_streams(2, n_frames=8, hold=2))
    dense_bits = frame_output_bits(eng.geom)
    for r in done:
        assert r.skip_rate == pytest.approx(0.5)
        assert r.bits_per_frame < dense_bits
        assert r.reduction_vs_dense > 1.5
        # exact accounting: rerun frames pay dense + flag, skips pay flag
        reruns = r.frames_done - r.skip_count
        assert r.bits == reruns * dense_bits + r.frames_done
    s = eng.stream_summary()
    assert s["stem_skip_rate"] == pytest.approx(0.5)
    assert s["bits_per_frame"] < s["dense_bits_per_frame"]
    assert s["measured_reduction_vs_dense"] > 1.5


def test_noisy_streams_never_skip():
    """Per-frame noise breaks bit-identity: with threshold 0 every frame
    re-runs and the measured bits equal the dense baseline + flags."""
    eng = _engine()
    done = eng.run(_streams(2, noise=0.02))
    for r in done:
        assert r.skip_count == 0
        assert r.bits == r.frames_done * (frame_output_bits(eng.geom) + 1)


def test_first_frame_always_reruns():
    """A fresh slot has no reference frame: frame 0 must re-run even on
    an all-identical stream (hold >= n_frames)."""
    eng = _engine(max_streams=1)
    done = eng.run(_streams(1, n_frames=4, hold=8))
    (r,) = done
    assert r.skip_count == 3  # frames 1..3 identical to the reference
    assert r.frames_done == 4


# -------------------------------------------------- multi-tick slot model


def test_mixed_length_streams_occupy_slots_for_their_lifetime():
    """Streams of different lengths through a 2-slot table: serve_ticks
    equals the stream length, a freed slot admits the next stream, and
    completion order follows stream length not submission order."""
    eng = _engine(max_streams=2)
    lens = [6, 2, 3]
    reqs = [StreamRequest(
        uid=i, frames=SyntheticVideo(image_size=CFG.image_size,
                                     n_frames=n, seed=i).frames())
        for i, n in enumerate(lens)]
    done = eng.run(reqs)
    assert [r.uid for r in done] == [1, 2, 0]  # 2 ends @2, 3 rides @3-5
    by = {r.uid: r for r in done}
    for i, n in enumerate(lens):
        assert by[i].serve_ticks == n
        assert by[i].frames_done == n
    assert by[2].served_tick == 3  # admitted when uid=1 freed its slot
    # slot accounting: total busy slot-ticks == sum of stream lengths
    assert eng.stats["busy_slot_ticks"] == sum(lens)


def test_slot_state_isolation_across_recycled_streams():
    """The invariant StreamEngine depends on: a recycled slot must not
    leak gate reference frames, cached stem activations, or track ids
    from its previous occupant.  Two identical streams served back to
    back through ONE slot must produce identical results — including the
    first-frame rerun and restarted track ids."""
    eng = _engine(max_streams=1)
    vid = SyntheticVideo(image_size=CFG.image_size, n_frames=5, hold=2,
                         seed=3)
    a = StreamRequest(uid=0, frames=vid.frames())
    b = StreamRequest(uid=1, frames=vid.frames())
    done = eng.run([a, b])
    assert [r.uid for r in done] == [0, 1]
    ra, rb = done
    # identical streams, identical per-frame outputs and accounting —
    # any leaked reference frame would turn rb's first frame into a skip
    assert ra.skip_count == rb.skip_count
    assert rb.frame_outputs and ra.frames_done == rb.frames_done
    for (ba, sa), (bb, sb) in zip(ra.frame_outputs, rb.frame_outputs):
        np.testing.assert_array_equal(ba, bb)
        np.testing.assert_array_equal(sa, sb)
    # track ids restart at 0 for the recycled slot's new tracker
    ids_a = {tid for fr in ra.tracks for tid, _, _ in fr}
    ids_b = {tid for fr in rb.tracks for tid, _, _ in fr}
    assert ids_a == ids_b  # same stream → same (restarted) id space


def test_latency_ledger_multi_tick_streams():
    eng = _engine(max_streams=1)
    done = eng.run(_streams(2, n_frames=3))
    assert [r.queue_ticks for r in done] == [1, 4]  # second waits 3 ticks
    assert all(r.serve_ticks == 3 for r in done)
    assert all(r.frame_latency_us > 0 for r in done)


# ------------------------------------------------------- front-door routing


def test_front_door_routes_streams_next_to_lm_and_vision():
    """StreamRequest routes to the StreamEngine while VisionRequest still
    lands on the VisionEngine — mixed traffic, one merged completion
    stream, per-engine clocks in lockstep."""
    from repro.data import SyntheticVWW
    from repro.launch.serve import FrontDoor
    from repro.serving import VisionEngine, VisionRequest

    params, bn, det = _model()
    stream = _engine(max_streams=1)
    vision = VisionEngine(params, bn, CFG, max_batch=2)
    door = FrontDoor(stream=stream, vision=vision)

    imgs = SyntheticVWW(image_size=CFG.image_size, batch=2).batch_at(0)["images"]
    reqs = _streams(1, n_frames=3) + [
        VisionRequest(uid=100 + i, image=imgs[i]) for i in range(2)]
    merged = door.run(reqs)
    names = [n for n, _ in merged]
    assert names.count("stream") == 1 and names.count("vision") == 2
    (sreq,) = [r for n, r in merged if n == "stream"]
    assert sreq.frames_done == 3
    assert door.tick == stream.tick == vision.tick


def test_stream_engine_rejects_empty_stream():
    """A zero-frame stream would occupy a slot whose launch has no frame
    to read — shed it at submit instead of crashing the shared tick."""
    eng = _engine(max_streams=1)
    with pytest.raises(ValueError, match="no frames"):
        eng.submit(StreamRequest(
            uid=0, frames=np.empty((0, CFG.image_size, CFG.image_size, 3),
                                   np.float32)))
    assert not eng.busy()


def test_stream_engine_rejects_baseline_variant():
    params, bn = init_mnv2(jax.random.PRNGKey(0),
                           MNV2Config(variant="baseline", image_size=20,
                                      width=0.25, head_channels=16))
    _, _, det = _model()
    with pytest.raises(ValueError, match="p2m variant"):
        StreamEngine(params, bn,
                     MNV2Config(variant="baseline", image_size=20,
                                width=0.25, head_channels=16), det)


# ----------------------------- multi-device lane (scripts/ci.sh re-runs
# this test under XLA_FLAGS=--xla_force_host_platform_device_count=8)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (CI multi-device lane)")


@needs8
def test_sharded_stream_engine_matches_single_device():
    """Data-mesh-sharded stream launch (images + cached stems + rerun
    mask split over 8 devices; the stem cache stays device-resident and
    sharded between ticks) matches the single-device engine within 1e-3
    across a short multi-tick stream — the per-tick forward is
    deterministic given its inputs, so the multi-tick comparison stays
    well-posed (unlike training trajectories, DESIGN.md §7.1)."""
    from repro.launch.mesh import make_debug_mesh

    params, bn, det = _model()
    single = StreamEngine(params, bn, CFG, det, det_cfg=DCFG, max_streams=8)
    sharded = StreamEngine(params, bn, CFG, det, det_cfg=DCFG, max_streams=8,
                           mesh=make_debug_mesh(8))
    d_single = single.run(_streams(8, n_frames=3))
    d_sharded = sharded.run(_streams(8, n_frames=3))
    assert [r.uid for r in d_single] == [r.uid for r in d_sharded]
    for a, b in zip(d_single, d_sharded):
        assert a.skip_count == b.skip_count
        for (ba, sa), (bb, sb) in zip(a.frame_outputs, b.frame_outputs):
            np.testing.assert_allclose(bb, ba, rtol=1e-4, atol=1e-3)
            np.testing.assert_allclose(sb, sa, rtol=1e-4, atol=1e-3)


@needs8
def test_sharded_stream_engine_splits_batch_over_mesh():
    """Pin the split itself: the compiled stream launch shards the image
    and cached-stem batch dims 1/8 per device (a silent fallback to
    replication would keep parity green)."""
    from repro.launch.mesh import make_debug_mesh

    params, bn, det = _model()
    eng = StreamEngine(params, bn, CFG, det, det_cfg=DCFG, max_streams=8,
                       mesh=make_debug_mesh(8))
    h = CFG.image_size
    ho = CFG.p2m.out_spatial(h)
    co = CFG.p2m.out_channels
    compiled = eng._fwd.lower(
        params, bn, eng._deploy, det,
        np.zeros((8, h, h, 3), np.float32),
        np.zeros((8, ho, ho, co), np.float32),
        np.zeros((8,), np.bool_)).compile()
    shardings = jax.tree.leaves(compiled.input_shardings[0])
    img_sh = shardings[-3]  # (images, cached, rerun) are the last three
    assert len(img_sh.device_set) == 8
    assert not img_sh.is_fully_replicated
    assert img_sh.shard_shape((8, h, h, 3)) == (1, h, h, 3)
