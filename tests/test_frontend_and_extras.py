"""P²M frontend integration, pruned pixel model, HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontend import (
    P2MFrontendConfig,
    apply_p2m_frontend,
    init_p2m_frontend,
    init_p2m_frontend_state,
)
from repro.core.p2m_conv import P2MConvConfig
from repro.core.pixel_model import (
    default_pixel_model,
    prune_pixel_model,
    spice_surrogate,
)
from repro.launch.hlo_analysis import analyze, parse_module


def test_p2m_frontend_shapes():
    cfg = P2MFrontendConfig(image_size=80, d_model=64, pool=2,
                            conv=P2MConvConfig())
    params = init_p2m_frontend(jax.random.PRNGKey(0), cfg)
    state = init_p2m_frontend_state(cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 80, 80, 3))
    emb, _ = apply_p2m_frontend(params, state, imgs, cfg, train=True)
    assert emb.shape == (2, cfg.tokens, 64)
    assert cfg.tokens == (80 // 5 // 2) ** 2
    assert bool(jnp.all(jnp.isfinite(emb)))


def test_p2m_frontend_feeds_vlm():
    """P²M as the VLM's vision frontend (the --frontend p2m path)."""
    from repro.configs import get_smoke_config
    from repro.models import vlm

    mcfg = get_smoke_config("llama-3.2-vision-11b").replace(dtype=jnp.float32)
    fcfg = P2MFrontendConfig(image_size=40, d_model=mcfg.d_model, pool=4,
                             conv=P2MConvConfig())
    assert fcfg.tokens == 4  # 40/5/4 = 2 → 2² (forward takes any token count)
    fparams = init_p2m_frontend(jax.random.PRNGKey(0), fcfg)
    fstate = init_p2m_frontend_state(fcfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 40, 40, 3))
    emb, _ = apply_p2m_frontend(fparams, fstate, imgs, fcfg)

    params, _ = vlm.init_vlm(jax.random.PRNGKey(2), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, mcfg.vocab)
    logits, _ = vlm.forward(params, toks, emb, mcfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pruned_model_error_within_one_lsb():
    m = default_pixel_model()
    mp = prune_pixel_model(m, 0.06)
    n_terms = int((np.abs(mp.coeffs) > 0).sum())
    assert n_terms <= 5  # ≥ ~2x fewer MXU matmuls than the 9-term basis
    w = np.random.default_rng(0).random(2000)
    x = np.random.default_rng(1).random(2000)
    err = np.abs(np.asarray(mp(w, x)) - spice_surrogate(w, x)).max()
    assert err < 1.5 / 255  # ≈ 1 LSB of the 8-bit ADC


HLO_SAMPLE = """\
HloModule test, entry_computation_layout={()->f32[8,8]{1,0}}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main () -> f32[8,8] {
  %c = f32[8,8]{1,0} constant({...})
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%z, %c)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_loop_multipliers():
    r = analyze(HLO_SAMPLE)
    # dot: 2·8·8·8 = 1024 flops × 5 trips
    assert r["flops"] == 5 * 1024
    assert r["collectives"]["all-reduce"]["count"] == 5
    assert r["collectives"]["all-reduce"]["bytes"] == 5 * 8 * 8 * 4


def test_hlo_parser_computations():
    comps = parse_module(HLO_SAMPLE)
    assert set(comps) == {"body", "cond", "main"}
    assert comps["main"].entry
    assert comps["body"].root.opcode == "tuple"
