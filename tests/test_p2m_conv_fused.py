"""Fused implicit-im2col conv kernel vs the patch-materializing path and
the elementwise oracle: parity matrix over modes × strides × odd shapes,
gradient parity of the Pallas dX/dW backward kernels (incl. quant STE),
autotuner legality/caching, and the core-layer impl equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adc import ADCConfig
from repro.core.p2m_conv import (
    P2MConvConfig,
    apply_p2m_conv_deploy,
    apply_p2m_conv_train,
    extract_patches,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.pixel_model import default_pixel_model
from repro.kernels.p2m_conv import (
    im2col_matrix,
    p2m_backward_jnp,
    p2m_bwd_dx_pallas,
    p2m_bwd_dw_pallas,
    p2m_conv,
    p2m_conv_jnp,
    p2m_conv_pallas,
    p2m_matmul_jnp,
    p2m_matmul_ref,
    premix_weights,
)
from repro.kernels.p2m_conv import tune
from repro.kernels.p2m_conv.backward import epilogue_mask
from repro.kernels.p2m_conv.ops import _coeff_tuple

MODEL = default_pixel_model()
ADC = ADCConfig()
COEFFS = _coeff_tuple(MODEL)

# (B, H, W, C, k, s): paper geometry, non-divisible H/W (remainder crop),
# overlapping stride < kernel, stride > kernel gaps, single-channel,
# single-pixel-row outputs, shapes off the 8/128 tile quanta.
GEOMETRIES = [
    (2, 20, 20, 3, 5, 5),    # paper fast path, divisible
    (1, 23, 19, 3, 5, 5),    # fast path with remainder crop
    (2, 14, 11, 2, 3, 2),    # overlapping stride < kernel
    (2, 13, 13, 3, 5, 3),    # overlapping, odd dims
    (1, 9, 9, 1, 4, 4),      # single channel
    (1, 8, 17, 3, 2, 2),     # wide/narrow
    (2, 10, 10, 3, 3, 6),    # stride > kernel (gaps)
    (1, 5, 5, 3, 5, 5),      # single output pixel
]


def _conv_data(b, h, w_dim, c, k, n=8, seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.random((b, h, w_dim, c)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1, 1, (k * k * c, n)), jnp.float32)
    s = jnp.asarray(rng.uniform(-0.2, 0.2, (n,)), jnp.float32)
    return imgs, w, s


def _patch_reference(imgs, w, s, k, stride, mode):
    """extract_patches + p2m_matmul_jnp — the materializing baseline."""
    b = imgs.shape[0]
    patches = extract_patches(imgs, k, stride)
    xf = patches.reshape(b * patches.shape[1], -1)
    out = p2m_matmul_jnp(xf, w, s, MODEL, ADC, mode)
    ho = (imgs.shape[1] - k) // stride + 1
    wo = (imgs.shape[2] - k) // stride + 1
    return out.reshape(b, ho, wo, w.shape[1])


@pytest.mark.parametrize("b,h,w_dim,c,k,s", GEOMETRIES)
@pytest.mark.parametrize("mode", ["raw", "relu", "quant"])
def test_fused_conv_matches_patch_path(b, h, w_dim, c, k, s, mode):
    imgs, w, sh = _conv_data(b, h, w_dim, c, k)
    ref = _patch_reference(imgs, w, sh, k, s, mode)
    out = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s, coeffs=COEFFS,
                          mode=mode, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    outj = p2m_conv_jnp(imgs, w, sh, MODEL, ADC, mode, k, s)
    np.testing.assert_allclose(np.asarray(outj), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,w_dim,c,k,s", GEOMETRIES[:4])
def test_fused_conv_matches_elementwise_oracle(b, h, w_dim, c, k, s):
    """Fused kernel ≡ the faithful per-element g() oracle (ref.py)."""
    imgs, w, sh = _conv_data(b, h, w_dim, c, k, seed=3)
    xf = im2col_matrix(imgs, k, s)
    ref = p2m_matmul_ref(xf, w, MODEL, sh, ADC)
    out = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s, coeffs=COEFFS,
                          mode="relu", interpret=True)
    np.testing.assert_allclose(np.asarray(out).reshape(ref.shape),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_im2col_matrix_matches_extract_patches():
    for b, h, w_dim, c, k, s in GEOMETRIES:
        imgs, _, _ = _conv_data(b, h, w_dim, c, k, seed=1)
        a = im2col_matrix(imgs, k, s)
        bnum = imgs.shape[0]
        p = extract_patches(imgs, k, s).reshape(a.shape)
        np.testing.assert_allclose(np.asarray(a), np.asarray(p), atol=0)


def test_fused_conv_tiny_blocks_padded_edges():
    """Force 1-row blocks so every tile edge is a padded edge."""
    imgs, w, sh = _conv_data(2, 13, 11, 3, 5, seed=5)
    ref = _patch_reference(imgs, w, sh, 5, 3, "relu")
    out = p2m_conv_pallas(imgs, w, sh, kernel=5, stride=3, coeffs=COEFFS,
                          mode="relu", block_h=1, block_n=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_premix_weights_collapses_basis():
    """Σ_j X^j @ W̃_j ≡ Σ_ij a_ij X^j (sign(W)|W|^i) — the premix identity."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.random((32, 12)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1, 1, (12, 4)), jnp.float32)
    wmix = premix_weights(w, COEFFS)
    acc = sum((x ** j) @ wmix[j - 1] for j in range(1, wmix.shape[0] + 1))
    ref = p2m_matmul_jnp(x, w, jnp.zeros((4,)), MODEL, ADC, "raw")
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Gradient parity: Pallas dX/dW kernels vs jax.vjp of the jnp path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(48, 75, 8), (130, 33, 5), (8, 1, 1)])
@pytest.mark.parametrize("mode", ["raw", "relu"])
def test_pallas_bwd_kernels_match_jax_vjp(m, k, n, mode):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.random((m, k)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1, 1, (k, n)), jnp.float32)
    s = jnp.asarray(rng.uniform(-0.2, 0.2, (n,)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

    _, vjp = jax.vjp(
        lambda xx, ww, ss: p2m_matmul_jnp(xx, ww, ss, MODEL, ADC, mode),
        x, w, s)
    rgx, rgw, rgs = vjp(g)

    raw = p2m_matmul_jnp(x, w, jnp.zeros_like(s), MODEL, ADC, "raw")
    g_eff = g * epilogue_mask(raw, s, mode=mode, full_scale=ADC.full_scale)
    gx = p2m_bwd_dx_pallas(g_eff, w, x, coeffs=COEFFS, interpret=True)
    gw = p2m_bwd_dw_pallas(g_eff, w, x, coeffs=COEFFS, interpret=True)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rgx),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rgw),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_eff.sum(0)), np.asarray(rgs),
                               rtol=1e-4, atol=1e-5)

    jgx, jgw = p2m_backward_jnp(g_eff, w, x, COEFFS)
    np.testing.assert_allclose(np.asarray(jgx), np.asarray(rgx),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jgw), np.asarray(rgw),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("b,h,w_dim,c,k,s",
                         [(2, 20, 20, 3, 5, 5), (2, 13, 11, 3, 5, 3)])
@pytest.mark.parametrize("mode", ["raw", "relu"])
def test_fused_conv_gradients_match_jnp(b, h, w_dim, c, k, s, mode):
    """custom-VJP fused conv (Pallas fwd + Pallas bwd) ≡ autodiff of the
    XLA fused path, including the col2im scatter for overlapping stride."""
    imgs, w, sh = _conv_data(b, h, w_dim, c, k, seed=4)

    def loss_pallas(im, ww, ss):
        return (p2m_conv(im, ww, ss, MODEL, ADC, mode, k, s, True,
                         "pallas") ** 2).sum()

    def loss_jnp(im, ww, ss):
        return (p2m_conv_jnp(im, ww, ss, MODEL, ADC, mode, k, s) ** 2).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(imgs, w, sh)
    g2 = jax.grad(loss_jnp, argnums=(0, 1, 2))(imgs, w, sh)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-5)


def test_fused_conv_quant_ste_gradient():
    """quant forward is stepped; its gradient is the relu path's (STE)."""
    imgs, w, sh = _conv_data(1, 13, 13, 3, 5, seed=6)
    gq = jax.grad(lambda im: p2m_conv(im, w, sh, MODEL, ADC, "quant", 5, 3,
                                      True, "pallas").sum())(imgs)
    gr = jax.grad(lambda im: p2m_conv_jnp(im, w, sh, MODEL, ADC, "relu",
                                          5, 3).sum())(imgs)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gr),
                               rtol=1e-4, atol=1e-5)


def test_train_form_grad_impl_equivalence():
    """d loss/d theta agrees between the fused custom-VJP path and the
    patch-materializing autodiff path through the full train form."""
    cfg = P2MConvConfig()
    params = init_p2m_conv(jax.random.PRNGKey(0), cfg)
    state = init_p2m_state(cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 20, 20, 3))

    def loss(theta, impl):
        p = dict(params, theta=theta)
        out, _ = apply_p2m_conv_train(p, state, imgs, cfg, impl=impl)
        return (out ** 2).sum()

    g_pallas = jax.grad(lambda t: loss(t, "pallas"))(params["theta"])
    g_fused = jax.grad(lambda t: loss(t, "fused"))(params["theta"])
    g_patch = jax.grad(lambda t: loss(t, "patches"))(params["theta"])
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_patch),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_pallas), np.asarray(g_patch),
                               rtol=1e-4, atol=1e-5)


def test_deploy_impl_equivalence():
    cfg = P2MConvConfig()
    rng = np.random.default_rng(8)
    dep = {
        "w": jnp.asarray(rng.uniform(-1, 1, (75, cfg.out_channels)),
                         jnp.float32),
        "shift": jnp.asarray(rng.uniform(-0.1, 0.1, (cfg.out_channels,)),
                             jnp.float32),
    }
    imgs = jnp.asarray(rng.random((2, 20, 20, 3)), jnp.float32)
    outs = [apply_p2m_conv_deploy(dep, imgs, cfg, quantize=True, impl=impl)
            for impl in ("pallas", "fused", "patches")]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(outs[2]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


def test_autotune_candidates_respect_vmem_budget():
    for cand in tune.matmul_candidates(4096, 75, 8):
        assert tune.matmul_vmem_bytes(*cand) <= tune.VMEM_BUDGET_BYTES
    for bh, bn, depth in tune.conv_candidates(8, 112, 112, 8, 15):
        assert depth in tune.CONV_PIPELINE_DEPTHS
        assert tune.conv_vmem_bytes(bh, 112, 15, bn,
                                    depth=depth) <= tune.VMEM_BUDGET_BYTES
    assert tune.matmul_candidates(4096, 75, 8)  # never empty at paper geom
    assert tune.conv_candidates(8, 112, 112, 8, 15)


def test_autotune_times_once_and_caches():
    tune.cache_clear()
    calls = []
    orig = tune._time_once

    def counting_timer(fn, *args, **kw):
        calls.append(1)
        return orig(fn, *args, iters=1, warmup=0)

    tune._time_once = counting_timer
    try:
        blocks = tune.get_matmul_blocks(16, 12, 4, COEFFS, "relu",
                                        enable=True, interpret=True, iters=1)
        n_first = len(calls)
        assert n_first >= 1
        again = tune.get_matmul_blocks(16, 12, 4, COEFFS, "relu",
                                       enable=True, interpret=True, iters=1)
        assert again == blocks
        assert len(calls) == n_first  # cached: no re-timing
    finally:
        tune._time_once = orig
        tune.cache_clear()


def test_autotune_disabled_returns_defaults_instantly():
    tune.cache_clear()
    assert tune.get_matmul_blocks(10**6, 75, 8, COEFFS, "relu",
                                  enable=False) == (256, 128, 128)
    assert tune.get_conv_blocks(8, 224, 224, 3, 8, 5, 5, COEFFS, "relu",
                                enable=False) == (None, None, 0)


def test_autotuned_conv_blocks_stay_correct():
    """Whatever block shape the tuner picks must not change the numerics."""
    tune.cache_clear()
    imgs, w, sh = _conv_data(1, 15, 15, 3, 5, seed=9)
    ref = _patch_reference(imgs, w, sh, 5, 5, "relu")
    bh, bn, depth = tune.get_conv_blocks(1, 15, 15, 3, 8, 5, 5, COEFFS,
                                         "relu", enable=True, interpret=True,
                                         iters=1)
    out = p2m_conv_pallas(imgs, w, sh, kernel=5, stride=5, coeffs=COEFFS,
                          mode="relu", block_h=bh, block_n=bn,
                          pipeline_depth=depth, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    tune.cache_clear()
