"""Stateful streaming-LM sessions: multi-turn slot residency.

A `SessionRequest` holds its slot for the whole conversation and the
recurrent (token-shift, WKV) state rides in the slot's batch row across
turns.  The executable spec is single-request decode with a persistent
state: feed turn t's prompt token by token, generate, then feed turn
t+1's prompt into the SAME state (the final generated token of a turn is
recorded but never fed back).  Everything here pins the engine — turn
bookkeeping, chunked prefill, slot recycling, front-door routing, and
the 8-device sharded lane — to that spec (DESIGN.md §12.4).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine, SessionEngine, SessionRequest


def _setup():
    cfg = get_smoke_config("rwkv6-3b").replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    return cfg, family, params


def _turns(rng, cfg, n_turns, lo=3, hi=8):
    return [rng.integers(0, cfg.vocab, rng.integers(lo, hi)).tolist()
            for _ in range(n_turns)]


def _reference_session(params, cfg, family, turns, max_new):
    """Single-session greedy replay: persistent state, per-token feed.
    Returns per-turn outputs."""
    state, _ = family.init_decode_state(cfg, 1, 256)
    pos = jnp.zeros((1,), jnp.int32)  # rwkv ignores positions
    outs = []
    logits = None
    for prompt in turns:
        for tok in prompt:
            logits, state = family.decode(
                params, state, jnp.asarray([[tok]], jnp.int32), pos, cfg)
        gen = []
        for i in range(max_new):
            nxt = int(jnp.argmax(logits[0, -1]))
            gen.append(nxt)
            if i + 1 < max_new:  # a turn's last token is never fed back
                logits, state = family.decode(
                    params, state, jnp.asarray([[nxt]], jnp.int32), pos, cfg)
        outs.append(gen)
    return outs


@pytest.mark.parametrize("prefill_chunk", [1, 4])
def test_sessions_match_persistent_state_reference(prefill_chunk):
    """Turn t+1 must continue from turn t's state — for both the
    token-by-token and the fused chunked-WKV prefill paths."""
    cfg, family, params = _setup()
    rng = np.random.default_rng(0)
    all_turns = [_turns(rng, cfg, 3) for _ in range(3)]

    eng = SessionEngine(params, cfg, max_batch=2, max_len=256,
                        prefill_chunk=prefill_chunk)
    reqs = [SessionRequest(uid=i, turns=t, max_new_tokens=5)
            for i, t in enumerate(all_turns)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    for r in reqs:
        ref = _reference_session(params, cfg, family, r.turns, 5)
        assert r.outputs == ref, f"session {r.uid} diverged from reference"
        assert r.done


def test_state_actually_persists_across_turns():
    """Sanity on the spec itself: turn 2 decoded with the session's
    carried state must differ from turn 2 decoded fresh — otherwise the
    'stateful' in stateful sessions is vacuous for this config."""
    cfg, family, params = _setup()
    rng = np.random.default_rng(1)
    turns = _turns(rng, cfg, 2, lo=6, hi=10)

    eng = SessionEngine(params, cfg, max_batch=1, max_len=256,
                        prefill_chunk=4)
    req = SessionRequest(uid=0, turns=turns, max_new_tokens=6)
    eng.submit(req)
    eng.run()
    fresh = _reference_session(params, cfg, family, [turns[1]], 6)[0]
    assert req.outputs[1] != fresh, (
        "turn-2 output identical to fresh-state decode — session state "
        "is not being carried")


def test_recycled_slot_sees_no_stale_session_state():
    """PR-4 leak property, session flavor: a slot freed by one
    conversation and re-admitted by another must behave as freshly
    initialized even after worst-case poisoning of the engine state.
    Recurrent state is the sharp case — a leaked WKV matrix feeds every
    subsequent token of the next conversation."""
    cfg, family, params = _setup()
    rng = np.random.default_rng(2)
    t1, t2 = _turns(rng, cfg, 2), _turns(rng, cfg, 2)

    eng = SessionEngine(params, cfg, max_batch=1, max_len=256,
                        prefill_chunk=4)
    eng.submit(SessionRequest(uid=0, turns=t1, max_new_tokens=4))
    eng.run()
    assert len(eng.completed) == 1

    # worst-case stale state: saturate every slot's recurrent state
    eng.state = jax.tree.map(lambda a: jnp.full_like(a, 7.0), eng.state)

    req2 = SessionRequest(uid=1, turns=t2, max_new_tokens=4)
    eng.submit(req2)
    eng.run()
    ref = _reference_session(params, cfg, family, t2, 4)
    assert req2.outputs == ref, (
        "recycled slot leaked previous conversation's WKV state")


def test_more_sessions_than_slots():
    """Sessions queue and recycle like any slot request; every
    conversation completes all its turns."""
    cfg, family, params = _setup()
    rng = np.random.default_rng(3)
    eng = SessionEngine(params, cfg, max_batch=2, max_len=256,
                        prefill_chunk=4)
    reqs = [SessionRequest(uid=i, turns=_turns(rng, cfg, 2),
                           max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(r.uid for r in done) == list(range(5))
    assert all(len(r.outputs) == 2 for r in reqs)
    assert all(len(o) == 3 for r in reqs for o in r.outputs)


def test_session_length_cap_ends_conversation():
    """A conversation that would outrun the slot's max_len stops at the
    hard cap instead of wrapping or crashing the tick."""
    cfg, family, params = _setup()
    rng = np.random.default_rng(4)
    eng = SessionEngine(params, cfg, max_batch=1, max_len=16,
                        prefill_chunk=4)
    req = SessionRequest(uid=0, turns=_turns(rng, cfg, 8),
                         max_new_tokens=4)
    eng.submit(req)
    done = eng.run()
    assert len(done) == 1 and req.done
    assert len(req.outputs) < 8  # capped before the last turn


def test_session_engine_rejects_kv_cache_family():
    """KV-cache families have no positionless prefill hook — per-session
    history in a recycled slot is unsound, so construction fails loudly."""
    cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="prefill"):
        SessionEngine(params, cfg, max_batch=1, max_len=32)


def test_front_door_routes_sessions_next_to_lm():
    """SessionRequest routes to the SessionEngine while plain Request
    still lands on the LM engine — mixed traffic, one merged completion
    stream, no router changes."""
    from repro.launch.serve import FrontDoor

    cfg, family, params = _setup()
    rng = np.random.default_rng(5)
    lm = ServeEngine(params, cfg, max_batch=2, max_len=64)
    chat = SessionEngine(params, cfg, max_batch=1, max_len=256,
                         prefill_chunk=4)
    door = FrontDoor(lm=lm, chat=chat)

    reqs = [Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4).tolist(),
                    max_new_tokens=3),
            SessionRequest(uid=100, turns=_turns(rng, cfg, 2),
                           max_new_tokens=3)]
    merged = door.run(reqs)
    names = sorted(n for n, _ in merged)
    assert names == ["chat", "lm"]
    (sreq,) = [r for n, r in merged if n == "chat"]
    assert sreq.done and len(sreq.outputs) == 2


def test_session_replay_is_deterministic():
    """Same conversations submitted twice through fresh engines produce
    identical per-turn outputs and identical tick counts — the property
    `bench_gate.py` gates on the bench row."""
    cfg, family, params = _setup()
    rng = np.random.default_rng(6)
    all_turns = [_turns(rng, cfg, 2) for _ in range(3)]

    runs = []
    for _ in range(2):
        eng = SessionEngine(params, cfg, max_batch=2, max_len=256,
                            prefill_chunk=4)
        reqs = [SessionRequest(uid=i, turns=[list(t) for t in ts],
                               max_new_tokens=4)
                for i, ts in enumerate(all_turns)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        runs.append(([r.outputs for r in reqs], eng.tick))
    assert runs[0] == runs[1], "session replay nondeterministic"


# ----------------------------- multi-device lane (scripts/ci.sh re-runs
# this file under XLA_FLAGS=--xla_force_host_platform_device_count=8)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (CI multi-device lane)")


@needs8
def test_sharded_sessions_match_single_device_bitwise():
    """Satellite 4: session state sharded over the 8-device data mesh —
    resident across ticks, never gathered to host between turns — must
    match the single-device engine *bitwise* (token ids equal, final
    recurrent state array_equal).  The per-tick step is deterministic
    given its inputs, and sharding the batch axis must not change any
    per-row reduction order, so exact equality is the right bar."""
    from repro.launch.mesh import make_debug_mesh

    cfg, family, params = _setup()
    rng = np.random.default_rng(7)
    all_turns = [_turns(rng, cfg, 2) for _ in range(8)]

    def run(mesh):
        eng = SessionEngine(params, cfg, max_batch=8, max_len=256,
                            prefill_chunk=4, mesh=mesh)
        reqs = [SessionRequest(uid=i, turns=[list(t) for t in ts],
                               max_new_tokens=4)
                for i, ts in enumerate(all_turns)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.outputs for r in reqs], eng.state

    outs_1, state_1 = run(None)
    outs_8, state_8 = run(make_debug_mesh(8))
    assert outs_8 == outs_1, "sharded session tokens diverged"
    for a, b in zip(jax.tree.leaves(state_1), jax.tree.leaves(state_8)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs8
def test_sharded_recycled_slot_no_leak():
    """Leak property on the sharded lane: device-resident sharded state
    must still be zeroed on recycle — `_reset_slot`'s host-side zero-fill
    and the device_put round-trip may not silently skip shards."""
    from repro.launch.mesh import make_debug_mesh

    cfg, family, params = _setup()
    rng = np.random.default_rng(8)
    t1 = [_turns(rng, cfg, 2) for _ in range(8)]
    t2 = _turns(rng, cfg, 2)

    eng = SessionEngine(params, cfg, max_batch=8, max_len=256,
                        prefill_chunk=4, mesh=make_debug_mesh(8))
    for i, ts in enumerate(t1):
        eng.submit(SessionRequest(uid=i, turns=ts, max_new_tokens=3))
    eng.run()
    eng.state = jax.tree.map(lambda a: jnp.full_like(a, 7.0), eng.state)
    req = SessionRequest(uid=99, turns=t2, max_new_tokens=3)
    eng.submit(req)
    eng.run()
    ref = _reference_session(params, cfg, family, t2, 3)
    assert req.outputs == ref, "sharded recycled slot leaked state"
