"""Per-architecture smoke tests (deliverable f): reduced same-family
config, one forward + one train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.families import get_family
from repro.optim import constant, sgd
from repro.train import TrainState, make_train_step


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, axes = family.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    loss, metrics = family.loss(params, batch, cfg)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert 0.0 <= float(metrics["acc"]) <= 1.0

    optimizer = sgd(constant(1e-2))
    state = TrainState(params, optimizer.init(params))
    step = jax.jit(make_train_step(cfg, optimizer))
    new_state, m = step(state, batch)
    assert int(new_state["step"]) == 1
    assert jnp.isfinite(m["loss"])
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    b = 2
    state, _ = family.init_decode_state(cfg, b, 32)
    if cfg.family == "vlm":
        from repro.models import vlm
        img = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (b, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
        state = dict(state)
        state["cross"] = vlm.prefill_cross_kv(params, img, cfg)
    if cfg.family == "encdec":
        from repro.models import whisper
        src = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (b, cfg.max_source_positions, cfg.d_model)), jnp.float32)
        state = dict(state)
        state["cross"] = whisper.prefill_cross_kv(params, src, cfg)
    toks = jnp.asarray([[1], [2]], jnp.int32)
    logits, new_state = family.decode(params, state, toks,
                                      jnp.zeros((b,), jnp.int32), cfg)
    assert logits.shape[0] == b and logits.shape[-1] >= cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The exact public configs (spot-check the assigned numbers)."""
    cfg = get_config(arch)
    expected = {
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "mixtral-8x22b":
        assert (cfg.n_experts, cfg.top_k) == (8, 2)
        assert cfg.sliding_window is not None
    if arch == "recurrentgemma-9b":
        assert cfg.block_pattern == ("rec", "rec", "attn")
