"""Token-by-token decode reproduces the teacher-forced forward pass for
every family — the core serving-correctness invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import rglru, rwkv6, transformer, vlm, whisper
from repro.models.families import get_family

TOL = 2e-4


def _decode_all(family, params, cfg, toks, state):
    outs = []
    b = toks.shape[0]
    for t in range(toks.shape[1]):
        lg, state = family.decode(params, state, toks[:, t:t + 1],
                                  jnp.full((b,), t, jnp.int32), cfg)
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("arch", ["qwen3-32b", "llama3.2-1b", "mixtral-8x22b",
                                  "rwkv6-3b", "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 18), 0, cfg.vocab)

    if cfg.family in ("dense", "moe"):
        ref, _ = transformer.forward(params, toks, cfg)
    elif cfg.family == "rwkv":
        ref, _, _ = rwkv6.forward(params, toks, cfg)
    else:
        ref, _ = rglru.forward(params, toks, cfg)

    state, _ = family.init_decode_state(cfg, 2, 64)
    dec = _decode_all(family, params, cfg, toks, state)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=TOL,
                               atol=5e-4)


def test_vlm_decode_matches_forward():
    cfg = get_smoke_config("llama-3.2-vision-11b").replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    img = jax.random.normal(jax.random.PRNGKey(2),
                            (2, cfg.n_image_tokens, cfg.d_model))
    ref, _ = vlm.forward(params, toks, img, cfg)
    state, _ = family.init_decode_state(cfg, 2, 32)
    state = dict(state)
    state["cross"] = vlm.prefill_cross_kv(params, img, cfg)
    dec = _decode_all(family, params, cfg, toks, state)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=TOL,
                               atol=5e-4)


def test_whisper_decode_matches_forward():
    cfg = get_smoke_config("whisper-tiny").replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    src = jax.random.normal(jax.random.PRNGKey(2),
                            (2, cfg.max_source_positions, cfg.d_model))
    ref, _ = whisper.forward(params, src, toks, cfg)
    state, _ = family.init_decode_state(cfg, 2, 16)
    state = dict(state)
    state["cross"] = whisper.prefill_cross_kv(params, src, cfg)
    dec = _decode_all(family, params, cfg, toks, state)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=TOL,
                               atol=5e-4)


def test_rwkv_chunked_equals_naive():
    cfg = get_smoke_config("rwkv6-3b").replace(dtype=jnp.float32)
    params, _ = rwkv6.init_rwkv(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 45), 0, cfg.vocab)
    lc, _, sc = rwkv6.forward(params, toks, cfg, chunked=True)
    ln, _, sn = rwkv6.forward(params, toks, cfg, chunked=False)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ln), rtol=TOL,
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(sc["wkv"]), np.asarray(sn["wkv"]),
                               rtol=1e-3, atol=1e-4)


def test_rolling_window_cache_decode():
    """SWA decode with a cache smaller than the sequence stays exact."""
    cfg = get_smoke_config("mixtral-8x22b").replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 40), 0, cfg.vocab)
    ref, _ = transformer.forward(params, toks, cfg)
    state, _ = family.init_decode_state(cfg, 1, 64)
    assert state["k"].shape[2] == cfg.sliding_window  # rolling buffer
    dec = _decode_all(family, params, cfg, toks, state)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=TOL,
                               atol=5e-4)
