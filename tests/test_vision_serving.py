"""Batched vision serving engine: microbatch parity with the direct
deploy-folded forward, FIFO ordering under variable arrival, bounded
queue eviction, per-request latency accounting, and (on the CI
multi-device lane) data-mesh-sharded microbatch parity."""
import jax
import numpy as np
import pytest

from repro.core.bn_fold import deploy_params
from repro.core.quant import QuantSpec, quantize_deploy
from repro.data import SyntheticVWW
from repro.models.mobilenetv2 import MNV2Config, apply_mnv2, init_mnv2
from repro.serving import VisionEngine, VisionRequest

CFG = MNV2Config(variant="p2m", image_size=20, width=0.25, head_channels=16)
BASE_CFG = MNV2Config(variant="baseline", image_size=20, width=0.25,
                      head_channels=16)


def _model(cfg=CFG, seed=0):
    return init_mnv2(jax.random.PRNGKey(seed), cfg)


def _images(n, cfg=CFG, seed=0):
    ds = SyntheticVWW(image_size=cfg.image_size, batch=n, seed=seed)
    return ds.batch_at(0)["images"]


def test_engine_matches_direct_deploy_forward():
    """Engine microbatching (incl. zero-padded free slots) must not
    change results: per-request probs equal the direct deploy-folded
    forward on the unpadded batch."""
    params, bn = _model()
    imgs = _images(5)
    engine = VisionEngine(params, bn, CFG, max_batch=2)
    for uid in range(5):
        engine.submit(VisionRequest(uid=uid, image=imgs[uid]))
    done = engine.run()
    assert len(done) == 5

    dep = quantize_deploy(deploy_params(params["stem"], bn["stem"], CFG.p2m),
                          QuantSpec(8, 8))
    logits, _ = apply_mnv2(params, bn, imgs, CFG, train=False, p2m_deploy=dep)
    probs_ref = np.asarray(jax.nn.softmax(logits, axis=-1))
    for req in done:
        np.testing.assert_allclose(req.probs, probs_ref[req.uid],
                                   rtol=1e-5, atol=1e-6)
        assert req.label == int(probs_ref[req.uid].argmax())


def test_engine_fifo_ordering_variable_arrival():
    """Completion preserves arrival order even when requests trickle in
    across ticks and span multiple launches."""
    params, bn = _model()
    imgs = _images(7)
    reqs = [VisionRequest(uid=i, image=imgs[i], arrival_tick=[0, 0, 0, 2, 2,
                                                              5, 5][i])
            for i in range(7)]
    engine = VisionEngine(params, bn, CFG, max_batch=2)
    done = engine.run(reqs)
    assert [r.uid for r in done] == list(range(7))
    # a request can never be served before it arrived
    assert all(r.served_tick > r.arrival_tick for r in done)


def test_engine_bounded_queue_evicts_oldest():
    params, bn = _model()
    imgs = _images(6)
    engine = VisionEngine(params, bn, CFG, max_batch=2, max_queue=3)
    for uid in range(6):  # 6 submits into a 3-deep queue, no steps between
        engine.submit(VisionRequest(uid=uid, image=imgs[uid]))
    assert [r.uid for r in engine.evicted] == [0, 1, 2]  # oldest dropped
    assert all(r.evicted for r in engine.evicted)
    done = engine.run()
    assert [r.uid for r in done] == [3, 4, 5]
    assert engine.latency_summary()["evictions"] == 3
    assert all(not r.evicted for r in done)


def test_engine_latency_counters():
    params, bn = _model()
    imgs = _images(5)
    engine = VisionEngine(params, bn, CFG, max_batch=4)
    # burst of 5 into 4 slots: one request waits a full extra tick
    reqs = [VisionRequest(uid=i, image=imgs[i]) for i in range(5)]
    done = engine.run(reqs)
    assert len(done) == 5
    assert [r.queue_ticks for r in done] == [1, 1, 1, 1, 2]
    assert all(r.batch_wall_us > 0 for r in done)

    s = engine.latency_summary()
    assert s["served"] == 5
    assert s["launches"] == 2
    assert s["utilization"] == pytest.approx(5 / 8)
    assert s["mean_queue_ticks"] == pytest.approx(6 / 5)
    assert s["mean_launch_us"] > 0
    assert s["evictions"] == 0


def test_engine_idle_ticks_advance_to_future_arrivals():
    params, bn = _model()
    imgs = _images(1)
    engine = VisionEngine(params, bn, CFG, max_batch=2)
    done = engine.run([VisionRequest(uid=0, image=imgs[0], arrival_tick=4)])
    assert len(done) == 1
    assert done[0].served_tick > 4


# ----------------------------- multi-device lane (scripts/ci.sh re-runs
# this test under XLA_FLAGS=--xla_force_host_platform_device_count=8)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (CI multi-device lane)")


@needs8
def test_sharded_engine_matches_single_device():
    """One engine tick with the microbatch sharded 8-way over the data
    mesh (pure-DP vision plan, DESIGN.md §7.1) matches the single-device
    tick within 1e-3.  One tick only: the deploy forward is stateless so
    a single launch is the whole contract — and clip nonlinearities make
    multi-step trajectory comparisons chaotic anyway (§7.1)."""
    from repro.launch.mesh import make_debug_mesh

    params, bn = _model()
    imgs = _images(8)
    reqs = lambda: [VisionRequest(uid=i, image=imgs[i]) for i in range(8)]

    single = VisionEngine(params, bn, CFG, max_batch=8)
    sharded = VisionEngine(params, bn, CFG, max_batch=8,
                           mesh=make_debug_mesh(8))
    for a, b in zip(reqs(), reqs()):
        single.submit(a)
        sharded.submit(b)
    d_single, d_sharded = single.step(), sharded.step()
    assert len(d_single) == len(d_sharded) == 8
    for a, b in zip(d_single, d_sharded):
        assert a.uid == b.uid
        np.testing.assert_allclose(b.probs, a.probs, rtol=1e-4, atol=1e-3)
        assert b.label == a.label


@needs8
def test_sharded_engine_splits_batch_over_mesh():
    """The *engine's* compiled forward actually distributes the
    microbatch: lower+compile the engine's jitted function and assert
    the image argument's per-device shard covers 1/8 of the batch (a
    silent fallback to a replicated image sharding would keep parity
    and throughput green — this pins the split itself)."""
    from repro.launch.mesh import make_debug_mesh

    params, bn = _model()
    engine = VisionEngine(params, bn, CFG, max_batch=8,
                          mesh=make_debug_mesh(8))
    h = CFG.image_size
    compiled = engine._fwd.lower(
        params, bn, engine._deploy,
        np.zeros((8, h, h, 3), np.float32)).compile()
    arg_shardings = jax.tree.leaves(compiled.input_shardings[0])
    img_sh = arg_shardings[-1]  # images is the last argument
    assert len(img_sh.device_set) == 8
    assert not img_sh.is_fully_replicated
    assert img_sh.shard_shape((8, h, h, 3)) == (1, h, h, 3)


def test_engine_baseline_variant_no_deploy_fold():
    """The baseline MobileNetV2 (no in-pixel layer) serves through the
    same engine; parity against the plain eval forward."""
    params, bn = _model(BASE_CFG)
    imgs = _images(3, BASE_CFG)
    engine = VisionEngine(params, bn, BASE_CFG, max_batch=4)
    for uid in range(3):
        engine.submit(VisionRequest(uid=uid, image=imgs[uid]))
    done = engine.run()
    logits, _ = apply_mnv2(params, bn, imgs, BASE_CFG, train=False)
    probs_ref = np.asarray(jax.nn.softmax(logits, axis=-1))
    for req in done:
        np.testing.assert_allclose(req.probs, probs_ref[req.uid],
                                   rtol=1e-5, atol=1e-6)
