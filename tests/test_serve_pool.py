"""Replica pools + event-driven front door (serving/pool.py,
launch/serve.py, DESIGN.md §11): lockstep equivalence of the event
loop, deterministic least-loaded dispatch, halted-replica exclusion,
exactly-once accounting under overload, per-engine cadences, and the
door-clock latency conversion.  The 8-virtual-device lane adds a
2-replica pool of mesh-sharded vision engines over disjoint submeshes.
"""
import dataclasses

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.launch.serve import FrontDoor
from repro.serving import ReplicaPool
from repro.serving.scheduler import (
    ADMITTED,
    REJECTED_HALTED,
    REJECTED_QUEUE,
    ScheduledRequest,
    SlotEngine,
)

# ------------------------------------------------------------ dummy adapters
# (tests cannot import benchmarks.*; these mirror the test_scheduler.py
# dummies — distinct request types per modality so the door can route)


@dataclasses.dataclass
class _AReq(ScheduledRequest):
    uid: int = 0


@dataclasses.dataclass
class _BReq(ScheduledRequest):
    uid: int = 0
    work: int = 1  # engine ticks of slot residency
    done: int = 0


class _AEngine(SlotEngine):
    """One-tick modality (the vision shape)."""

    request_type = _AReq

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return True


class _BEngine(SlotEngine):
    """Multi-tick modality (the LM/stream shape)."""

    request_type = _BReq

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        req.done += 1
        return req.done >= req.work


class _RaisingEngine(_AEngine):
    """Escapes its own launch containment after ``fail_at`` ticks —
    the bug class the pool's isolation boundary must contain."""

    def __init__(self, *a, fail_at=2, **kw):
        super().__init__(*a, **kw)
        self.fail_at = fail_at

    def step(self):
        if self.tick + 1 >= self.fail_at:
            self.tick += 1
            raise RuntimeError("replica wedged")
        return super().step()


def _mixed_trace(seed: int, n: int) -> list:
    """Seeded mixed two-modality trace with bursty arrivals."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        arrival = int(rng.integers(0, max(1, n // 2)))
        if rng.random() < 0.5:
            reqs.append(_AReq(uid=i, arrival_tick=arrival))
        else:
            reqs.append(_BReq(uid=i, work=1 + int(rng.integers(0, 4)),
                              arrival_tick=arrival))
    return reqs


def _ledger(door) -> list:
    """Every request the door ever saw, with its full latency ledger —
    the bit-identity witness for the equivalence property."""
    rows = [("done", name, r.uid, r.submitted_tick, r.served_tick,
             r.finished_tick, r.queue_ticks, r.serve_ticks)
            for name, r in door.completed]
    for name, e in door.engines.items():
        for kind in ("failed", "evicted", "rejected"):
            rows += [(kind, name, r.uid, r.submitted_tick, r.evicted_tick,
                      r.queue_ticks) for r in getattr(e, kind)]
    return sorted(rows)


# ------------------------------------------------- lockstep equivalence


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 40))
def test_event_loop_matches_lockstep_door(seed, n):
    """With every tick_cost equal, the event-driven door over 1-replica
    pools replays the lockstep reference door bit-identically: same
    completion set, same per-request ledgers, same rejections — on any
    seeded mixed trace, including overloaded ones (bounded queues)."""
    def build(lockstep, pooled):
        def wrap(e):
            return ReplicaPool(e) if pooled else e
        return FrontDoor(
            lockstep=lockstep,
            a=wrap(_AEngine(2, max_queue=3, evict="drop-newest")),
            b=wrap(_BEngine(2, max_queue=3, evict="drop-oldest")))

    ref = build(lockstep=True, pooled=False)
    evt = build(lockstep=False, pooled=True)
    ref.run(_mixed_trace(seed, n), max_ticks=10_000, on_undrained="raise")
    evt.run(_mixed_trace(seed, n), max_ticks=10_000, on_undrained="raise")
    assert _ledger(ref) == _ledger(evt)
    assert ref.tick == evt.tick


# ------------------------------------------------------- pool dispatch


def test_pool_least_loaded_dispatch_deterministic():
    """Arrivals spread least-loaded-first with index tie-breaks: the
    same submission sequence always lands on the same replicas."""
    def run_once():
        pool = ReplicaPool(_AEngine(1, max_queue=8), _AEngine(1, max_queue=8))
        for uid in range(5):
            assert pool.submit(_AReq(uid=uid)) == ADMITTED
        return [[r.uid for r in rep.queue] for rep in pool.replicas]

    first = run_once()
    # Tie at every even submission breaks to replica 0.
    assert first == [[0, 2, 4], [1, 3]]
    assert run_once() == first


def test_pool_rejects_only_when_all_replicas_reject():
    """Overflow on the least-loaded replica falls through to its
    sibling; rejection happens only when every replica is full — and
    lands on exactly one replica's ledger."""
    pool = ReplicaPool(_AEngine(1, max_queue=1), _AEngine(1, max_queue=1))
    assert [pool.submit(_AReq(uid=u)) for u in range(2)] == [ADMITTED] * 2
    assert pool.submit(_AReq(uid=2)) == REJECTED_QUEUE
    # Drop-newest records the overflow victim on the evicted ledger of
    # exactly one replica (the least-loaded one) — never on both.
    assert sum(len(rep.evicted) for rep in pool.replicas) == 1


def test_pool_halted_replica_excluded_but_pool_serves():
    """A replica whose step escapes containment is halted and excluded
    from dispatch; its traffic fails visibly, the sibling keeps serving,
    and the pool reports halted only when every replica is down."""
    bad = _RaisingEngine(1, max_queue=4, fail_at=1)
    good = _AEngine(1, max_queue=4)
    pool = ReplicaPool(bad, good)
    done = pool.run([_AReq(uid=u, arrival_tick=u) for u in range(6)],
                    max_ticks=50, on_undrained="warn")
    assert pool.down == {0: "RuntimeError: replica wedged"}
    assert pool.halted is None  # one live replica keeps the pool up
    assert bad.halted is not None
    # Everything the wedged replica held failed onto its ledger; the
    # survivor served the rest, including all post-failure arrivals.
    assert {r.uid for r in done} | {r.uid for r in pool.failed} == set(range(6))
    assert all(r.uid in {r2.uid for r2 in good.completed} for r in done)
    assert pool.health()["halted"] is None
    # After the survivor dies too, the pool is down and bounces submits.
    good.halt("drained")
    assert pool.halted is not None
    assert pool.submit(_AReq(uid=9)) == REJECTED_HALTED


def test_pool_exactly_once_accounting_under_overload():
    """Sustained overload of a bounded-queue pool: every submitted
    request lands on exactly one ledger (completed / rejected — never
    duplicated, never lost), both replicas take work, and admitted
    traffic all completes (no starvation)."""
    pool = ReplicaPool(_AEngine(1, max_queue=2), _AEngine(1, max_queue=2))
    reqs = [_AReq(uid=u, arrival_tick=u // 8) for u in range(80)]
    done = pool.run(reqs, max_ticks=200, on_undrained="raise")
    uids = [r.uid for r in done] + [r.uid for r in pool.evicted]
    assert sorted(uids) == list(range(80))  # exactly once, nowhere twice
    assert not pool.failed and not pool.rejected
    assert all(len(rep.completed) > 0 for rep in pool.replicas)
    served = {r.uid for rep in pool.replicas for r in rep.completed}
    assert len(served) == len(done)  # no request served by two replicas


def test_pool_validates_replica_homogeneity():
    with pytest.raises(ValueError):
        ReplicaPool(_AEngine(1), _BEngine(1))
    with pytest.raises(ValueError):
        ReplicaPool(_AEngine(1, tick_cost=1), _AEngine(1, tick_cost=2))
    with pytest.raises(ValueError):
        ReplicaPool()


# ------------------------------------------------- cadences + door clock


def test_door_cadences_fire_engines_at_tick_cost():
    """A tick_cost=3 engine ticks once per three door ticks, first at
    door tick 3; a tick_cost=1 engine ticks every door tick."""
    fast, slow = _AEngine(1), _BEngine(1, tick_cost=3)
    door = FrontDoor(fast=fast, slow=slow)
    ticks = []
    for _ in range(9):
        door.step()
        ticks.append((fast.tick, slow.tick))
    assert ticks[0] == (1, 0)
    assert ticks[2] == (3, 1)  # slow pays its cost, then fires
    assert ticks[8] == (9, 3)


def test_door_converts_latency_to_door_clock():
    """Every ``*_ticks`` figure the door reports is engine ticks x
    tick_cost — converted once, in the door, at any nesting depth."""
    slow = _BEngine(1, max_queue=4, tick_cost=2)
    door = FrontDoor(slow=slow)
    done = door.run([_BReq(uid=u, work=1, arrival_tick=0) for u in range(3)],
                    max_ticks=50, on_undrained="raise")
    assert len(done) == 3
    eng = slow.latency_summary()
    via_door = door.latency_summary()["slow"]
    for key in ("mean_queue_ticks", "mean_serve_ticks", "p95_queue_ticks",
                "p99_serve_ticks"):
        assert via_door[key] == 2 * eng[key]
    assert via_door["served"] == eng["served"]  # counts don't scale
    health = door.health()["engines"]["slow"]
    assert health["tick_cost"] == 2
    assert health["queue_depth"] == 0
    assert health["latency"]["mean_serve_ticks"] == 2 * eng["mean_serve_ticks"]


def test_door_converts_pool_latency_at_depth():
    """The conversion recurses into a pool's per-replica summaries."""
    pool = ReplicaPool(_BEngine(1, tick_cost=2), _BEngine(1, tick_cost=2))
    door = FrontDoor(b=pool)
    door.run([_BReq(uid=u, work=2) for u in range(4)],
             max_ticks=50, on_undrained="raise")
    summary = door.latency_summary()["b"]
    for rep_summary, rep in zip(summary["replicas"], pool.replicas):
        raw = rep.latency_summary()
        assert rep_summary["mean_serve_ticks"] == 2 * raw["mean_serve_ticks"]


def test_door_route_error_lists_registered_types():
    door = FrontDoor(a=_AEngine(1), b=_BEngine(1))
    with pytest.raises(TypeError) as err:
        door.submit(object())
    msg = str(err.value)
    assert "a=_AReq" in msg and "b=_BReq" in msg


def test_lockstep_door_rejects_nonunit_costs():
    with pytest.raises(ValueError):
        FrontDoor(lockstep=True, b=_BEngine(1, tick_cost=2))


# ----------------------------- multi-device lane (scripts/ci.sh re-runs
# this test under XLA_FLAGS=--xla_force_host_platform_device_count=8)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (CI multi-device lane)")


@needs8
def test_pooled_sharded_vision_matches_single_engine():
    """A 2-replica pool of mesh-sharded VisionEngines over the disjoint
    submeshes of `make_submeshes(2)` — replica-parallel across pools,
    data-parallel within — serves the same answers as one single-device
    engine: every request completes with matching probs/labels."""
    from repro.data import SyntheticVWW
    from repro.launch.mesh import make_submeshes
    from repro.models.mobilenetv2 import MNV2Config, init_mnv2
    from repro.serving import VisionEngine, VisionRequest

    cfg = MNV2Config(variant="p2m", image_size=20, width=0.25,
                     head_channels=16)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    imgs = SyntheticVWW(image_size=20, batch=8, seed=0).batch_at(0)["images"]

    sub = make_submeshes(2)
    assert [m.devices.size for m in sub] == [4, 4]
    assert not set(map(id, sub[0].devices.flat)) & \
        set(map(id, sub[1].devices.flat))  # disjoint replicas
    pool = ReplicaPool(
        VisionEngine(params, bn, cfg, max_batch=4, mesh=sub[0]),
        VisionEngine(params, bn, cfg, max_batch=4, mesh=sub[1]))
    single = VisionEngine(params, bn, cfg, max_batch=8)

    reqs = lambda: [VisionRequest(uid=i, image=imgs[i]) for i in range(8)]
    ref = {r.uid: r for r in single.run(reqs())}
    done = pool.run(reqs())
    assert len(done) == 8
    assert all(len(rep.completed) == 4 for rep in pool.replicas)
    for r in done:
        np.testing.assert_allclose(r.probs, ref[r.uid].probs,
                                   rtol=1e-4, atol=1e-3)
        assert r.label == ref[r.uid].label
