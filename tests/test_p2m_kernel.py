"""Pallas p2m_conv kernel vs the pure-jnp oracle: shape/dtype sweeps,
gradient agreement, CDS sign-split and zero-padding invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.adc import ADCConfig
from repro.core.pixel_model import default_pixel_model, fit_pixel_model
from repro.kernels.p2m_conv import p2m_matmul, p2m_matmul_jnp, p2m_matmul_ref

MODEL = default_pixel_model()
ADC = ADCConfig()


def _data(m, k, n, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((m, k)), dtype)
    w = jnp.asarray(rng.uniform(-1, 1, (k, n)), dtype)
    s = jnp.asarray(rng.uniform(-0.2, 0.2, (n,)), jnp.float32)
    return x, w, s


# Shapes chosen to hit: exact paper geometry (75), non-multiples of the
# 8/128 tile quanta in every dim, single row/col, >1 K tile.
SHAPES = [(100, 75, 8), (1, 75, 8), (256, 128, 128), (130, 33, 5),
          (64, 300, 16), (8, 1, 1), (1024, 75, 8)]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("mode", ["raw", "relu", "quant"])
def test_kernel_matches_ref(m, k, n, mode):
    x, w, s = _data(m, k, n)
    ref = (p2m_matmul_ref(x, w, MODEL, s, None) if mode == "raw" else
           p2m_matmul_ref(x, w, MODEL, s, ADC, quantize=(mode == "quant")))
    out = p2m_matmul(x, w, s, MODEL, ADC, mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
def test_kernel_dtypes(dtype, tol):
    x, w, s = _data(128, 75, 8, dtype=dtype)
    ref = p2m_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                         MODEL, s, ADC)
    out = p2m_matmul(x, w, s, MODEL, ADC, "relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_quant_mode_integer_exact():
    """ADC output lands exactly on the count grid (counts·Δ)."""
    x, w, s = _data(64, 75, 8, seed=5)
    out = np.asarray(p2m_matmul(x, w, s, MODEL, ADC, "quant"))
    counts = out / ADC.v_lsb
    assert np.allclose(counts, np.round(counts), atol=1e-4)
    assert counts.min() >= 0 and counts.max() <= ADC.max_count


def test_gradients_match_jnp_path():
    x, w, s = _data(48, 75, 8, seed=2)

    def loss_pallas(x, w, s):
        return (p2m_matmul(x, w, s, MODEL, ADC, "relu") ** 2).sum()

    def loss_jnp(x, w, s):
        return (p2m_matmul_jnp(x, w, s, MODEL, ADC, "relu") ** 2).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, s)
    g2 = jax.grad(loss_jnp, argnums=(0, 1, 2))(x, w, s)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_quant_mode_ste_gradient():
    """quant forward is stepped, but its gradient equals the relu path's."""
    x, w, s = _data(32, 27, 4, seed=7)
    gq = jax.grad(lambda xx: p2m_matmul(xx, w, s, MODEL, ADC, "quant").sum())(x)
    gr = jax.grad(lambda xx: p2m_matmul_jnp(xx, w, s, MODEL, ADC, "relu").sum())(x)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gr), rtol=1e-4,
                               atol=1e-5)


def test_cds_sign_split_equivalence():
    """CDS double-sampling: out == Σ g(w⁺,x) − Σ g(w⁻,x) with w = w⁺ − w⁻."""
    x, w, s = _data(40, 75, 6, seed=3)
    wp = jnp.maximum(w, 0.0)
    wn = jnp.maximum(-w, 0.0)
    zero = jnp.zeros_like(s)
    pos = p2m_matmul_jnp(x, wp, zero, MODEL, ADC, "raw")
    neg = p2m_matmul_jnp(x, wn, zero, MODEL, ADC, "raw")
    full = p2m_matmul_jnp(x, w, zero, MODEL, ADC, "raw")
    np.testing.assert_allclose(np.asarray(pos - neg), np.asarray(full),
                               rtol=1e-4, atol=1e-5)


def test_zero_padding_exact():
    """Padding K with zeros adds exactly nothing (i,j ≥ 1 basis)."""
    x, w, s = _data(32, 50, 8, seed=4)
    xp = jnp.pad(x, ((0, 0), (0, 30)))
    wp = jnp.pad(w, ((0, 30), (0, 0)))
    a = p2m_matmul_jnp(x, w, s, MODEL, ADC, "raw")
    b = p2m_matmul_jnp(xp, wp, s, MODEL, ADC, "raw")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 12),
       st.integers(0, 2**31 - 1))
def test_kernel_matches_ref_property(m, k, n, seed):
    x, w, s = _data(m, k, n, seed=seed)
    ref = p2m_matmul_ref(x, w, MODEL, s, ADC)
    out = p2m_matmul(x, w, s, MODEL, ADC, "relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_degree1_model_reduces_to_matmul():
    """With g(w,x) = w·x the whole layer is a plain (signed) matmul."""
    lin = fit_pixel_model(degree_w=1, degree_x=1,
                          samples_w=np.array([0.5, 1.0, 0.25]),
                          samples_x=np.array([1.0, 0.5, 0.25]),
                          samples_v=np.array([0.5, 0.5, 0.0625]))
    x, w, s = _data(16, 12, 3, seed=9)
    out = p2m_matmul_jnp(x, w, s, lin, ADC, "raw")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w + s),
                               rtol=1e-4, atol=1e-5)
