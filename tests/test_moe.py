"""MoE dispatch invariants + equivalence against a brute-force reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.init_utils import KeyGen, split_tree
from repro.models.moe import apply_moe, capacity, init_moe


def _cfg(e=8, k=2, cf=8.0):
    # huge capacity factor ⇒ no drops ⇒ exact equivalence testable
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=48, vocab=64,
                       n_experts=e, top_k=k, capacity_factor=cf,
                       dtype=jnp.float32)


def _params(cfg, seed=0):
    tree = init_moe(KeyGen(jax.random.PRNGKey(seed)), cfg, (1,))
    params, _ = split_tree(tree)
    return jax.tree.map(lambda a: a[0], params)  # drop layer dim


def _reference_moe(p, x, cfg):
    """Brute force: every token through its top-k experts."""
    g, s, d = x.shape
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    vals = vals / vals.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for gi in range(g):
        for si in range(s):
            acc = jnp.zeros((d,))
            for ki in range(cfg.top_k):
                e = int(idx[gi, si, ki])
                h = x[gi, si] @ p["wi"][e]
                gate = x[gi, si] @ p["wg"][e]
                acc += vals[gi, si, ki] * ((jax.nn.silu(gate) * h) @ p["wo"][e])
            out = out.at[gi, si].set(acc)
    return out


def test_moe_matches_bruteforce_no_drops():
    cfg = _cfg()
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 12, 32)),
                    jnp.float32)
    y, aux = apply_moe(p, x, cfg)
    ref = _reference_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux) > 0.0


def test_capacity_drops_tokens():
    cfg = _cfg(cf=0.25)  # tiny capacity ⇒ forced drops
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (1, 64, 32)),
                    jnp.float32)
    y, _ = apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    ref = _reference_moe(p, x, cfg)
    # dropped tokens make outputs differ — dispatch must NOT silently equal
    assert float(jnp.abs(y - ref).max()) > 1e-3


def test_capacity_rounding():
    cfg = _cfg()
    c = capacity(cfg, 128)
    assert c % 8 == 0 and c >= 128 * cfg.top_k / cfg.n_experts


def test_aux_loss_uniform_vs_skewed():
    """Balanced routing must have lower aux loss than a collapsed router."""
    cfg = _cfg(e=4, k=1)
    p = _params(cfg, seed=2)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (1, 64, 32)),
                    jnp.float32)
    _, aux_balanced = apply_moe(p, x, cfg)
    p_collapsed = dict(p)
    router = np.zeros((32, 4), np.float32)
    router[:, 0] = 10.0  # everything to expert 0
    p_collapsed["router"] = jnp.asarray(router)
    _, aux_collapsed = apply_moe(p_collapsed, x, cfg)
    assert float(aux_collapsed) > float(aux_balanced)


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg()
    p = _params(cfg, seed=3)
    x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (1, 16, 32)),
                    jnp.float32)

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return (y**2).sum() + aux

    g = jax.grad(loss)(p)
    for name in ("router", "wi", "wg", "wo"):
        assert float(jnp.abs(g[name]).max()) > 0.0, name
