"""Checkpoint manager (atomicity, corruption fallback, GC) and Trainer
fault-tolerance (resume, straggler detection, restart-on-failure)."""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataPipeline, SyntheticLMDataset
from repro.train import StragglerMonitor, Trainer, TrainState
from repro.train.trainer import StragglerMonitor


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 4)), "b": jnp.zeros((4,))},
        "opt": {"mu": {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = _state()
    mgr.save(7, state, extra={"pipeline": {"step": 7}}, blocking=True)
    restored, extra = mgr.restore_latest(_state(seed=1))
    assert extra == {"pipeline": {"step": 7}}
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["step"]) == 7


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_corrupt_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _state(1), blocking=True)
    mgr.save(2, _state(2), blocking=True)
    # corrupt newest: truncate the npz so it cannot be read back
    npz = tmp_path / "step_2" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[: 64])
    restored = mgr.restore_latest(_state())
    assert restored is not None
    state, _ = restored
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(_state(1)["params"]["w"]))


def test_tmp_dirs_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    (tmp_path / "step_9.tmp").mkdir()
    assert mgr.steps() == []


def test_straggler_monitor():
    mon = StragglerMonitor(window=16, threshold=3.0)
    flagged = []
    for i in range(20):
        dt = 1.0 if i != 15 else 10.0
        flagged.append(mon.observe(i, dt))
    assert flagged[15] is True
    assert sum(flagged) == 1
    assert mon.last_flagged == 15


class _FlakyStep:
    """Fails once at a chosen step, then behaves."""

    def __init__(self, fail_at=3):
        self.fail_at = fail_at
        self.calls = 0

    def __call__(self, state, batch):
        self.calls += 1
        if self.calls == self.fail_at:
            raise RuntimeError("simulated preemption")
        new = dict(state)
        new["step"] = state["step"] + 1
        new["params"] = jax.tree.map(lambda p: p * 0.9, state["params"])
        return new, {"loss": jnp.asarray(1.0 / self.calls)}


def test_trainer_restart_on_failure(tmp_path):
    ds = SyntheticLMDataset(vocab=64, seq_len=8, batch=2)
    pipe = DataPipeline(ds, prefetch=0)
    mgr = CheckpointManager(tmp_path)
    state = _state()
    state["step"] = jnp.asarray(0, jnp.int32)
    trainer = Trainer(_FlakyStep(fail_at=3), state, pipe, ckpt_manager=mgr,
                      ckpt_every=1, log_every=0, max_restarts=2)
    trainer.run(6)
    assert int(jax.device_get(trainer.state["step"])) == 6
    assert mgr.steps()  # checkpoints exist


def test_trainer_resume_from_checkpoint(tmp_path):
    ds = SyntheticLMDataset(vocab=64, seq_len=8, batch=2)
    mgr = CheckpointManager(tmp_path)
    pipe = DataPipeline(ds, prefetch=0)
    state = _state()
    state["step"] = jnp.asarray(0, jnp.int32)
    t1 = Trainer(_FlakyStep(fail_at=10**9), state, pipe, ckpt_manager=mgr,
                 ckpt_every=2, log_every=0)
    t1.run(4)
    # fresh trainer restores where the last left off (incl. pipeline cursor)
    pipe2 = DataPipeline(ds, prefetch=0)
    t2 = Trainer(_FlakyStep(fail_at=10**9), _state(seed=9), pipe2,
                 ckpt_manager=mgr, log_every=0)
    assert t2.restore()
    assert int(jax.device_get(t2.state["step"])) == 4
    assert pipe2.state_dict()["step"] == pipe.state_dict()["step"]


def test_pipeline_determinism_and_restart():
    ds = SyntheticLMDataset(vocab=97, seq_len=16, batch=4)
    p1 = DataPipeline(ds, prefetch=2)
    batches = [next(p1) for _ in range(5)]
    cursor = p1.state_dict()
    p1.close()
    # restart from step 3 must reproduce batch 3 exactly
    p2 = DataPipeline(ds, prefetch=0)
    p2.load_state_dict({"step": 3})
    b3 = next(p2)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    assert cursor == {"step": 5}
