"""Property-based parity for the fused P²M conv stack.

The parametrized matrix in `test_p2m_conv_fused.py` pins hand-picked
geometries; these properties draw random (H, W, C, k, s, mode) tuples
through the hypothesis shim (`_hypothesis_compat` — real hypothesis when
installed, a deterministic corner+random sampler otherwise) and assert
the full implementation-tier ladder agrees on each draw:

    fused Pallas (interpret) == fused XLA == patches+matmul == oracle

forward in every epilogue mode, and gradients (dImages, dW, dShift)
between the fused custom-VJP path and autodiff of the patch path.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.adc import ADCConfig
from repro.core.p2m_conv import extract_patches
from repro.core.pixel_model import default_pixel_model
from repro.kernels.p2m_conv import (
    im2col_matrix,
    p2m_conv,
    p2m_conv_jnp,
    p2m_conv_pallas,
    p2m_matmul_jnp,
    p2m_matmul_ref,
)
from repro.kernels.p2m_conv.ops import _coeff_tuple

MODEL = default_pixel_model()
ADC = ADCConfig()
COEFFS = _coeff_tuple(MODEL)
MODES = ("raw", "relu", "quant")
N_OUT = 5  # off the lane quantum on purpose


def _geometry(h, w_dim, c, k, s):
    """Clamp a raw draw into a valid conv geometry (image at least one
    kernel window on each side)."""
    h = max(h, k)
    w_dim = max(w_dim, k)
    return h, w_dim, c, k, s


def _data(h, w_dim, c, k, seed):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.random((2, h, w_dim, c)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1, 1, (k * k * c, N_OUT)), jnp.float32)
    sh = jnp.asarray(rng.uniform(-0.2, 0.2, (N_OUT,)), jnp.float32)
    return imgs, w, sh


def _patch_reference(imgs, w, sh, k, stride, mode):
    b = imgs.shape[0]
    patches = extract_patches(imgs, k, stride)
    out = p2m_matmul_jnp(patches.reshape(b * patches.shape[1], -1),
                         w, sh, MODEL, ADC, mode)
    ho = (imgs.shape[1] - k) // stride + 1
    wo = (imgs.shape[2] - k) // stride + 1
    return out.reshape(b, ho, wo, N_OUT)


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 20), st.integers(2, 20), st.integers(1, 4),
       st.integers(1, 5), st.integers(1, 6), st.integers(0, 2))
def test_fused_conv_forward_parity_random_geometry(h, w_dim, c, k, s, mode_i):
    h, w_dim, c, k, s = _geometry(h, w_dim, c, k, s)
    mode = MODES[mode_i]
    imgs, w, sh = _data(h, w_dim, c, k, seed=h * 31 + w_dim * 7 + k + s)

    ref = _patch_reference(imgs, w, sh, k, s, mode)
    fused_xla = p2m_conv_jnp(imgs, w, sh, MODEL, ADC, mode, k, s)
    np.testing.assert_allclose(np.asarray(fused_xla), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    fused_pl = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s,
                               coeffs=COEFFS, mode=mode, interpret=True)
    np.testing.assert_allclose(np.asarray(fused_pl), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # elementwise oracle (the faithful per-element g() formulation)
    xf = im2col_matrix(imgs, k, s)
    oracle = p2m_matmul_ref(xf, w, MODEL, sh,
                            None if mode == "raw" else ADC,
                            quantize=(mode == "quant"))
    np.testing.assert_allclose(np.asarray(ref).reshape(oracle.shape),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(3, 16), st.integers(3, 16), st.integers(1, 3),
       st.integers(2, 5), st.integers(1, 5), st.integers(0, 1))
def test_fused_conv_grad_parity_random_geometry(h, w_dim, c, k, s, mode_i):
    """Gradients through the fused custom-VJP conv (Pallas fwd + premixed
    closed-form bwd, incl. the col2im scatter for overlapping strides)
    match autodiff of the patch-materializing path on random geometry."""
    h, w_dim, c, k, s = _geometry(h, w_dim, c, k, s)
    mode = MODES[mode_i]
    imgs, w, sh = _data(h, w_dim, c, k, seed=h * 17 + w_dim * 3 + k * s)

    def loss_fused(im, ww, ss):
        return (p2m_conv(im, ww, ss, MODEL, ADC, mode, k, s, True,
                         "pallas") ** 2).sum()

    def loss_patch(im, ww, ss):
        return (_patch_reference(im, ww, ss, k, s, mode) ** 2).sum()

    g_fused = jax.grad(loss_fused, argnums=(0, 1, 2))(imgs, w, sh)
    g_patch = jax.grad(loss_patch, argnums=(0, 1, 2))(imgs, w, sh)
    for a, b in zip(g_fused, g_patch):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
