"""Pixel model: fit quality, structural constraints, Fig. 3 behaviour."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pixel_model import (
    W_RANGE,
    X_RANGE,
    default_pixel_model,
    fit_pixel_model,
    linear_pixel_model,
    spice_surrogate,
)


def test_fit_quality():
    m = default_pixel_model()
    assert m.fit_rmse < 1e-3
    w = np.linspace(0, 1, 41)
    x = np.linspace(0, 1, 41)
    wg, xg = np.meshgrid(w, x)
    err = np.abs(np.asarray(m(wg, xg)) - spice_surrogate(wg, xg))
    assert err.max() < 5e-3


def test_zero_boundaries():
    """g(0, x) = 0 (no weight transistor) and g(w, 0) = 0 (CDS reset)."""
    m = default_pixel_model()
    x = np.linspace(0, 1, 17)
    assert np.allclose(np.asarray(m(0.0, x)), 0.0, atol=1e-12)
    assert np.allclose(np.asarray(m(x, 0.0)), 0.0, atol=1e-12)


def test_monotone_in_w_and_x():
    """Fig. 3(a): pixel output increases with weight and with light."""
    m = default_pixel_model()
    grid = np.linspace(0.05, 1.0, 24)
    for fixed in (0.2, 0.5, 0.9):
        gw = np.asarray(m(grid, fixed))
        gx = np.asarray(m(fixed, grid))
        assert np.all(np.diff(gw) > -1e-6)
        assert np.all(np.diff(gx) > -1e-6)


def test_linear_model_is_product():
    m = linear_pixel_model()
    w = np.random.default_rng(0).random(100)
    x = np.random.default_rng(1).random(100)
    assert np.allclose(np.asarray(m(w, x)), w * x, atol=1e-6)


def test_fit_from_custom_samples():
    rng = np.random.default_rng(3)
    w, x = rng.random(500), rng.random(500)
    v = 0.5 * w * x + 0.25 * (w * x) ** 2
    m = fit_pixel_model(w, x, v, degree_w=2, degree_x=2)
    assert m.fit_rmse < 1e-6
    assert abs(m.term(1, 1) - 0.5) < 1e-6
    assert abs(m.term(2, 2) - 0.25) < 1e-6


@settings(max_examples=50, deadline=None)
@given(st.floats(W_RANGE[0], W_RANGE[1]), st.floats(X_RANGE[0], X_RANGE[1]))
def test_fit_close_pointwise(w, x):
    m = default_pixel_model()
    assert abs(float(m(w, x)) - float(spice_surrogate(w, x))) < 5e-3
