"""Serving engine: continuous batching matches per-request greedy decode;
slot recycling never leaks state between requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine, greedy_generate


def _setup(arch="llama3.2-1b"):
    cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    return cfg, family, params


def _reference_decode(params, cfg, prompt, n_new):
    """Single-request greedy decode (fresh state)."""
    out = greedy_generate(params, cfg,
                          jnp.asarray([prompt], jnp.int32), n_new,
                          max_len=64)
    return np.asarray(out[0]).tolist()


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-3b"])
def test_engine_matches_single_request_decode(arch):
    cfg, family, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 6).tolist() for _ in range(5)]

    engine = ServeEngine(params, cfg, max_batch=2, max_len=64)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=5))
    done = engine.run()
    assert len(done) == 5

    for req in done:
        ref = _reference_decode(params, cfg, req.prompt, 5)
        assert req.output == ref, (
            f"req {req.uid}: engine {req.output} != reference {ref} "
            f"(slot reuse leak?)")


def test_more_requests_than_slots():
    cfg, family, params = _setup()
    engine = ServeEngine(params, cfg, max_batch=2, max_len=32)
    for uid in range(7):
        engine.submit(Request(uid=uid, prompt=[uid + 1, uid + 2],
                              max_new_tokens=3))
    done = engine.run()
    assert sorted(r.uid for r in done) == list(range(7))
    assert all(len(r.output) == 3 for r in done)


def test_greedy_generate_shape():
    cfg, family, params = _setup()
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = greedy_generate(params, cfg, prompts, steps=4, max_len=32)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.padded_vocab
