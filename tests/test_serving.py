"""Serving engine: continuous batching matches per-request greedy decode;
slot recycling never leaks state between requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.families import get_family
from repro.serving import Request, ServeEngine, greedy_generate


def _setup(arch="llama3.2-1b"):
    cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    return cfg, family, params


def _reference_decode(params, cfg, prompt, n_new):
    """Single-request greedy decode (fresh state)."""
    out = greedy_generate(params, cfg,
                          jnp.asarray([prompt], jnp.int32), n_new,
                          max_len=64)
    return np.asarray(out[0]).tolist()


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-3b"])
def test_engine_matches_single_request_decode(arch):
    cfg, family, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 6).tolist() for _ in range(5)]

    engine = ServeEngine(params, cfg, max_batch=2, max_len=64)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=5))
    done = engine.run()
    assert len(done) == 5

    for req in done:
        ref = _reference_decode(params, cfg, req.prompt, 5)
        assert req.output == ref, (
            f"req {req.uid}: engine {req.output} != reference {ref} "
            f"(slot reuse leak?)")


def test_more_requests_than_slots():
    cfg, family, params = _setup()
    engine = ServeEngine(params, cfg, max_batch=2, max_len=32)
    for uid in range(7):
        engine.submit(Request(uid=uid, prompt=[uid + 1, uid + 2],
                              max_new_tokens=3))
    done = engine.run()
    assert sorted(r.uid for r in done) == list(range(7))
    assert all(len(r.output) == 3 for r in done)


def test_reset_slot_zeroes_only_that_slot():
    """_reset_slot must clear the freed slot's decode state (KV cache /
    recurrent state, batch axis 1 in every state tree) and leave the
    other slots' state untouched."""
    cfg, family, params = _setup("rwkv6-3b")
    engine = ServeEngine(params, cfg, max_batch=3, max_len=16)
    engine.state = jax.tree.map(lambda a: jnp.ones_like(a), engine.state)
    engine._reset_slot(1)
    for leaf in jax.tree.leaves(engine.state):
        arr = np.asarray(leaf)
        assert np.all(arr[:, 1] == 0), "freed slot not cleared"
        assert np.all(arr[:, 0] == 1), "neighbor slot was clobbered"
        assert np.all(arr[:, 2] == 1), "neighbor slot was clobbered"


@pytest.mark.parametrize("arch", ["rwkv6-3b", "llama3.2-1b"])
def test_recycled_slot_sees_no_stale_state(arch):
    """A slot freed by one request and re-admitted by another must behave
    as if freshly initialized — even if the previous occupant left
    non-zero KV/recurrent state behind.  Poison the engine state after
    the first request completes; admission must reset the slot, so the
    second request's output equals a fresh single-request decode.
    (Recurrent archs are the sharp case: stale state feeds *every*
    subsequent step, with no kv_pos masking to hide behind.)"""
    cfg, family, params = _setup(arch)
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab, 5).tolist()
    p2 = rng.integers(0, cfg.vocab, 4).tolist()

    engine = ServeEngine(params, cfg, max_batch=1, max_len=32)
    engine.submit(Request(uid=0, prompt=p1, max_new_tokens=4))
    engine.run()
    assert len(engine.completed) == 1

    # worst-case stale state: saturate every slot's decode state
    engine.state = jax.tree.map(lambda a: jnp.full_like(a, 7.0), engine.state)

    engine.submit(Request(uid=1, prompt=p2, max_new_tokens=4))
    done = engine.run()
    req2 = [r for r in done if r.uid == 1][0]
    assert req2.output == _reference_decode(params, cfg, p2, 4), \
        "recycled slot leaked previous occupant's state"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-3b"])
def test_chunked_prefill_token_identical(arch):
    """The chunked-prefill fast path (C prompt tokens per tick through the
    masked-scan chunk step) must emit exactly the tokens token-by-token
    prefill emits — prompt lengths below, at, and above the chunk size,
    finishing at different ticks so slots recycle mid-stream."""
    cfg, family, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).tolist()
               for n in (1, 3, 5, 7, 11)]  # C=5: shorter, equal, longer

    outs = {}
    for chunk in (1, 5):
        engine = ServeEngine(params, cfg, max_batch=2, max_len=64,
                             prefill_chunk=chunk)
        for uid, p in enumerate(prompts):
            engine.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        done = engine.run()
        assert len(done) == 5
        outs[chunk] = ({r.uid: r.output for r in done}, engine.tick)

    assert outs[5][0] == outs[1][0], "chunked prefill diverged"
    assert outs[5][1] < outs[1][1], "chunked prefill saved no ticks"
    for req_out in outs[5][0].values():
        assert len(req_out) == 4


def test_chunked_prefill_matches_reference_decode():
    """Chunked engine output equals fresh single-request greedy decode
    (the same invariant the token-by-token engine is held to)."""
    cfg, family, params = _setup("rwkv6-3b")
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 6).tolist() for _ in range(3)]
    engine = ServeEngine(params, cfg, max_batch=2, max_len=64,
                         prefill_chunk=4)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=5))
    for req in engine.run():
        assert req.output == _reference_decode(params, cfg, req.prompt, 5)


def test_bounded_queue_drop_newest():
    """The LM door sheds load by rejecting arrivals (an accepted prompt
    is a promise; the queue never breaks one already made)."""
    cfg, family, params = _setup()
    engine = ServeEngine(params, cfg, max_batch=1, max_len=32, max_queue=2)
    for uid in range(5):
        engine.submit(Request(uid=uid, prompt=[uid + 1],
                              max_new_tokens=2))
    # slot empty until run(): all 5 submits hit the 2-deep queue
    assert [r.uid for r in engine.evicted] == [2, 3, 4]
    done = engine.run()
    assert sorted(r.uid for r in done) == [0, 1]
    assert engine.latency_summary()["evictions"] == 3


def test_greedy_generate_shape():
    cfg, family, params = _setup()
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = greedy_generate(params, cfg, prompts, steps=4, max_len=32)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.padded_vocab


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-3b"])
def test_greedy_generate_chunked_prefill_token_identical(arch):
    """`greedy_generate` prefill now routes through the shared chunked
    step (whole prompt in ⌈P/C⌉ launches).  Every chunking — including
    the rwkv fused-WKV prefill hook — must emit exactly the tokens the
    legacy token-by-token loop (prefill_chunk=1) emits."""
    cfg, family, params = _setup(arch)
    rng = np.random.default_rng(7)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (3, 7)), jnp.int32)
    ref = np.asarray(greedy_generate(params, cfg, prompts, steps=5,
                                     max_len=64, prefill_chunk=1))
    for chunk in (3, 7, None):  # partial tail, exact, single launch
        out = np.asarray(greedy_generate(params, cfg, prompts, steps=5,
                                         max_len=64, prefill_chunk=chunk))
        np.testing.assert_array_equal(out, ref, err_msg=f"chunk={chunk}")


def test_slot_layout_validation_rejects_rglru():
    """Satellite guard: the chunked step's `keep` select and
    `_reset_slot` assume batch at axis 1 of every decode-state leaf.
    rglru declares batch at axis 2 for its grouped recurrent leaves —
    engines must refuse it loudly, not silently corrupt slots."""
    from repro.models.families import validate_slot_layout

    cfg = get_smoke_config("recurrentgemma-9b").replace(dtype=jnp.float32)
    with pytest.raises(ValueError, match="cache_batch"):
        validate_slot_layout(cfg)
    family = get_family(cfg)
    params, _ = family.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="cache_batch"):
        ServeEngine(params, cfg, max_batch=1, max_len=16)
