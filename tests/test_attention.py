"""Flash (online-softmax chunked) attention vs the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.attention import (
    apply_rope,
    dense_attention,
    flash_attention,
    gqa_repeat,
)


def _qkv(b, s, h, d, seed=0, t=None):
    rng = np.random.default_rng(seed)
    t = t or s
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kp = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    return q, k, v, qp, kp


@pytest.mark.parametrize("s,qc,kc", [(64, 16, 16), (60, 16, 32), (128, 128, 128),
                                     (37, 8, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_dense(s, qc, kc, causal):
    q, k, v, qp, kp = _qkv(2, s, 4, 16)
    ref = dense_attention(q, k, v, qp, kp, causal=causal)
    out = flash_attention(q, k, v, qp, kp, causal=causal, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("window", [4, 16, 100])
def test_flash_sliding_window(window):
    q, k, v, qp, kp = _qkv(1, 48, 2, 8, seed=1)
    ref = dense_attention(q, k, v, qp, kp, causal=True, window=window)
    out = flash_attention(q, k, v, qp, kp, causal=True, window=window,
                          q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_flash_gradients_match_dense():
    q, k, v, qp, kp = _qkv(1, 32, 2, 8, seed=2)

    def f_ref(q, k, v):
        return (dense_attention(q, k, v, qp, kp, causal=True) ** 2).sum()

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, qp, kp, causal=True,
                                q_chunk=8, kv_chunk=8) ** 2).sum()

    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-3)


def test_gqa_repeat():
    kv = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
    rep = gqa_repeat(kv, 6)
    assert rep.shape == (2, 3, 6, 4)
    for g in range(3):
        np.testing.assert_array_equal(np.asarray(rep[:, :, g]),
                                      np.asarray(kv[:, :, 0]))
        np.testing.assert_array_equal(np.asarray(rep[:, :, 3 + g]),
                                      np.asarray(kv[:, :, 1]))


def test_rope_relative_property():
    """RoPE: ⟨q_m, k_n⟩ depends only on (m − n)."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)), jnp.float32)

    def dot_at(m, n):
        qm = apply_rope(q, jnp.asarray([[m]]), 10000.0)
        kn = apply_rope(k, jnp.asarray([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-4


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(2, 40), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
def test_flash_matches_dense_property(b, s, h, seed):
    q, k, v, qp, kp = _qkv(b, s, h, 8, seed=seed)
    ref = dense_attention(q, k, v, qp, kp, causal=True)
    out = flash_attention(q, k, v, qp, kp, causal=True, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-4,
                               atol=5e-4)
