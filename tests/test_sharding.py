"""Sharding-plan machinery: spec sanitization, rule tables, spec trees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.specs import input_specs, plan_for_cell
from repro.parallel import plan_for, sanitize_spec, shard, use_plan
from repro.parallel.axes import logical_spec
from repro.parallel.sharding_utils import shardings_for


def _mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def _mesh16():
    """Abstract 16-device mesh shape for sanitization tests (no devices
    touched — sanitize only reads mesh.shape)."""
    class FakeMesh:
        shape = {"data": 4, "model": 4}
    return FakeMesh()


def test_sanitize_divisibility():
    m = _mesh16()
    spec = sanitize_spec((8, 12), P("data", "model"), m)
    assert spec == P("data", "model")
    spec = sanitize_spec((6, 12), P("data", "model"), m)  # 6 % 4 != 0
    assert spec == P(None, "model")


def test_sanitize_missing_axis():
    m = _mesh16()
    spec = sanitize_spec((8, 8), P(("pod", "data"), None), m)
    assert spec == P("data", None)


def test_sanitize_duplicate_axis_conflict():
    """MoE fallback: expert takes 'model'; mlp dim loses the conflict."""
    m = _mesh16()
    spec = sanitize_spec((8, 16, 16), P("model", None, "model"), m)
    assert spec == P("model", None, None)
    # when the first dim is not divisible, the later dim inherits the axis
    spec = sanitize_spec((6, 16, 16), P("model", None, "model"), m)
    assert spec == P(None, None, "model")


def test_logical_spec_resolution():
    mesh = _mesh()
    plan = plan_for(mesh)
    spec = logical_spec((4, 8), ("batch", "seq"), plan)
    # pod axis absent on single-pod mesh → dropped
    assert spec == P("data", None)


def test_shard_noop_outside_plan():
    x = jnp.ones((4, 4))
    y = shard(x, "batch", "seq")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fsdp_plan_shards_embed():
    plan = plan_for(_mesh(), fsdp=True)
    assert plan.rules["embed"] == "data"
    plan2 = plan_for(_mesh(), fsdp=False)
    assert plan2.rules["embed"] is None


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b", "whisper-tiny",
                                  "llama-3.2-vision-11b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    spec = SHAPES[shape]
    inputs, axes = input_specs(cfg, spec)
    assert set(inputs) == set(axes)
    if spec.kind == "train":
        assert inputs["tokens"].shape[0] == spec.global_batch
        if cfg.family == "encdec":
            assert inputs["src_embeds"].shape[1] == spec.seq_len
            assert inputs["tokens"].shape[1] == 448
        else:
            assert inputs["tokens"].shape[1] == spec.seq_len
    else:
        assert inputs["tokens"].shape == (spec.global_batch, 1)


def test_shardings_tree_structure():
    mesh = _mesh()
    plan = plan_for(mesh)
    values = {"a": jnp.zeros((4, 8)), "b": {"c": jnp.zeros((2,))}}
    axes = {"a": ("batch", "embed"), "b": {"c": ("heads",)}}
    sh = shardings_for(values, axes, plan)
    assert sh["a"].spec == P("data", None)
    assert sh["b"]["c"].spec == P("model") or sh["b"]["c"].spec == P(None)


def test_plan_for_cell_decode_uses_cache_sharding():
    cfg = get_config("qwen3-32b")
    mesh = _mesh()
    plan = plan_for_cell(cfg, SHAPES["decode_32k"], mesh)
    assert plan.rules["cache_seq"] == "model"
    plan_b1 = plan_for_cell(cfg, SHAPES["long_500k"], mesh)
    assert plan_b1.rules["cache_seq"] == ("data", "model")
    plan_train = plan_for_cell(cfg, SHAPES["train_4k"], mesh)
    assert plan_train.rules["cache_seq"] is None
    assert plan_train.rules["embed"] == "data"  # 32B model → FSDP


# ------------------------------------------------------------- vision DP


def test_vision_plan_is_pure_data_parallel():
    from repro.parallel import vision_plan_for

    plan = vision_plan_for(_mesh())
    spec = logical_spec((32, 40, 40, 3), ("batch", None, None, None), plan)
    assert spec == P("data", None, None, None)
    used = set()
    for v in plan.rules.values():
        if v is not None:
            used.update((v,) if isinstance(v, str) else v)
    assert "model" not in used  # the model axis stays free for LM co-tenants


def test_replicated_tree_and_batch_shardings():
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel import vision_plan_for
    from repro.parallel.sharding_utils import batch_shardings, replicated_tree

    mesh = make_debug_mesh()
    plan = vision_plan_for(mesh)
    state = {"params": {"w": jnp.ones((4, 3))}, "step": jnp.zeros((), jnp.int32)}
    rep = replicated_tree(state, plan)
    assert all(s.spec == P() for s in jax.tree.leaves(rep))

    batch = {"images": jnp.ones((8, 6, 6, 3)), "labels": jnp.ones((8,), jnp.int32),
             "mixup_lam": jnp.float32(0.2)}
    bs = batch_shardings(batch, plan)
    assert bs["images"].spec == P("data", None, None, None)
    assert bs["labels"].spec == P("data")
    assert bs["mixup_lam"].spec == P()  # scalar leaves replicate
    placed = jax.device_put(batch, bs)
    np.testing.assert_array_equal(np.asarray(placed["images"]),
                                  np.asarray(batch["images"]))


# ----------------------------- multi-device lane (scripts/ci.sh runs this
# file again under XLA_FLAGS=--xla_force_host_platform_device_count=8)

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (CI multi-device lane)")


@needs8
def test_batch_shardings_distribute_eight_ways():
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel import vision_plan_for
    from repro.parallel.sharding_utils import batch_shardings

    mesh = make_debug_mesh(8)
    plan = vision_plan_for(mesh)
    batch = {"x": jnp.arange(32.0).reshape(32, 1)}
    placed = jax.device_put(batch, batch_shardings(batch, plan))
    assert len(placed["x"].sharding.device_set) == 8
    with use_plan(plan), mesh:
        m = jax.jit(lambda b: shard(b["x"], "batch", None).mean())(placed)
    assert float(m) == 15.5  # global (cross-device) reduction


@needs8
def test_shard_constraint_partitions_jitted_compute():
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel import vision_plan_for

    mesh = make_debug_mesh(8)
    plan = vision_plan_for(mesh)
    x = jnp.arange(64.0).reshape(16, 4)
    with use_plan(plan), mesh:
        y = jax.jit(lambda v: shard(v, "batch", None) * 2.0)(x)
    assert len(y.sharding.device_set) == 8
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2.0)
