"""Sharding-plan machinery: spec sanitization, rule tables, spec trees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.specs import input_specs, plan_for_cell
from repro.parallel import plan_for, sanitize_spec, shard, use_plan
from repro.parallel.axes import logical_spec
from repro.parallel.sharding_utils import shardings_for


def _mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def _mesh16():
    """Abstract 16-device mesh shape for sanitization tests (no devices
    touched — sanitize only reads mesh.shape)."""
    class FakeMesh:
        shape = {"data": 4, "model": 4}
    return FakeMesh()


def test_sanitize_divisibility():
    m = _mesh16()
    spec = sanitize_spec((8, 12), P("data", "model"), m)
    assert spec == P("data", "model")
    spec = sanitize_spec((6, 12), P("data", "model"), m)  # 6 % 4 != 0
    assert spec == P(None, "model")


def test_sanitize_missing_axis():
    m = _mesh16()
    spec = sanitize_spec((8, 8), P(("pod", "data"), None), m)
    assert spec == P("data", None)


def test_sanitize_duplicate_axis_conflict():
    """MoE fallback: expert takes 'model'; mlp dim loses the conflict."""
    m = _mesh16()
    spec = sanitize_spec((8, 16, 16), P("model", None, "model"), m)
    assert spec == P("model", None, None)
    # when the first dim is not divisible, the later dim inherits the axis
    spec = sanitize_spec((6, 16, 16), P("model", None, "model"), m)
    assert spec == P(None, None, "model")


def test_logical_spec_resolution():
    mesh = _mesh()
    plan = plan_for(mesh)
    spec = logical_spec((4, 8), ("batch", "seq"), plan)
    # pod axis absent on single-pod mesh → dropped
    assert spec == P("data", None)


def test_shard_noop_outside_plan():
    x = jnp.ones((4, 4))
    y = shard(x, "batch", "seq")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fsdp_plan_shards_embed():
    plan = plan_for(_mesh(), fsdp=True)
    assert plan.rules["embed"] == "data"
    plan2 = plan_for(_mesh(), fsdp=False)
    assert plan2.rules["embed"] is None


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b", "whisper-tiny",
                                  "llama-3.2-vision-11b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    spec = SHAPES[shape]
    inputs, axes = input_specs(cfg, spec)
    assert set(inputs) == set(axes)
    if spec.kind == "train":
        assert inputs["tokens"].shape[0] == spec.global_batch
        if cfg.family == "encdec":
            assert inputs["src_embeds"].shape[1] == spec.seq_len
            assert inputs["tokens"].shape[1] == 448
        else:
            assert inputs["tokens"].shape[1] == spec.seq_len
    else:
        assert inputs["tokens"].shape == (spec.global_batch, 1)


def test_shardings_tree_structure():
    mesh = _mesh()
    plan = plan_for(mesh)
    values = {"a": jnp.zeros((4, 8)), "b": {"c": jnp.zeros((2,))}}
    axes = {"a": ("batch", "embed"), "b": {"c": ("heads",)}}
    sh = shardings_for(values, axes, plan)
    assert sh["a"].spec == P("data", None)
    assert sh["b"]["c"].spec == P("model") or sh["b"]["c"].spec == P(None)


def test_plan_for_cell_decode_uses_cache_sharding():
    cfg = get_config("qwen3-32b")
    mesh = _mesh()
    plan = plan_for_cell(cfg, SHAPES["decode_32k"], mesh)
    assert plan.rules["cache_seq"] == "model"
    plan_b1 = plan_for_cell(cfg, SHAPES["long_500k"], mesh)
    assert plan_b1.rules["cache_seq"] == ("data", "model")
    plan_train = plan_for_cell(cfg, SHAPES["train_4k"], mesh)
    assert plan_train.rules["cache_seq"] is None
    assert plan_train.rules["embed"] == "data"  # 32B model → FSDP
