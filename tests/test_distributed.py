"""Multi-device SPMD tests — run in a subprocess with 8 virtual CPU
devices (the main process keeps 1 device for every other test)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.families import get_family
        from repro.optim import sgd, constant
        from repro.train import TrainState, make_train_step
        from repro.train.state import state_logical_axes
        from repro.parallel import plan_for, use_plan
        from repro.parallel.sharding_utils import shardings_for
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("qwen3-32b").replace(dtype=jnp.float32)
        fam = get_family(cfg)
        batch = {
            "tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (8, 16)), jnp.int32),
            "targets": jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab, (8, 16)), jnp.int32),
        }
        opt = sgd(constant(1e-2))
        step = make_train_step(cfg, opt)

        # single-device reference
        params, axes = fam.init(jax.random.PRNGKey(0), cfg)
        s0 = TrainState(params, opt.init(params))
        ref_state, ref_metrics = jax.jit(step)(s0, batch)

        # sharded: 4-way data x 2-way model
        mesh = make_debug_mesh(8, model=2)
        plan = plan_for(mesh, fsdp=True)
        with use_plan(plan):
            params2, axes2 = fam.init(jax.random.PRNGKey(0), cfg)
            s1 = TrainState(params2, opt.init(params2))
            st_axes = state_logical_axes(axes2, s1["opt"])
            sh = shardings_for(s1, st_axes, plan)
            jitted = jax.jit(step, in_shardings=(sh, None), out_shardings=(sh, None))
            out_state, metrics = jitted(s1, batch)

        diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                            ref_state["params"], out_state["params"])
        max_diff = max(jax.tree.leaves(diff))
        n_shards = len(jax.tree.leaves(out_state["params"])[0].sharding.device_set)
        print(json.dumps({"loss_ref": float(ref_metrics["loss"]),
                          "loss_sharded": float(metrics["loss"]),
                          "max_param_diff": max_diff,
                          "devices": len(jax.devices())}))
    """))
    assert res["devices"] == 8
    assert abs(res["loss_ref"] - res["loss_sharded"]) < 1e-3
    assert res["max_param_diff"] < 1e-3


def test_sharded_moe_and_decode():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.families import get_family
        from repro.parallel import plan_for, use_plan
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("qwen3-moe-30b-a3b").replace(dtype=jnp.float32)
        fam = get_family(cfg)
        params, _ = fam.init(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (4, 24)), jnp.int32)
        batch = {"tokens": toks, "targets": toks}
        loss_ref, _ = fam.loss(params, batch, cfg)

        mesh = make_debug_mesh(8, model=4)  # experts (8) over model=4
        plan = plan_for(mesh)
        with use_plan(plan), mesh:
            loss_sh, _ = jax.jit(lambda p, b: fam.loss(p, b, cfg))(params, batch)

        # sharded decode with sequence-sharded cache
        plan_d = plan_for(mesh, cache_seq_shard=True)
        with use_plan(plan_d), mesh:
            state, _ = fam.init_decode_state(cfg, 4, 64)
            lg, _ = jax.jit(lambda p, s, t, pos: fam.decode(p, s, t, pos, cfg))(
                params, state, toks[:, :1], jnp.zeros((4,), jnp.int32))
        print(json.dumps({"loss_ref": float(loss_ref), "loss_sh": float(loss_sh),
                          "decode_finite": bool(jnp.all(jnp.isfinite(lg)))}))
    """))
    assert abs(res["loss_ref"] - res["loss_sh"]) < 1e-3
    assert res["decode_finite"]


def test_sharded_vww_train_matches_single_device():
    """The paper's workload at scale: P²M-MobileNetV2 VWW train step,
    8-way data-parallel with int8_ef gradient compression, matches the
    single-device step within 1e-3 on loss, params, and BN state.

    The parity assertion is on ONE step from identical state.  Multi-step
    trajectories are *not* comparable at tight tolerance: the saturating
    P²M ReLU / relu6 clips make the gradient a discontinuous function of
    the pre-activation, so an O(float-reassociation) forward difference
    can flip a clip mask and amplify chaotically across steps (DESIGN.md
    §7).  The sharded run is continued a few more steps to assert the
    compressed DP step keeps training (finite losses, advancing step
    counter, EF state carried)."""
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.data import SyntheticVWW
        from repro.models.mobilenetv2 import MNV2Config, init_mnv2
        from repro.optim import sgd, constant
        from repro.train.vision import (make_vww_train_step, vww_train_state,
                                        vww_train_shardings)
        from repro.parallel import use_plan, vision_plan_for
        from repro.launch.mesh import make_debug_mesh

        cfg = MNV2Config(variant="p2m", image_size=40, width=0.25,
                         head_channels=32)
        ds = SyntheticVWW(image_size=40, batch=32, seed=0)
        opt = sgd(constant(0.01), momentum=0.9)
        step = make_vww_train_step(cfg, opt, grad_compression="int8_ef")

        # single-device reference: one step from state S0
        params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
        ref = vww_train_state(params, bn, opt.init(params),
                              grad_compression="int8_ef")
        ref1, mref = jax.jit(step)(ref, ds.batch_at(0))

        # 8-way data-parallel with the vision plan, same S0
        mesh = make_debug_mesh(8)
        plan = vision_plan_for(mesh)
        with use_plan(plan), mesh:
            st = vww_train_state(params, bn, opt.init(params),
                                 grad_compression="int8_ef")
            batch0 = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
            st_sh, b_sh = vww_train_shardings(st, batch0, plan)
            jsh = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None))
            st, msh = jsh(st, jax.device_put(batch0, b_sh))
            pdiff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                                 ref1["params"], st["params"])
            bdiff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                                 ref1["bn"], st["bn"])
            # keep the sharded run going: compressed DP training advances
            losses = [float(msh["loss"])]
            for i in range(1, 5):
                batch = jax.device_put(
                    {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()},
                    b_sh)
                st, m = jsh(st, batch)
                losses.append(float(m["loss"]))
            replicas = len(
                jax.tree.leaves(st["params"])[0].sharding.device_set)
        print(json.dumps({
            "loss_ref": float(mref["loss"]), "losses_sh": losses,
            "max_param_diff": max(jax.tree.leaves(pdiff)),
            "max_bn_diff": max(jax.tree.leaves(bdiff)),
            "has_ef": "extras" in st,
            "step_count": int(st["step"]),
            "param_replicas": replicas,
            "devices": len(jax.devices())}))
    """))
    assert res["devices"] == 8
    assert res["has_ef"]
    assert res["param_replicas"] == 8  # replicated param tree spans the mesh
    assert abs(res["loss_ref"] - res["losses_sh"][0]) < 1e-3
    assert res["max_param_diff"] < 1e-3
    assert res["max_bn_diff"] < 1e-3
    assert res["step_count"] == 5
    assert all(np.isfinite(l) for l in res["losses_sh"])


def test_grad_compression_under_sharding():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.families import get_family
        from repro.optim import sgd, constant
        from repro.train import TrainState, make_train_step
        from repro.parallel import plan_for, use_plan
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
        fam = get_family(cfg)
        opt = sgd(constant(1e-2))
        step = make_train_step(cfg, opt, grad_compression="int8_ef")
        mesh = make_debug_mesh(8, model=2)
        plan = plan_for(mesh)
        rng = np.random.default_rng(0)
        with use_plan(plan), mesh:
            params, _ = fam.init(jax.random.PRNGKey(0), cfg)
            state = TrainState(params, opt.init(params))
            losses = []
            jstep = jax.jit(step)
            for i in range(8):
                toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)
                batch = {"tokens": toks, "targets": toks}
                state, m = jstep(state, batch)
                losses.append(float(m["loss"]))
        print(json.dumps({"first": losses[0], "last": losses[-1],
                          "has_ef": "extras" in state}))
    """))
    assert res["has_ef"]
    assert res["last"] < res["first"]  # training advances under compression
