"""Multi-device SPMD tests — run in a subprocess with 8 virtual CPU
devices (the main process keeps 1 device for every other test)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.families import get_family
        from repro.optim import sgd, constant
        from repro.train import TrainState, make_train_step
        from repro.train.state import state_logical_axes
        from repro.parallel import plan_for, use_plan
        from repro.parallel.sharding_utils import shardings_for
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("qwen3-32b").replace(dtype=jnp.float32)
        fam = get_family(cfg)
        batch = {
            "tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (8, 16)), jnp.int32),
            "targets": jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab, (8, 16)), jnp.int32),
        }
        opt = sgd(constant(1e-2))
        step = make_train_step(cfg, opt)

        # single-device reference
        params, axes = fam.init(jax.random.PRNGKey(0), cfg)
        s0 = TrainState(params, opt.init(params))
        ref_state, ref_metrics = jax.jit(step)(s0, batch)

        # sharded: 4-way data x 2-way model
        mesh = make_debug_mesh(8, model=2)
        plan = plan_for(mesh, fsdp=True)
        with use_plan(plan):
            params2, axes2 = fam.init(jax.random.PRNGKey(0), cfg)
            s1 = TrainState(params2, opt.init(params2))
            st_axes = state_logical_axes(axes2, s1["opt"])
            sh = shardings_for(s1, st_axes, plan)
            jitted = jax.jit(step, in_shardings=(sh, None), out_shardings=(sh, None))
            out_state, metrics = jitted(s1, batch)

        diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                            ref_state["params"], out_state["params"])
        max_diff = max(jax.tree.leaves(diff))
        n_shards = len(jax.tree.leaves(out_state["params"])[0].sharding.device_set)
        print(json.dumps({"loss_ref": float(ref_metrics["loss"]),
                          "loss_sharded": float(metrics["loss"]),
                          "max_param_diff": max_diff,
                          "devices": len(jax.devices())}))
    """))
    assert res["devices"] == 8
    assert abs(res["loss_ref"] - res["loss_sharded"]) < 1e-3
    assert res["max_param_diff"] < 1e-3


def test_sharded_moe_and_decode():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.families import get_family
        from repro.parallel import plan_for, use_plan
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("qwen3-moe-30b-a3b").replace(dtype=jnp.float32)
        fam = get_family(cfg)
        params, _ = fam.init(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (4, 24)), jnp.int32)
        batch = {"tokens": toks, "targets": toks}
        loss_ref, _ = fam.loss(params, batch, cfg)

        mesh = make_debug_mesh(8, model=4)  # experts (8) over model=4
        plan = plan_for(mesh)
        with use_plan(plan), mesh:
            loss_sh, _ = jax.jit(lambda p, b: fam.loss(p, b, cfg))(params, batch)

        # sharded decode with sequence-sharded cache
        plan_d = plan_for(mesh, cache_seq_shard=True)
        with use_plan(plan_d), mesh:
            state, _ = fam.init_decode_state(cfg, 4, 64)
            lg, _ = jax.jit(lambda p, s, t, pos: fam.decode(p, s, t, pos, cfg))(
                params, state, toks[:, :1], jnp.zeros((4,), jnp.int32))
        print(json.dumps({"loss_ref": float(loss_ref), "loss_sh": float(loss_sh),
                          "decode_finite": bool(jnp.all(jnp.isfinite(lg)))}))
    """))
    assert abs(res["loss_ref"] - res["loss_sh"]) < 1e-3
    assert res["decode_finite"]


def test_grad_compression_under_sharding():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.families import get_family
        from repro.optim import sgd, constant
        from repro.train import TrainState, make_train_step
        from repro.parallel import plan_for, use_plan
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
        fam = get_family(cfg)
        opt = sgd(constant(1e-2))
        step = make_train_step(cfg, opt, grad_compression="int8_ef")
        mesh = make_debug_mesh(8, model=2)
        plan = plan_for(mesh)
        rng = np.random.default_rng(0)
        with use_plan(plan), mesh:
            params, _ = fam.init(jax.random.PRNGKey(0), cfg)
            state = TrainState(params, opt.init(params))
            losses = []
            jstep = jax.jit(step)
            for i in range(8):
                toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)
                batch = {"tokens": toks, "targets": toks}
                state, m = jstep(state, batch)
                losses.append(float(m["loss"]))
        print(json.dumps({"first": losses[0], "last": losses[-1],
                          "has_ef": "extras" in state}))
    """))
    assert res["has_ef"]
    assert res["last"] < res["first"]  # training advances under compression
