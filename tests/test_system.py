"""End-to-end behaviour: the paper's pipeline (P²M MobileNetV2 on
synthetic VWW) trains, beats chance, and deploys consistently; the LM
pipeline trains with falling loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bn_fold import deploy_params
from repro.core.quant import QuantSpec, quantize_deploy
from repro.data import DataPipeline, SyntheticLMDataset, SyntheticVWW
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.optim import constant, sgd, adamw
from repro.train.vision import make_vww_eval, make_vww_train_step

P2M_SMOKE = MNV2Config(variant="p2m", image_size=40, width=0.25,
                       head_channels=32)
BASE_SMOKE = MNV2Config(variant="baseline", image_size=40, width=0.25,
                        head_channels=32)


def _train_vww(cfg, steps=40, seed=0):
    ds = SyntheticVWW(image_size=cfg.image_size, batch=32, seed=seed)
    params, bn = init_mnv2(jax.random.PRNGKey(seed), cfg)
    opt = sgd(constant(0.05), momentum=0.9)  # paper's optimizer
    state = {"params": params, "bn": bn, "opt": opt.init(params),
             "step": jnp.asarray(0, jnp.int32)}
    step = jax.jit(make_vww_train_step(cfg, opt))
    losses = []
    for i in range(steps):
        batch = ds.batch_at(i)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def test_p2m_vww_trains_above_chance():
    state, losses = _train_vww(P2M_SMOKE, steps=80)
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
    ds = SyntheticVWW(image_size=40, batch=128, seed=999)
    ev = make_vww_eval(P2M_SMOKE)
    acc = ev(state["params"], state["bn"], ds.batch_at(0))
    assert acc > 0.55, f"eval accuracy {acc} not above chance"


def test_p2m_deploy_consistency_after_training():
    """Fold + 8-bit quantization of the trained stem barely moves logits
    (the paper's PTQ claim: 8-bit ≈ fp)."""
    state, _ = _train_vww(P2M_SMOKE, steps=30)
    from repro.models.mobilenetv2 import apply_mnv2

    ds = SyntheticVWW(image_size=40, batch=16, seed=123)
    batch = ds.batch_at(0)
    logits_train, _ = apply_mnv2(state["params"], state["bn"], batch["images"],
                                 P2M_SMOKE, train=False)
    dep = deploy_params(state["params"]["stem"],
                        state["bn"]["stem"], P2M_SMOKE.p2m)
    dep8 = quantize_deploy(dep, QuantSpec(8, 8))
    logits_dep, _ = apply_mnv2(state["params"], state["bn"], batch["images"],
                               P2M_SMOKE, train=False, p2m_deploy=dep8)
    agree = (logits_train.argmax(-1) == logits_dep.argmax(-1)).mean()
    assert float(agree) > 0.85


def test_lm_training_loss_decreases():
    from repro.configs import get_smoke_config
    from repro.models.families import get_family
    from repro.train import TrainState, make_train_step

    cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    fam = get_family(cfg)
    params, _ = fam.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(constant(3e-3), weight_decay=0.0)
    state = TrainState(params, opt.init(params))
    step = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, batch=8)
    losses = []
    for i in range(40):
        b = ds.batch_at(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < 0.8 * np.mean(losses[:5])


def test_grad_accumulation_equivalence():
    """accum_steps=2 over a 2×batch equals two separate half-batches."""
    from repro.configs import get_smoke_config
    from repro.models.families import get_family
    from repro.train import TrainState, make_train_step

    cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    fam = get_family(cfg)
    params, _ = fam.init(jax.random.PRNGKey(0), cfg)
    opt = sgd(constant(1e-2), momentum=0.0)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, batch=8)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    s1 = TrainState(params, opt.init(params))
    step1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))
    out1, _ = step1(s1, batch)

    s2 = TrainState(params, opt.init(params))
    step2 = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    out2, _ = step2(s2, batch)

    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                        out1["params"], out2["params"])
    assert max(jax.tree.leaves(diff)) < 1e-5
