"""Analytic reproduction of the paper's tables: bandwidth (Eq. 2-3),
Table 2 (MAdds / peak memory), Fig. 8 EDP ratios."""
import pytest

from repro.core.bandwidth import FirstLayerGeom, bandwidth_reduction, compression_ratio
from repro.core.energy import (
    BASELINE_C_ENERGY,
    BASELINE_DELAY,
    N_PIX_BASELINE_C,
    N_PIX_P2M,
    P2M_DELAY,
    P2M_ENERGY,
    evaluate_model,
    total_macs,
)
from repro.models.mobilenetv2 import MNV2Config, layer_census, peak_activation_bytes


def test_bandwidth_reduction_table1():
    geom = FirstLayerGeom()  # paper Table 1 values
    br = bandwidth_reduction(geom)
    assert abs(br - 18.75) < 1e-9  # Eq. 2 with Table 1 values (paper: "~21×")
    assert abs(compression_ratio(geom) - 1 / 18.75) < 1e-12


def test_bandwidth_scales_with_bits():
    g8 = FirstLayerGeom(out_bits=8)
    g4 = FirstLayerGeom(out_bits=4)
    assert abs(bandwidth_reduction(g4) / bandwidth_reduction(g8) - 2.0) < 1e-9


# paper Table 2 values: (MAdds G, peak MB); peak convention per column —
# see models/mobilenetv2.py docstring.
TABLE2 = {
    ("baseline", 560): (1.93, 7.53),
    ("p2m", 560): (0.27, 0.30),
    ("baseline", 225): (0.31, 1.2),
    ("p2m", 225): (0.05, 0.049),
    ("baseline", 115): (0.09, 0.311),
    ("p2m", 115): (0.01, 0.013),
}


@pytest.mark.parametrize("variant,res", list(TABLE2))
def test_table2_reproduction(variant, res):
    paper_madds, paper_peak = TABLE2[(variant, res)]
    cfg = MNV2Config(variant=variant, image_size=res)
    madds = total_macs(layer_census(cfg)) / 1e9
    peak = peak_activation_bytes(cfg, fused_blocks=(variant == "p2m")) / 1e6
    assert abs(madds - paper_madds) / paper_madds < 0.45  # counting conventions
    assert abs(peak - paper_peak) / paper_peak < 0.06


def test_table2_reduction_ratios():
    """The headline ratios: ~7.15× MAdds, ~25.1× peak memory at 560²."""
    base = MNV2Config(variant="baseline", image_size=560)
    p2m = MNV2Config(variant="p2m", image_size=560)
    madds_ratio = total_macs(layer_census(base)) / total_macs(layer_census(p2m))
    peak_ratio = (peak_activation_bytes(base, fused_blocks=False)
                  / peak_activation_bytes(p2m, fused_blocks=True))
    assert 6.0 < madds_ratio < 8.0
    assert 23.0 < peak_ratio < 27.0


def test_fig8_edp_ratios():
    """Energy ≤7.81×, delay ≤2.15×, EDP 16.76× / ~11× (paper §5.3)."""
    p2m_census = layer_census(MNV2Config(variant="p2m", image_size=560))
    base_census = layer_census(MNV2Config(variant="baseline", image_size=560))
    rp = evaluate_model(p2m_census, N_PIX_P2M, P2M_ENERGY, P2M_DELAY)
    rb = evaluate_model(base_census, N_PIX_BASELINE_C, BASELINE_C_ENERGY,
                        BASELINE_DELAY)
    energy_ratio = rb.energy_uj / rp.energy_uj
    delay_ratio = rb.delay_sequential_ms / rp.delay_sequential_ms
    edp_seq = rb.edp_sequential / rp.edp_sequential
    edp_cons = rb.edp_conservative / rp.edp_conservative
    assert abs(energy_ratio - 7.81) / 7.81 < 0.05
    assert abs(delay_ratio - 2.15) / 2.15 < 0.08
    assert abs(edp_seq - 16.76) / 16.76 < 0.05
    assert abs(edp_cons - 11.0) / 11.0 < 0.15


def test_sensing_energy_breakdown():
    """P²M moves energy out of sensing+com: its sensor-side energy must be
    ≪ baseline's (the point of Fig. 8a)."""
    p2m_census = layer_census(MNV2Config(variant="p2m", image_size=560))
    base_census = layer_census(MNV2Config(variant="baseline", image_size=560))
    rp = evaluate_model(p2m_census, N_PIX_P2M, P2M_ENERGY, P2M_DELAY)
    rb = evaluate_model(base_census, N_PIX_BASELINE_C, BASELINE_C_ENERGY,
                        BASELINE_DELAY)
    assert (rp.sens_energy_uj + rp.com_energy_uj) < 0.12 * (
        rb.sens_energy_uj + rb.com_energy_uj)
