"""Analytic reproduction of the paper's tables: bandwidth (Eq. 2-3,
geometry validation, event-readout extension), Table 2 (MAdds / peak
memory), Fig. 8 EDP ratios."""
import pytest

from repro.core.bandwidth import (
    SKIP_FLAG_BITS,
    FirstLayerGeom,
    StreamBandwidthLedger,
    bandwidth_reduction,
    compression_ratio,
    event_readout_bits,
    frame_output_bits,
)
from repro.core.energy import (
    BASELINE_C_ENERGY,
    BASELINE_DELAY,
    N_PIX_BASELINE_C,
    N_PIX_P2M,
    P2M_DELAY,
    P2M_ENERGY,
    evaluate_model,
    total_macs,
)
from repro.models.mobilenetv2 import MNV2Config, layer_census, peak_activation_bytes


def test_bandwidth_reduction_table1():
    geom = FirstLayerGeom()  # paper Table 1 values
    br = bandwidth_reduction(geom)
    assert abs(br - 18.75) < 1e-9  # Eq. 2 with Table 1 values (paper: "~21×")
    assert abs(compression_ratio(geom) - 1 / 18.75) < 1e-12


def test_bandwidth_scales_with_bits():
    g8 = FirstLayerGeom(out_bits=8)
    g4 = FirstLayerGeom(out_bits=4)
    assert abs(bandwidth_reduction(g4) / bandwidth_reduction(g8) - 2.0) < 1e-9


@pytest.mark.parametrize("bad", [
    dict(kernel=600),              # kernel > padded image → out_spatial ≤ 0
    dict(image_size=4, kernel=5),  # same, small geometry
    dict(stride=0),                # stride must be ≥ 1
    dict(stride=-2),
    dict(out_bits=0),              # ADC width must be ≥ 1
    dict(out_channels=0),
    dict(padding=-1),
    dict(image_size=0),
    dict(kernel=0),
])
def test_first_layer_geom_rejects_degenerate(bad):
    """`__post_init__` validation: geometries that would silently floor
    `out_spatial` to ≤ 0 (or divide by zero downstream) raise."""
    with pytest.raises(ValueError):
        FirstLayerGeom(**bad)


def test_first_layer_geom_accepts_padding_rescue():
    """Padding can legalize a kernel bigger than the raw image."""
    g = FirstLayerGeom(image_size=4, kernel=5, padding=1, stride=1)
    assert g.out_spatial == 2


# ------------------------------------------------------------ event readout


def test_event_readout_closed_form():
    g = FirstLayerGeom()
    assert frame_output_bits(g) == g.output_elems * 8
    assert event_readout_bits(g, 1.0) == frame_output_bits(g) + SKIP_FLAG_BITS
    assert event_readout_bits(g, 0.0) == SKIP_FLAG_BITS
    with pytest.raises(ValueError):
        event_readout_bits(g, 1.5)


def test_stream_bandwidth_ledger_measures_reduction():
    """The measured ledger matches the closed form at the same rerun
    fraction, and its reduction crosses 1 as soon as any frame skips."""
    g = FirstLayerGeom(image_size=20, kernel=5, stride=5, out_channels=8,
                       out_bits=8)
    led = StreamBandwidthLedger(g)
    for reran in [True, False, True, False]:
        led.record(reran)
    assert led.frames == 4 and led.rerun_frames == 2
    assert led.skip_rate == pytest.approx(0.5)
    assert led.bits_per_frame == pytest.approx(event_readout_bits(g, 0.5))
    assert led.bits_per_frame < led.dense_bits_per_frame
    assert led.reduction_vs_dense > 1.9  # ≈ 2× at half-rate reruns
    dense = StreamBandwidthLedger(g)
    dense.record(True)
    assert dense.reduction_vs_dense < 1.0  # flag overhead, no skips yet


# paper Table 2 values: (MAdds G, peak MB); peak convention per column —
# see models/mobilenetv2.py docstring.
TABLE2 = {
    ("baseline", 560): (1.93, 7.53),
    ("p2m", 560): (0.27, 0.30),
    ("baseline", 225): (0.31, 1.2),
    ("p2m", 225): (0.05, 0.049),
    ("baseline", 115): (0.09, 0.311),
    ("p2m", 115): (0.01, 0.013),
}


@pytest.mark.parametrize("variant,res", list(TABLE2))
def test_table2_reproduction(variant, res):
    paper_madds, paper_peak = TABLE2[(variant, res)]
    cfg = MNV2Config(variant=variant, image_size=res)
    madds = total_macs(layer_census(cfg)) / 1e9
    peak = peak_activation_bytes(cfg, fused_blocks=(variant == "p2m")) / 1e6
    assert abs(madds - paper_madds) / paper_madds < 0.45  # counting conventions
    assert abs(peak - paper_peak) / paper_peak < 0.06


def test_table2_reduction_ratios():
    """The headline ratios: ~7.15× MAdds, ~25.1× peak memory at 560²."""
    base = MNV2Config(variant="baseline", image_size=560)
    p2m = MNV2Config(variant="p2m", image_size=560)
    madds_ratio = total_macs(layer_census(base)) / total_macs(layer_census(p2m))
    peak_ratio = (peak_activation_bytes(base, fused_blocks=False)
                  / peak_activation_bytes(p2m, fused_blocks=True))
    assert 6.0 < madds_ratio < 8.0
    assert 23.0 < peak_ratio < 27.0


def test_fig8_edp_ratios():
    """Energy ≤7.81×, delay ≤2.15×, EDP 16.76× / ~11× (paper §5.3)."""
    p2m_census = layer_census(MNV2Config(variant="p2m", image_size=560))
    base_census = layer_census(MNV2Config(variant="baseline", image_size=560))
    rp = evaluate_model(p2m_census, N_PIX_P2M, P2M_ENERGY, P2M_DELAY)
    rb = evaluate_model(base_census, N_PIX_BASELINE_C, BASELINE_C_ENERGY,
                        BASELINE_DELAY)
    energy_ratio = rb.energy_uj / rp.energy_uj
    delay_ratio = rb.delay_sequential_ms / rp.delay_sequential_ms
    edp_seq = rb.edp_sequential / rp.edp_sequential
    edp_cons = rb.edp_conservative / rp.edp_conservative
    assert abs(energy_ratio - 7.81) / 7.81 < 0.05
    assert abs(delay_ratio - 2.15) / 2.15 < 0.08
    assert abs(edp_seq - 16.76) / 16.76 < 0.05
    assert abs(edp_cons - 11.0) / 11.0 < 0.15


def test_sensing_energy_breakdown():
    """P²M moves energy out of sensing+com: its sensor-side energy must be
    ≪ baseline's (the point of Fig. 8a)."""
    p2m_census = layer_census(MNV2Config(variant="p2m", image_size=560))
    base_census = layer_census(MNV2Config(variant="baseline", image_size=560))
    rp = evaluate_model(p2m_census, N_PIX_P2M, P2M_ENERGY, P2M_DELAY)
    rb = evaluate_model(base_census, N_PIX_BASELINE_C, BASELINE_C_ENERGY,
                        BASELINE_DELAY)
    assert (rp.sens_energy_uj + rp.com_energy_uj) < 0.12 * (
        rb.sens_energy_uj + rb.com_energy_uj)
