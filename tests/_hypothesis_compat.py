"""`hypothesis` shim: property tests degrade to deterministic sampled examples.

The tier-1 environment is bare (no `hypothesis` wheel baked into the
image), but the property tests carry real coverage — shapes off the tile
quanta, random seeds, boundary floats.  Rather than skipping them
(`pytest.importorskip` would silently drop ~70 example runs), this shim
re-implements the tiny slice of the hypothesis API the suite uses
(`given`, `settings`, `st.integers`, `st.floats`) as a deterministic
example sampler: each decorated test runs against a fixed number of
pseudo-random draws plus the strategy's corner values (lo, hi).

When `hypothesis` *is* installed, it is used unmodified — the shim is a
pure re-export, so richer environments keep shrinking and the example
database.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import zlib

    import numpy as _np

    HAVE_HYPOTHESIS = False
    _N_RANDOM_EXAMPLES = 10

    class _Strategy:
        """A draw callable plus the corner values every run must include."""

        def __init__(self, draw, corners):
            self.draw = draw
            self.corners = corners

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                (min_value, max_value),
            )

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                (float(min_value), float(max_value)),
            )

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                # Seed from the test name so examples are stable across runs
                # and distinct across tests.
                seed = zlib.crc32(fn.__name__.encode())
                rng = _np.random.default_rng(seed)
                cases = [tuple(s.corners[0] for s in strategies),
                         tuple(s.corners[1] for s in strategies)]
                cases += [tuple(s.draw(rng) for s in strategies)
                          for _ in range(_N_RANDOM_EXAMPLES)]
                for case in cases:
                    fn(*args, *case, **kwargs)

            # Hide the strategy parameters from pytest's fixture resolution
            # (hypothesis does the same): the wrapper itself takes none.
            run.__signature__ = inspect.Signature()
            return run

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
