"""Scheduler core (serving/scheduler.py): eviction policies, latency
ledger, and the adapter contract — the LM and vision engines must be the
*same machine* (identical admit/evict/complete ordering and latency
counters) when their slot lifetimes coincide."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.data import SyntheticVWW
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.serving import (
    Request,
    ScheduledRequest,
    ServeEngine,
    SlotEngine,
    VisionEngine,
    VisionRequest,
)

# ------------------------------------------------------------- dummy adapter


@dataclasses.dataclass
class _Req(ScheduledRequest):
    uid: int = 0


class _OneTickEngine(SlotEngine):
    """Minimal adapter: every slot lives one tick, launch is a no-op."""

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return True


class _NTickEngine(SlotEngine):
    """Adapter whose requests occupy a slot for ``uid`` ticks (≥1)."""

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return req.serve_ticks >= max(1, req.uid)


# ------------------------------------------------------- eviction policies


def test_drop_newest_rejects_arrivals():
    eng = _OneTickEngine(1, max_queue=2, evict="drop-newest")
    reqs = [_Req(uid=i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    assert [r.uid for r in eng.evicted] == [2, 3]  # arrivals bounced
    assert all(r.evicted for r in eng.evicted)
    done = eng.run()
    assert [r.uid for r in done] == [0, 1]
    assert eng.stats["evictions"] == 2
    assert all(not r.evicted for r in done)


def test_drop_oldest_sheds_stale_queue():
    eng = _OneTickEngine(1, max_queue=2, evict="drop-oldest")
    reqs = [_Req(uid=i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    assert [r.uid for r in eng.evicted] == [0, 1]  # oldest waiting dropped
    done = eng.run()
    assert [r.uid for r in done] == [2, 3]


def test_zero_depth_queue_sheds_all_arrivals():
    """max_queue=0 is the degenerate bound: both policies shed the
    arrival itself (drop-oldest has no older frame to trade away)."""
    for policy in ("drop-newest", "drop-oldest"):
        eng = _OneTickEngine(1, max_queue=0, evict=policy)
        eng.submit(_Req(uid=0))
        assert [r.uid for r in eng.evicted] == [0], policy
        assert eng.run() == []


def test_unbounded_queue_never_evicts():
    eng = _OneTickEngine(2)  # max_queue=None
    for i in range(50):
        eng.submit(_Req(uid=i))
    done = eng.run()
    assert [r.uid for r in done] == list(range(50))
    assert eng.evicted == [] and eng.stats["evictions"] == 0


def test_custom_eviction_callable():
    """The policy slot is pluggable: a callable picking the victim."""
    def drop_odd_uid(queue, incoming):
        for j, r in enumerate(queue):
            if r.uid % 2:
                return queue.pop(j)
        return incoming

    eng = _OneTickEngine(1, max_queue=2, evict=drop_odd_uid)
    for i in range(4):
        eng.submit(_Req(uid=i))
    assert [r.uid for r in eng.evicted] == [1, 3]
    assert [r.uid for r in eng.run()] == [0, 2]


# ------------------------------------------------------- latency ledger


def test_latency_ledger_one_tick_slots():
    eng = _OneTickEngine(4)
    for i in range(5):
        eng.submit(_Req(uid=i))
    eng.run()
    assert [r.queue_ticks for r in eng.completed] == [1, 1, 1, 1, 2]
    assert all(r.serve_ticks == 1 for r in eng.completed)
    assert all(r.finished_tick == r.served_tick for r in eng.completed)
    s = eng.latency_summary()
    assert s["served"] == 5 and s["launches"] == 2
    assert s["utilization"] == pytest.approx(5 / 8)
    assert s["busy_utilization"] == pytest.approx(5 / 8)
    assert s["mean_queue_ticks"] == pytest.approx(6 / 5)
    assert s["mean_serve_ticks"] == 1.0


def test_latency_ledger_multi_tick_slots():
    """LM-shaped lifetimes: a slot held N ticks accrues serve_ticks=N and
    every launch it rode in lands in launch_wall_us."""
    eng = _NTickEngine(2)
    eng.submit(_Req(uid=3))  # 3 ticks in slot
    eng.submit(_Req(uid=1))  # 1 tick
    eng.submit(_Req(uid=2))  # admitted when uid=1 frees its slot
    done = eng.run()
    assert [r.uid for r in done] == [1, 3, 2]
    by = {r.uid: r for r in done}
    assert by[3].serve_ticks == 3 and by[1].serve_ticks == 1
    assert by[2].queue_ticks == 2  # submitted @0, slot freed only @2
    assert by[2].served_tick == 2 and by[2].finished_tick == 3
    # busy slot-ticks: t1 both, t2 slot0+slot1(admitted uid2), t3 both = 6?
    # t1: uid3+uid1; t2: uid3+uid2; t3: uid3+uid2 → 6 busy of 6 total
    assert eng.stats["busy_slot_ticks"] == 6
    assert eng.stats["slot_ticks"] == 6


def test_idle_ticks_advance_clock_without_launch():
    eng = _OneTickEngine(2)
    done = eng.run([_Req(uid=0, arrival_tick=4)])
    assert len(done) == 1
    assert done[0].served_tick > 4
    assert eng.stats["launches"] == 1  # idle ticks launched nothing


# ------------------------------------- adapter equivalence (property-based)
#
# With one-tick lifetimes on the LM side (prompt length 1, one new token)
# the two adapters must traverse *identical* schedules: same admit order,
# same evictions, same completion order, same per-request tick ledger —
# the shared core is the machine, the engines only supply the compute.

_LM_CFG = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
_V_CFG = MNV2Config(variant="p2m", image_size=20, width=0.25,
                    head_channels=16)

# Lazy module caches, not fixtures: the hypothesis shim hides the test's
# parameters from pytest's fixture resolution (as hypothesis itself
# does), so the property test takes no injected arguments.
_MODELS: dict = {}


def _lm_params():
    if "lm" not in _MODELS:
        fam = get_family(_LM_CFG)
        _MODELS["lm"], _ = fam.init(jax.random.PRNGKey(0), _LM_CFG)
    return _MODELS["lm"]


def _vision_model():
    if "vis" not in _MODELS:
        _MODELS["vis"] = init_mnv2(jax.random.PRNGKey(0), _V_CFG)
    return _MODELS["vis"]


def _ledger(requests):
    return [(r.uid, r.submitted_tick, r.served_tick, r.finished_tick,
             r.queue_ticks, r.serve_ticks) for r in requests]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 3),
       st.integers(0, 1))
def test_adapters_schedule_identically(seed, n_slots, max_queue, policy_ix):
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(4, 14))
    # bursty arrivals so the bounded queue actually overflows
    arrivals = np.sort(rng.integers(0, max(2, n_req // 2), n_req))
    policy = ("drop-newest", "drop-oldest")[policy_ix]

    params = _lm_params()
    vparams, vbn = _vision_model()
    imgs = SyntheticVWW(image_size=_V_CFG.image_size, batch=1,
                        seed=0).batch_at(0)["images"]

    lm = ServeEngine(params, _LM_CFG, max_batch=n_slots, max_len=16,
                     max_queue=max_queue, evict=policy)
    vis = VisionEngine(vparams, vbn, _V_CFG, max_batch=n_slots,
                       max_queue=max_queue, evict=policy)

    lm_reqs = [Request(uid=i, prompt=[1 + i % 7], max_new_tokens=1,
                       arrival_tick=int(t)) for i, t in enumerate(arrivals)]
    v_reqs = [VisionRequest(uid=i, image=imgs[0], arrival_tick=int(t))
              for i, t in enumerate(arrivals)]

    lm.run(lm_reqs)
    vis.run(v_reqs)

    assert [r.uid for r in lm.completed] == [r.uid for r in vis.completed]
    assert [r.uid for r in lm.evicted] == [r.uid for r in vis.evicted]
    assert _ledger(lm.completed) == _ledger(vis.completed)
    for key in ("launches", "served", "evictions", "slot_ticks",
                "busy_slot_ticks"):
        assert lm.stats[key] == vis.stats[key], key
    assert lm.tick == vis.tick
