"""Scheduler core (serving/scheduler.py): eviction policies, latency
ledger, and the adapter contract — the LM and vision engines must be the
*same machine* (identical admit/evict/complete ordering and latency
counters) when their slot lifetimes coincide."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.data import SyntheticVWW
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.serving import (
    Request,
    ScheduledRequest,
    ServeEngine,
    SlotEngine,
    VisionEngine,
    VisionRequest,
)

# ------------------------------------------------------------- dummy adapter


@dataclasses.dataclass
class _Req(ScheduledRequest):
    uid: int = 0


class _OneTickEngine(SlotEngine):
    """Minimal adapter: every slot lives one tick, launch is a no-op."""

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return True


class _NTickEngine(SlotEngine):
    """Adapter whose requests occupy a slot for ``uid`` ticks (≥1)."""

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return req.serve_ticks >= max(1, req.uid)


@dataclasses.dataclass
class _StreamReq(ScheduledRequest):
    """Multi-tick request with per-slot-state observability: ``length``
    ticks in a slot; the engine folds its per-slot counter into
    ``observed`` every tick."""

    uid: int = 0
    length: int = 1
    observed: list = dataclasses.field(default_factory=list)


class _StatefulStreamEngine(SlotEngine):
    """Multi-tick adapter with real per-slot state (a counter the
    occupant accumulates), recycled through ``_on_admit`` — the
    StreamEngine shape: gate reference / stem cache / tracker state all
    reduce to this."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.slot_state = [0] * self.n_slots

    def _on_admit(self, i, req):
        self.slot_state[i] = 0  # the isolation contract

    def _launch(self, active):
        for i, _ in active:
            self.slot_state[i] += 1
        return None

    def _absorb(self, i, req, result):
        req.observed.append(self.slot_state[i])
        return len(req.observed) >= req.length


# ------------------------------------------------------- eviction policies


def test_drop_newest_rejects_arrivals():
    eng = _OneTickEngine(1, max_queue=2, evict="drop-newest")
    reqs = [_Req(uid=i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    assert [r.uid for r in eng.evicted] == [2, 3]  # arrivals bounced
    assert all(r.evicted for r in eng.evicted)
    done = eng.run()
    assert [r.uid for r in done] == [0, 1]
    assert eng.stats["evictions"] == 2
    assert all(not r.evicted for r in done)


def test_drop_oldest_sheds_stale_queue():
    eng = _OneTickEngine(1, max_queue=2, evict="drop-oldest")
    reqs = [_Req(uid=i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    assert [r.uid for r in eng.evicted] == [0, 1]  # oldest waiting dropped
    done = eng.run()
    assert [r.uid for r in done] == [2, 3]


def test_zero_depth_queue_sheds_all_arrivals():
    """max_queue=0 is the degenerate bound: both policies shed the
    arrival itself (drop-oldest has no older frame to trade away)."""
    for policy in ("drop-newest", "drop-oldest"):
        eng = _OneTickEngine(1, max_queue=0, evict=policy)
        eng.submit(_Req(uid=0))
        assert [r.uid for r in eng.evicted] == [0], policy
        assert eng.run() == []


def test_unbounded_queue_never_evicts():
    eng = _OneTickEngine(2)  # max_queue=None
    for i in range(50):
        eng.submit(_Req(uid=i))
    done = eng.run()
    assert [r.uid for r in done] == list(range(50))
    assert eng.evicted == [] and eng.stats["evictions"] == 0


def test_custom_eviction_callable():
    """The policy slot is pluggable: a callable picking the victim."""
    def drop_odd_uid(queue, incoming):
        for j, r in enumerate(queue):
            if r.uid % 2:
                return queue.pop(j)
        return incoming

    eng = _OneTickEngine(1, max_queue=2, evict=drop_odd_uid)
    for i in range(4):
        eng.submit(_Req(uid=i))
    assert [r.uid for r in eng.evicted] == [1, 3]
    assert [r.uid for r in eng.run()] == [0, 2]


# -------------------------------------------- multi-tick slots + isolation


def test_multi_tick_occupancy_mixed_stream_lengths():
    """Mixed-length multi-tick requests through a 2-slot table: each
    occupies its slot for exactly `length` ticks, freed slots admit the
    next stream FIFO, and completion order follows remaining work."""
    eng = _StatefulStreamEngine(2)
    lens = [5, 2, 3, 1]
    reqs = [_StreamReq(uid=i, length=n) for i, n in enumerate(lens)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    # uid1 (len 2) frees slot1 @2; uid2 rides it @3-5, tying with uid0
    # @5 — ties resolve in slot order — and uid3 takes the first free slot
    assert [r.uid for r in done] == [1, 0, 2, 3]
    by = {r.uid: r for r in done}
    for i, n in enumerate(lens):
        assert by[i].serve_ticks == n
        # the slot counter reads 1..n for every stream — state began
        # fresh on admit and advanced once per held tick
        assert by[i].observed == list(range(1, n + 1))
    assert eng.stats["busy_slot_ticks"] == sum(lens)


def test_callable_eviction_with_multi_tick_streams():
    """The callable-eviction path under multi-tick lifetimes: a policy
    that sheds the *longest* waiting stream (the most slot-hungry) keeps
    short interactive streams and bounds the queue."""
    def drop_longest(queue, incoming):
        longest = max(queue + [incoming], key=lambda r: r.length)
        if longest is incoming:
            return incoming
        queue.remove(longest)
        return longest

    eng = _StatefulStreamEngine(1, max_queue=2, evict=drop_longest)
    for i, n in enumerate([9, 2, 7, 3, 1]):
        eng.submit(_StreamReq(uid=i, length=n))
    # queue bound 2: uid0 admitted later; uid2 (len 7) then uid1? —
    # victims are the longest waiters at each overflow
    assert all(r.evicted for r in eng.evicted)
    assert len(eng.queue) <= 2
    done = eng.run()
    assert {r.uid for r in done} | {r.uid for r in eng.evicted} == set(range(5))
    for r in done:
        assert r.observed == list(range(1, r.length + 1))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(0, 3))
def test_slot_state_never_leaks_across_recycled_streams(seed, n_slots,
                                                        max_queue):
    """Property: under random mixed-length arrivals, bounded queues and
    both eviction policies, every request observes its per-slot counter
    as exactly 1..length — a recycled slot NEVER shows a previous
    occupant's state.  This is the invariant StreamEngine's gate /
    stem-cache / tracker recycling depends on (DESIGN.md §9)."""
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(3, 16))
    policy = ("drop-newest", "drop-oldest")[int(rng.integers(0, 2))]
    eng = _StatefulStreamEngine(n_slots, max_queue=max_queue, evict=policy)
    reqs = [_StreamReq(uid=i, length=int(rng.integers(1, 6)),
                       arrival_tick=int(rng.integers(0, 8)))
            for i in range(n_req)]
    done = eng.run(reqs)
    # every request either completed or was shed at the queue
    assert {r.uid for r in done} | {r.uid for r in eng.evicted} == set(
        range(n_req))
    if max_queue > 0:
        assert done, "a nonzero queue must serve at least one arrival"
    for r in done:
        assert r.observed == list(range(1, r.length + 1)), (
            f"slot state leaked into request {r.uid}: {r.observed}")
        assert r.serve_ticks == r.length
    # evicted requests never touched a slot
    for r in eng.evicted:
        assert r.observed == [] and r.served_tick == -1


# ------------------------------------------------------- latency ledger


def test_latency_ledger_one_tick_slots():
    eng = _OneTickEngine(4)
    for i in range(5):
        eng.submit(_Req(uid=i))
    eng.run()
    assert [r.queue_ticks for r in eng.completed] == [1, 1, 1, 1, 2]
    assert all(r.serve_ticks == 1 for r in eng.completed)
    assert all(r.finished_tick == r.served_tick for r in eng.completed)
    s = eng.latency_summary()
    assert s["served"] == 5 and s["launches"] == 2
    assert s["utilization"] == pytest.approx(5 / 8)
    assert s["busy_utilization"] == pytest.approx(5 / 8)
    assert s["mean_queue_ticks"] == pytest.approx(6 / 5)
    assert s["mean_serve_ticks"] == 1.0


def test_latency_ledger_multi_tick_slots():
    """LM-shaped lifetimes: a slot held N ticks accrues serve_ticks=N and
    every launch it rode in lands in launch_wall_us."""
    eng = _NTickEngine(2)
    eng.submit(_Req(uid=3))  # 3 ticks in slot
    eng.submit(_Req(uid=1))  # 1 tick
    eng.submit(_Req(uid=2))  # admitted when uid=1 frees its slot
    done = eng.run()
    assert [r.uid for r in done] == [1, 3, 2]
    by = {r.uid: r for r in done}
    assert by[3].serve_ticks == 3 and by[1].serve_ticks == 1
    assert by[2].queue_ticks == 2  # submitted @0, slot freed only @2
    assert by[2].served_tick == 2 and by[2].finished_tick == 3
    # busy slot-ticks: t1 both, t2 slot0+slot1(admitted uid2), t3 both = 6?
    # t1: uid3+uid1; t2: uid3+uid2; t3: uid3+uid2 → 6 busy of 6 total
    assert eng.stats["busy_slot_ticks"] == 6
    assert eng.stats["slot_ticks"] == 6


def test_idle_ticks_advance_clock_without_launch():
    eng = _OneTickEngine(2)
    done = eng.run([_Req(uid=0, arrival_tick=4)])
    assert len(done) == 1
    assert done[0].served_tick > 4
    assert eng.stats["launches"] == 1  # idle ticks launched nothing


# ------------------------------------- adapter equivalence (property-based)
#
# With one-tick lifetimes on the LM side (prompt length 1, one new token)
# the two adapters must traverse *identical* schedules: same admit order,
# same evictions, same completion order, same per-request tick ledger —
# the shared core is the machine, the engines only supply the compute.

_LM_CFG = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
_V_CFG = MNV2Config(variant="p2m", image_size=20, width=0.25,
                    head_channels=16)

# Lazy module caches, not fixtures: the hypothesis shim hides the test's
# parameters from pytest's fixture resolution (as hypothesis itself
# does), so the property test takes no injected arguments.
_MODELS: dict = {}


def _lm_params():
    if "lm" not in _MODELS:
        fam = get_family(_LM_CFG)
        _MODELS["lm"], _ = fam.init(jax.random.PRNGKey(0), _LM_CFG)
    return _MODELS["lm"]


def _vision_model():
    if "vis" not in _MODELS:
        _MODELS["vis"] = init_mnv2(jax.random.PRNGKey(0), _V_CFG)
    return _MODELS["vis"]


def _ledger(requests):
    return [(r.uid, r.submitted_tick, r.served_tick, r.finished_tick,
             r.queue_ticks, r.serve_ticks) for r in requests]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 3),
       st.integers(0, 1))
def test_adapters_schedule_identically(seed, n_slots, max_queue, policy_ix):
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(4, 14))
    # bursty arrivals so the bounded queue actually overflows
    arrivals = np.sort(rng.integers(0, max(2, n_req // 2), n_req))
    policy = ("drop-newest", "drop-oldest")[policy_ix]

    params = _lm_params()
    vparams, vbn = _vision_model()
    imgs = SyntheticVWW(image_size=_V_CFG.image_size, batch=1,
                        seed=0).batch_at(0)["images"]

    lm = ServeEngine(params, _LM_CFG, max_batch=n_slots, max_len=16,
                     max_queue=max_queue, evict=policy)
    vis = VisionEngine(vparams, vbn, _V_CFG, max_batch=n_slots,
                       max_queue=max_queue, evict=policy)

    lm_reqs = [Request(uid=i, prompt=[1 + i % 7], max_new_tokens=1,
                       arrival_tick=int(t)) for i, t in enumerate(arrivals)]
    v_reqs = [VisionRequest(uid=i, image=imgs[0], arrival_tick=int(t))
              for i, t in enumerate(arrivals)]

    lm.run(lm_reqs)
    vis.run(v_reqs)

    assert [r.uid for r in lm.completed] == [r.uid for r in vis.completed]
    assert [r.uid for r in lm.evicted] == [r.uid for r in vis.evicted]
    assert _ledger(lm.completed) == _ledger(vis.completed)
    for key in ("launches", "served", "evictions", "slot_ticks",
                "busy_slot_ticks"):
        assert lm.stats[key] == vis.stats[key], key
    assert lm.tick == vis.tick
