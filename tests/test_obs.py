"""Observability subsystem (DESIGN.md §13): deterministic tick-domain
tracing, the unified metrics registry, structured logging, and the
kernel/compile counters.

The two hard contracts from §13.3 are pinned here on real scheduler
machinery (dummy adapters, no models):

* tracing is **bit-for-bit free when disabled** — an engine with
  ``tracer=None`` and one with a disabled tracer replay a seeded chaos
  trace to identical ledgers and summaries;
* tracing is **deterministic when enabled** — two fresh tracers over
  the same seeded chaos (through a mixed-cadence event-driven front
  door) export byte-identical Perfetto JSON that passes schema
  validation.
"""
import dataclasses
import json
import logging

import pytest

from repro.launch.serve import FrontDoor
from repro.obs import (
    Counter,
    MetricsRegistry,
    REQUEST_TID_BASE,
    TickHistogram,
    Tracer,
    counted_lru_cache,
    default_registry,
    format_record,
    structured,
    tick_percentiles,
    validate_trace_events,
)
from repro.serving import (
    FaultInjector,
    FaultPlan,
    ScheduledRequest,
    SlotEngine,
)

# ------------------------------------------------------------- dummy adapters
# (mirrors tests/test_faults.py: tiny SlotEngine adapters, no models)


@dataclasses.dataclass
class _Req(ScheduledRequest):
    uid: int = 0


@dataclasses.dataclass
class _ReqB(ScheduledRequest):
    uid: int = 0


@dataclasses.dataclass
class _StreamReq(ScheduledRequest):
    uid: int = 0
    length: int = 1
    observed: list = dataclasses.field(default_factory=list)


class _OneTickEngine(SlotEngine):
    request_type = _Req

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        return True


class _OneTickEngineB(_OneTickEngine):
    request_type = _ReqB


class _StreamEngine(SlotEngine):
    request_type = _StreamReq

    def _launch(self, active):
        return None

    def _absorb(self, i, req, result):
        req.observed.append(self.tick)
        return len(req.observed) >= req.length


def _chaos_traffic(n=12):
    """Seeded mixed traffic with staggered arrivals and deadlines."""
    reqs = [_Req(uid=i, arrival_tick=i // 3, deadline_tick=i + 20)
            for i in range(n)]
    reqs += [_ReqB(uid=100 + i, arrival_tick=i // 2) for i in range(n // 2)]
    return reqs


def _chaos_engine(tracer=None, registry=None, n_slots=2):
    inj = FaultInjector(FaultPlan(launch_error_rate=0.2, stuck_rate=0.15,
                                  seed=7),
                        registry=registry)
    return _StreamEngine(n_slots, max_queue=4, evict="deadline",
                         max_serve_ticks=6, launch_retries=1, faults=inj,
                         tracer=tracer, registry=registry)


def _chaos_run(tracer=None, registry=None):
    eng = _chaos_engine(tracer=tracer, registry=registry)
    reqs = [_StreamReq(uid=i, length=1 + i % 3, arrival_tick=i // 2,
                       deadline_tick=i + 25) for i in range(10)]
    eng.run(reqs, max_ticks=200)
    return eng


# ------------------------------------------------------ structured logging


def test_format_record_deterministic():
    a = format_record("p2m_event", zulu=1, alpha="x")
    b = format_record("p2m_event", alpha="x", zulu=1)
    assert a == b  # field order never leaks into the record
    rec = json.loads(a)
    assert rec["event"] == "p2m_event"
    assert rec["schema"] == 1
    assert " " not in a.split('"alpha"')[0]  # compact separators


def test_structured_logs_and_counts(caplog):
    reg = default_registry()
    before = reg.counter("log.obs_test_event").value
    log = logging.getLogger("test_obs")
    with caplog.at_level(logging.WARNING, logger="test_obs"):
        line = structured(log, "obs_test_event", level=logging.WARNING,
                          detail="hello")
    assert json.loads(line)["detail"] == "hello"
    assert any("obs_test_event" in r.message for r in caplog.records)
    assert reg.counter("log.obs_test_event").value == before + 1


# ------------------------------------------------------- metrics registry


def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_tick_histogram_matches_serving_estimator():
    h = TickHistogram()
    vals = [1, 2, 3, 5, 8, 13, 21]
    for v in vals:
        h.observe(v)
    assert h.percentiles() == tick_percentiles(vals)
    s = h.summary()
    assert s["count"] == len(vals)
    assert s["sum"] == float(sum(vals))


def test_registry_scopes_deterministic_and_views_weakref():
    reg = MetricsRegistry()
    e1, e2 = _OneTickEngine(1, registry=reg), _OneTickEngine(1, registry=reg)
    assert e1.metrics_scope == "_OneTickEngine#0"
    assert e2.metrics_scope == "_OneTickEngine#1"
    snap = reg.snapshot()
    assert set(snap["components"]) == {e1.metrics_scope, e2.metrics_scope}
    assert set(snap["components"][e1.metrics_scope]) == {"latency", "health"}
    del e2  # dead components drop out silently — the registry never
    import gc

    gc.collect()  # leaks an engine (weakref views, DESIGN.md §13.2)
    assert set(reg.snapshot()["components"]) == {e1.metrics_scope}


def test_registry_snapshot_matches_legacy_summaries():
    """The registry is a *view* over the legacy dict APIs: the snapshot
    and a direct summary call must read the same numbers."""
    reg = MetricsRegistry()
    eng = _chaos_run(registry=reg)
    snap = reg.snapshot()
    comp = snap["components"][eng.metrics_scope]
    assert comp["latency"] == eng.latency_summary()
    assert comp["health"] == eng.health()
    # the fault injector publishes its tallies into the same registry
    inj_scopes = [s for s in snap["components"] if s.startswith("FaultInjector")]
    assert inj_scopes
    assert snap["components"][inj_scopes[0]]["faults"] == eng.faults.summary()
    # tick histograms observe each completion with the exact ledger values
    hq = snap["tick_histograms"][f"{eng.metrics_scope}.queue_ticks"]
    hs = snap["tick_histograms"][f"{eng.metrics_scope}.serve_ticks"]
    s = eng.latency_summary()
    assert hq["count"] == hs["count"] == s["served"]
    assert hq["p50"] == s["p50_queue_ticks"]
    assert hs["p50"] == s["p50_serve_ticks"]


def test_counted_lru_cache_counts_and_survives_reset():
    reg = default_registry()
    calls = []

    @counted_lru_cache("obs_test_fn")
    def fn(x):
        calls.append(x)
        return x * 2

    h = reg.counter("compile_cache.obs_test_fn.hits")
    m = reg.counter("compile_cache.obs_test_fn.misses")
    h0, m0 = h.value, m.value
    assert fn(3) == 6 and fn(3) == 6 and fn(4) == 8
    assert calls == [3, 4]
    assert (h.value - h0, m.value - m0) == (1, 2)
    assert fn.cache_info().currsize == 2  # lru_cache API passes through
    # a registry reset (test isolation) must not orphan the cache:
    # counters are re-fetched per call, so counting just starts over
    reg.reset()
    fn(3)
    assert reg.counter("compile_cache.obs_test_fn.hits").value == 1


# --------------------------------------------------- autotuner observability


def test_autotune_counters_and_decision_record():
    # lazy: repro.kernels.p2m_conv must not be the module's first repro
    # import (core <-> kernels import cycle resolves via repro.core)
    from repro.core.adc import ADCConfig  # noqa: F401
    from repro.kernels.p2m_conv import tune

    reg = default_registry()
    hit = reg.counter("autotune.cache_hit")
    miss = reg.counter("autotune.cache_miss")
    h0, m0 = hit.value, miss.value
    key = ("obs_test", 1, 2)
    tune._CACHE.pop(key, None)
    try:
        r = tune.autotune(key, [(8, 8), (16, 16)],
                          lambda c: None, iters=1,
                          vmem=lambda c: c[0] * c[1] * 4)
        assert miss.value - m0 == 1
        # second serve of the same key is a cache hit — the counter the
        # acceptance criterion pins non-zero on cached paths
        assert tune.autotune(key, [(8, 8), (16, 16)], lambda c: None) is r
        assert hit.value - h0 == 1
        recs = [d for d in tune.decision_records() if d["kind"] == "obs_test"]
        assert len(recs) == 1
        d = recs[0]
        assert d["best"] in ([8, 8], [16, 16])
        assert d["candidates"] == [[8, 8], [16, 16]]
        assert d["vmem_bytes"] == [256, 1024]
        assert d["n_viable"] == 2
    finally:
        tune._CACHE.pop(key, None)


# ------------------------------------------------------------------ tracer


def test_disabled_tracer_is_bitwise_free():
    """tracer=None, Tracer(enabled=False), and an enabled tracer all
    replay the same seeded chaos to identical ledgers — tracing never
    touches schedule state (§13.3)."""
    base = _chaos_run(tracer=None, registry=MetricsRegistry())
    off = Tracer(enabled=False)
    dis = _chaos_run(tracer=off, registry=MetricsRegistry())
    on = _chaos_run(tracer=Tracer(), registry=MetricsRegistry())
    assert off.events == []  # a disabled tracer records nothing

    def ledgers(e):
        return {
            "completed": [r.uid for r in e.completed],
            "failed": [(r.uid, r.failure) for r in e.failed],
            "evicted": [r.uid for r in e.evicted],
            "rejected": [r.uid for r in e.rejected],
            "observed": {r.uid: r.observed for r in e.completed},
            "latency": {k: v for k, v in e.latency_summary().items()
                        if not k.endswith("_us") and k != "mean_launch_us"},
        }

    assert ledgers(base) == ledgers(dis) == ledgers(on)


def _traced_door_replay(tracer):
    """One seeded chaos replay through a mixed-cadence event-driven
    front door: two modalities, tick_cost 1 and 2 (exercises the clock
    scaling), launch faults and stuck slots (exercises the containment
    events)."""
    inj = FaultInjector(FaultPlan(launch_error_rate=0.15, stuck_rate=0.1,
                                  seed=3),
                        registry=MetricsRegistry())
    a = _OneTickEngine(2, max_queue=3, evict="deadline", max_serve_ticks=5,
                       launch_retries=1, faults=inj,
                       registry=MetricsRegistry())
    b = _OneTickEngineB(1, max_queue=2, tick_cost=2,
                        registry=MetricsRegistry())
    door = FrontDoor(tracer=tracer, fast=a, slow=b,
                     registry=MetricsRegistry())
    door.run(_chaos_traffic(), max_ticks=300)
    return door


def test_enabled_tracer_deterministic_and_valid():
    tr1, tr2 = Tracer(), Tracer()
    _traced_door_replay(tr1)
    _traced_door_replay(tr2)
    e1, e2 = tr1.export(), tr2.export()
    assert e1 == e2  # byte-identical across independent replays
    payload = json.loads(e1)
    assert validate_trace_events(payload) == []
    names = {ev["name"] for ev in payload["traceEvents"]}
    # the span taxonomy's core members all appear on real chaos
    assert {"submit", "queue", "admit", "serve", "complete",
            "engine_tick", "door_tick"} <= names
    assert names & {"launch", "fail", "watchdog"}  # chaos left a mark
    # track labels follow the door's registration names
    labels = {ev["args"]["name"] for ev in payload["traceEvents"]
              if ev["ph"] == "M"}
    assert {"door", "fast", "slow"} <= labels


def test_tracer_scale_maps_engine_ticks_to_door_clock():
    tr = Tracer()
    eng = object()
    tr.attach(eng, "e")
    tr.set_scale(eng, 3)
    tr.tick_instant(eng, "engine_tick", 5)
    tr.tick_span(eng, "serve", 2, 4, 1000)
    inst, span = tr.events
    assert inst["ts"] == 15  # engine tick 5 fired at door tick 15
    assert (span["ts"], span["dur"]) == (6, 12)


def test_tracer_wall_opt_in_is_outside_byte_identity():
    """wall=True may add wall-clock args; the default export of two
    identical runs stays byte-identical (the contract the bench gate
    pins on the full chaos stack)."""
    runs = []
    for _ in range(2):
        tr = Tracer()
        eng = _OneTickEngine(1, tracer=tr, registry=MetricsRegistry())
        eng.run([_Req(uid=0)])
        runs.append(tr.export())
    assert runs[0] == runs[1]
    assert "wall_us" not in runs[0]


# -------------------------------------------------------- trace validation


def _ev(name, ph="i", pid=1, tid=0, ts=0, **kw):
    e = {"name": name, "ph": ph, "pid": pid, "tid": tid, "ts": ts}
    if ph == "i":
        e["s"] = "t"
    e.update(kw)
    return e


def test_validate_catches_orphaned_terminal():
    probs = validate_trace_events([
        _ev("complete", tid=REQUEST_TID_BASE + 5, ts=4)])
    assert any("orphaned" in p for p in probs)


def test_validate_catches_double_terminal():
    tid = REQUEST_TID_BASE
    probs = validate_trace_events([
        _ev("submit", tid=tid), _ev("complete", tid=tid, ts=2),
        _ev("evict", tid=tid, ts=3)])
    assert any("second terminal" in p for p in probs)


def test_validate_catches_nonmonotone_ts():
    probs = validate_trace_events([
        _ev("engine_tick", ts=5), _ev("engine_tick", ts=3)])
    assert any("monotone" in p for p in probs)


def test_validate_catches_unknown_name_and_malformed():
    probs = validate_trace_events([
        _ev("made_up_event"),
        {"name": "serve", "ph": "X", "pid": 1, "tid": 0, "ts": 0,
         "dur": -2},
        {"name": "admit", "ph": "i", "pid": "one", "tid": 0, "ts": 0}])
    assert any("taxonomy" in p for p in probs)
    assert any("dur" in p for p in probs)
    assert any("pid" in p for p in probs)


def test_validate_accepts_clean_payload():
    tid = REQUEST_TID_BASE + 1
    assert validate_trace_events({"traceEvents": [
        _ev("submit", tid=tid, ts=0),
        _ev("queue", ph="X", tid=tid, ts=0, dur=2),
        _ev("admit", tid=tid, ts=2),
        _ev("serve", ph="X", tid=tid, ts=2, dur=3),
        _ev("complete", tid=tid, ts=5)]}) == []


# ------------------------------------------------------ undrained reporting


def test_undrained_warning_names_uids_and_ledgers():
    """drive(on_undrained='warn') reports per-ledger undrained counts
    *and* the offending uids — a count without uids is a deadlock an
    operator cannot chase."""
    inj = FaultInjector(FaultPlan(stuck_uids=(7,)),
                        registry=MetricsRegistry())
    eng = _StreamEngine(1, faults=inj, registry=MetricsRegistry())
    eng.submit(_StreamReq(uid=7, length=1))
    eng.submit(_StreamReq(uid=9, length=1))
    with pytest.warns(RuntimeWarning, match="undrained") as rec:
        eng.run(max_ticks=5)
    msg = next(str(w.message) for w in rec if "undrained" in str(w.message))
    assert "1 queued" in msg and "1 slots occupied" in msg
    assert "queued=1 uids=[9]" in msg
    assert "occupied=1 uids=[7]" in msg


def test_undrained_warning_reports_per_engine_behind_door():
    a = _OneTickEngine(1, registry=MetricsRegistry())
    b = _StreamEngine(1, faults=FaultInjector(FaultPlan(stuck_uids=(3,)),
                                              registry=MetricsRegistry()),
                      registry=MetricsRegistry())
    door = FrontDoor(fast=a, slow=b, registry=MetricsRegistry())
    door.submit(_StreamReq(uid=3, length=1))
    with pytest.warns(RuntimeWarning, match=r"slow: .*occupied=1 uids=\[3\]"):
        door.run(max_ticks=5)
