#!/usr/bin/env bash
# Tier-1 verification: the full test suite plus a short benchmark smoke of
# the P²M kernel stack, so kernel regressions are caught without a TPU.
# Usage: scripts/ci.sh  (or `make verify`)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# Two tests have been red since the seed import (unrelated to the P²M
# kernel stack; tracked in ROADMAP open items) — deselected here so the
# gate stays actionable for *regressions*.  The plain tier-1 command
# (`make test`) still runs them.
python -m pytest -x -q \
  --deselect tests/test_distributed.py::test_grad_compression_under_sharding \
  --deselect tests/test_system.py::test_lm_training_loss_decreases

echo "== benchmark smoke (p2m kernels, reduced shapes) =="
python benchmarks/run.py --smoke

echo "verify: OK"
