#!/usr/bin/env bash
# Tier-1 verification: the full test suite, a multi-device lane, and a
# short benchmark smoke of the P²M kernel stack with a regression gate —
# so kernel and scaling regressions are caught without a TPU.
# Usage: scripts/ci.sh  (or `make verify`)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# (includes the video-subsystem tests — test_video_detect_track.py and
# test_video_stream.py — the fault-injection / deadline / containment
# tests in test_faults.py, and the observability tests in test_obs.py —
# tracer determinism / disabled-tracer freedom / registry-vs-legacy
# parity / compile-cache counters, DESIGN.md §13 — all in the default
# lane)
python -m pytest -x -q

echo "== multi-device lane (8 virtual CPU devices, in-process) =="
# The sharding-machinery tests marked needs8 only run here — including
# the sharded-VisionEngine parity tests in test_vision_serving.py (one
# engine tick, sharded microbatch == single device; DESIGN.md §8), the
# sharded-StreamEngine multi-tick parity tests in test_video_stream.py
# (DESIGN.md §9), the sharded fault-containment test in test_faults.py
# (launch quarantine under a data mesh, DESIGN.md §10), and the
# replica-pool-over-submeshes parity test in test_serve_pool.py (a
# 2-replica pool of mesh-sharded vision engines, DESIGN.md §11), and the
# sharded stateful-LM-session tests in test_sessions.py (slot-resident
# WKV state over a data mesh, bitwise vs single device; DESIGN.md
# §12.4); the rest of each file re-runs under the virtual-device
# topology as a bonus.
# (test_distributed.py spawns its own 8-device subprocesses from tier-1.)
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
  python -m pytest -x -q tests/test_sharding.py tests/test_vision_serving.py \
    tests/test_video_stream.py tests/test_faults.py tests/test_serve_pool.py \
    tests/test_sessions.py

echo "== benchmark smoke (p2m kernels + serving + video + chaos + saturation + wkv + sessions, reduced shapes) =="
# emits the p2m_video_stream_* rows the gate's skip-rate and
# measured-bandwidth floors read, the p2m_serve_chaos_* rows its
# completion-rate floors read (DESIGN.md §10), the
# p2m_serve_saturation_* rows its pool-scaling and lockstep-equivalence
# floors read (DESIGN.md §11), and the p2m_rwkv_wkv_* / p2m_lm_session_*
# rows its WKV-parity and session-determinism floors read (DESIGN.md
# §12).  The chaos bench also writes the gated Perfetto trace artifact
# benchmarks/results/trace_smoke.json and stamps the smoke row with the
# trace_deterministic / trace_valid bits the gate holds at 1.0
# (DESIGN.md §13).
python benchmarks/run.py --smoke

echo "== bench regression gate (vs BENCH_p2m_conv.json baseline) =="
# also re-validates the trace artifact's span schema (well-formed
# events, no orphaned request tracks, monotone tick stamps)
python scripts/bench_gate.py

echo "== accelerator lane (opt-in: active when jax reports tpu/gpu) =="
# On a real accelerator the kernel tests re-run with
# REPRO_P2M_NO_INTERPRET=1 — the pipelined/gated kernel tests read it
# and drop their interpret=True pins, compiling the kernels for real —
# and the bench smoke re-runs compiled, emitting same-backend rows next
# to the committed CPU ones (bench_gate only compares same-backend
# pairs, so the lanes never gate against each other).  On CPU-only
# machines this lane is a no-op by design.
BACKEND="$(python -c 'import jax; print(jax.default_backend())')"
if [ "$BACKEND" = "tpu" ] || [ "$BACKEND" = "gpu" ]; then
  echo "accelerator backend: $BACKEND — running non-interpret kernel lane"
  REPRO_P2M_NO_INTERPRET=1 python -m pytest -x -q \
    tests/test_p2m_kernel.py tests/test_p2m_conv_fused.py \
    tests/test_p2m_conv_pipelined.py
  python benchmarks/run.py --smoke
  python scripts/bench_gate.py
else
  echo "accelerator lane: skipped (backend=$BACKEND; set up a TPU/GPU"
  echo "  runtime to exercise the compiled kernel path)"
fi

echo "verify: OK"
