#!/usr/bin/env bash
# Tier-1 verification: the full test suite, a multi-device lane, and a
# short benchmark smoke of the P²M kernel stack with a regression gate —
# so kernel and scaling regressions are caught without a TPU.
# Usage: scripts/ci.sh  (or `make verify`)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== multi-device lane (8 virtual CPU devices, in-process) =="
# The sharding-machinery tests marked needs8 only run here — including
# the sharded-VisionEngine parity tests in test_vision_serving.py (one
# engine tick, sharded microbatch == single device; DESIGN.md §8); the
# rest of each file re-runs under the virtual-device topology as a bonus.
# (test_distributed.py spawns its own 8-device subprocesses from tier-1.)
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
  python -m pytest -x -q tests/test_sharding.py tests/test_vision_serving.py

echo "== benchmark smoke (p2m kernels, reduced shapes) =="
python benchmarks/run.py --smoke

echo "== bench regression gate (vs BENCH_p2m_conv.json baseline) =="
python scripts/bench_gate.py

echo "verify: OK"
