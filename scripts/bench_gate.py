#!/usr/bin/env python
"""Bench-smoke regression gate (scripts/ci.sh).

Compares the smoke-run rows (`benchmarks/results/BENCH_p2m_conv.smoke.json`,
written by `benchmarks/run.py --smoke`) against the committed
full-geometry baseline `BENCH_p2m_conv.json`.

Absolute wall-clock is machine-dependent, so the gate holds the
*relative* metrics the kernel work is about — fused-vs-patches and
closed-form-bwd-vs-jax.vjp speedups — to a generous fraction of the
committed baseline's value for the corresponding full-geometry case.  A
real regression (fused path silently falling back to patch
materialization, the custom VJP re-differentiating the forward) craters
these ratios by far more than CI timing noise moves them.

Backend provenance: every row carries ``backend``/``platform``/
``interpret`` fields (benchmarks/common.py).  Baseline-relative
comparisons only run between SAME-backend, same-interpret row pairs —
an interpret-mode CPU number against a TPU number (or vice versa) is
not a regression signal, so mismatched pairs are skipped with a
warning.  Absolute floors (counts and exact ratios) are
machine-independent and always gate.  Old baseline rows without per-row
fields inherit the file-level ``meta.backend``.

Trace artifact: the chaos bench also writes the gated Perfetto trace
``benchmarks/results/trace_smoke.json`` (DESIGN.md §13); this gate
re-validates its span schema with `repro.obs.validate_trace_events`
(well-formed spans, no orphaned request tracks, monotone tick stamps)
and holds the smoke row's ``trace_deterministic`` / ``trace_valid``
bits at 1.0 — the byte-identity contract either holds or the trace
subsystem regressed; there is no noise band.

Skip with REPRO_BENCH_GATE=0 (e.g. on a loaded laptop).
"""
from __future__ import annotations

import json
import logging
import math
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import structured, validate_trace_events  # noqa: E402

BASELINE = ROOT / "BENCH_p2m_conv.json"
SMOKE = ROOT / "benchmarks" / "results" / "BENCH_p2m_conv.smoke.json"
TRACE = ROOT / "benchmarks" / "results" / "trace_smoke.json"

log = logging.getLogger("bench_gate")

# smoke row -> list of (baseline row, metric, floor): the smoke metric
# must reach `floor × baseline[baseline row][metric]` — or, when the
# baseline row is None, the absolute value `floor` (for
# machine-independent ratios with no committed-baseline counterpart).
# Floors are wide on purpose — observed smoke values sit 2.5×–16× above
# them across runs, while the regressions they guard against (silent
# fallback to the patch path / re-differentiated backward / a sharded
# serving path that reshards or host-syncs per tick / a delta gate that
# stopped gating) crater the metric well below them.  The bwd gate is
# widest: the jax.vjp comparator's wall-clock swings heavily with CI
# load.
GATES: dict[str, list[tuple[str | None, str, float]]] = {
    "p2m_conv_fused_smoke_b1":
        [("p2m_conv_fused_paper_b1", "speedup_vs_patches", 0.4)],
    "p2m_conv_fused_smoke_overlap":
        [("p2m_conv_fused_overlap_s2_b1", "speedup_vs_patches", 0.3)],
    "p2m_bwd_closed_smoke":
        [("p2m_bwd_closed_paper_1img", "speedup_vs_jaxvjp", 0.15)],
    # Sharded vision serving (benchmarks/bench_train_serve.py): per-tick
    # wall of the data-mesh-sharded engine vs single-device.  Absolute
    # floor, held very low for CI noise — and skipped entirely when the
    # smoke row ran on a 1-device mesh (see RATIO_METRICS_NEED_DEVICES:
    # sharded == single there, the ratio is pure timing noise).
    "p2m_vision_serve_sharded_smoke":
        [(None, "speedup_vs_single", 0.2)],
    # Streaming-video detection (video/engine.py, DESIGN.md §9): both
    # floors count frames and bits, not wall-clock, so they are exact
    # machine-independent guards.  The smoke stream's hold=2 redundancy
    # puts stem-skip at ~0.5 and the measured reduction at ~2.0x; a
    # delta gate that silently stopped skipping (or a ledger that stopped
    # metering) lands at 0.0 / 1.0.
    "p2m_video_stream_smoke":
        [(None, "stem_skip_rate", 0.1),
         (None, "measured_reduction_vs_dense", 1.2)],
    # Chaos replay (benchmarks/bench_serve_chaos.py, DESIGN.md §10):
    # fault decisions are pure functions of (seed, tick, uid) and every
    # gated metric counts requests and ticks, not wall-clock, so these
    # floors are exact machine-independent guards.  With the fault layer
    # attached but injecting nothing, every request completes — a gate
    # below 1.0 only to absorb float division.  Under the smoke plan the
    # measured replay completes 0.77 of all traffic and 1.00 of the
    # non-faulted traffic; the floors sit under those deterministic
    # values, and a containment regression (a launch fault poisoning the
    # cohort, a stuck slot deadlocking the table, a NaN escaping the
    # guard) drops them far below.
    "p2m_serve_chaos_off_smoke":
        [(None, "completion_rate", 0.999)],
    # trace_deterministic / trace_valid are exact 0-or-1 bits from the
    # traced double replay (DESIGN.md §13.3): two fresh tracers over the
    # same seeded chaos must export byte-identical Perfetto JSON, and
    # the export must pass schema validation.  1.0 floors — the
    # determinism contract either holds or the trace subsystem
    # regressed; there is no noise band.
    "p2m_serve_chaos_smoke":
        [(None, "completion_rate", 0.7),
         (None, "nonfault_completion_rate", 0.95),
         (None, "trace_deterministic", 1.0),
         (None, "trace_valid", 1.0)],
    # Replica-pool saturation (benchmarks/bench_serve_saturation.py,
    # DESIGN.md §11): synthetic cost-model engines — every metric counts
    # requests and ticks, never wall-clock, so the floors are exact
    # machine-independent guards.  The measured replay puts the 2-replica
    # door at 1.76x the 1-replica saturation throughput and the
    # 4-replica door at 3.30x; the floors sit under those deterministic
    # values, and a dispatch regression (a pool that stopped balancing,
    # an event loop that starves a cadence) drops them far below.  The
    # equivalence row is a hard bit-identity check: with equal
    # tick_costs, the event-driven door over 1-replica pools must replay
    # the lockstep reference door's completion ledgers exactly.
    "p2m_serve_saturation_pool2_smoke":
        [(None, "speedup_vs_pool1", 1.6)],
    "p2m_serve_saturation_pool4_smoke":
        [(None, "speedup_vs_pool1", 2.5)],
    "p2m_serve_saturation_equiv_smoke":
        [(None, "lockstep_equivalent", 1.0)],
    # Chunked-RWKV6 WKV kernel (benchmarks/bench_rwkv_wkv.py, DESIGN.md
    # §12): parity metrics are exact 0-or-1 fp32-tolerance checks of the
    # XLA twin and the Pallas kernel (interpret mode on CPU) against the
    # naive per-token scan — forward output, final state, and all six
    # closed-form gradients.  1.0 floors: parity either holds or the
    # kernel math regressed; there is no noise band.
    "p2m_rwkv_wkv_smoke":
        [(None, "xla_fwd_parity", 1.0),
         (None, "xla_state_parity", 1.0),
         (None, "xla_grad_parity", 1.0),
         (None, "pallas_fwd_parity", 1.0),
         (None, "pallas_state_parity", 1.0),
         (None, "pallas_grad_parity", 1.0)],
    # Stateful streaming-LM sessions through the front door (DESIGN.md
    # §12.4): every gated metric counts ticks and tokens, never
    # wall-clock, so the floors are exact machine-independent guards.
    # The greedy replay is deterministic — two fresh replays must agree
    # bit-for-bit (1.0), everything completes (0.999 absorbs float
    # division only), the chunked-WKV prefill engine finishes the same
    # traffic in fewer ticks than the token-by-token engine (measured
    # 1.91x; the floor sits under that deterministic value), and the
    # chunked path emits token-identical outputs to the tokenwise path.
    "p2m_lm_session_smoke":
        [(None, "completion_rate", 0.999),
         (None, "deterministic_replay", 1.0),
         (None, "tokenwise_parity", 1.0),
         (None, "prefill_tick_speedup", 1.2)],
    # Pipelined double-buffered conv kernel (DESIGN.md §3.5): exact
    # 0-or-1 bitwise checks of the explicit DMA-ring path against the
    # automatic grid pipeline — forward output and both closed-form
    # gradients.  1.0 floors: the ring either reproduces the grid path
    # bit-for-bit or its slot sequencing is wrong; there is no noise
    # band.
    "p2m_conv_pipelined_smoke":
        [(None, "fwd_parity", 1.0),
         (None, "dimg_parity", 1.0),
         (None, "dw_parity", 1.0)],
    # Fused delta-gated stem (DESIGN.md §3.6): the in-kernel
    # mask-and-copy path against the compute-all where-select reference
    # on the hold=2 smoke stream.  Parity is exact bit-identity of every
    # frame's boxes and scores (1.0 floor).  skip_vs_hold is the
    # stem-FLOPs-skipped ratio divided by the stream's hold fraction —
    # both frame counts, machine-independent; ≥ 1.0 means the kernel
    # skipped at least every frame the gate held (the ISSUE acceptance
    # bound).
    "p2m_gated_stem_smoke":
        [(None, "gated_stem_parity", 1.0),
         (None, "skip_vs_hold", 1.0)],
}

# Metrics that compare a sharded path against single-device: meaningless
# on a 1-device mesh (the row's `devices` field says), so the gate is
# skipped — with a log line — rather than held against noise.
RATIO_METRICS_NEED_DEVICES = {"speedup_vs_single"}


def _load(path: Path) -> tuple[dict, dict[str, dict]]:
    payload = json.loads(path.read_text())
    return payload.get("meta", {}), {r["name"]: r for r in payload["rows"]}


def _provenance(row: dict, meta: dict) -> tuple[str, bool]:
    """(backend, interpret) for a row; rows predating per-row provenance
    inherit the file-level meta.backend and are assumed compiled."""
    return (row.get("backend", meta.get("backend", "unknown")),
            bool(row.get("interpret", False)))


def _check_trace(failures: list[str]) -> None:
    """Re-validate the committed chaos-trace artifact's span schema
    (DESIGN.md §13.1): well-formed events, no orphaned request tracks,
    monotone tick stamps.  The bench already validated its in-memory
    export; this guards the *artifact* — a truncated or hand-edited
    file fails here even when the smoke row's bits read 1.0."""
    if not TRACE.exists():
        failures.append(f"missing trace artifact {TRACE} "
                        "(run `python benchmarks/run.py --smoke` first)")
        return
    try:
        payload = json.loads(TRACE.read_text())
    except json.JSONDecodeError as exc:
        failures.append(f"trace artifact {TRACE.name}: invalid JSON ({exc})")
        return
    problems = validate_trace_events(payload)
    for p in problems[:10]:
        failures.append(f"trace artifact {TRACE.name}: {p}")
    if not problems:
        n = len(payload.get("traceEvents", []))
        print(f"bench_gate: trace artifact {TRACE.name} schema OK "
              f"({n} events)")


def main() -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(name)s: %(message)s")
    if os.environ.get("REPRO_BENCH_GATE", "1") == "0":
        print("bench_gate: skipped (REPRO_BENCH_GATE=0)")
        return 0
    if not SMOKE.exists():
        print(f"bench_gate: FAIL — no smoke results at {SMOKE} "
              "(run `python benchmarks/run.py --smoke` first)")
        return 1
    smoke_meta, smoke = _load(SMOKE)
    base_meta, base = _load(BASELINE)

    failures: list[str] = []
    _check_trace(failures)
    for name, row in smoke.items():
        t = row["us_per_call"]
        if not (math.isfinite(t) and t > 0):
            failures.append(f"{name}: non-finite timing {t!r}")

    for smoke_name, specs in GATES.items():
        if smoke_name not in smoke:
            failures.append(f"missing smoke row {smoke_name}")
            continue
        row = smoke[smoke_name]
        for base_name, metric, fraction in specs:
            if (metric in RATIO_METRICS_NEED_DEVICES
                    and row.get("devices") == 1):
                structured(log, "bench_gate_skip", level=logging.WARNING,
                           row=smoke_name, metric=metric,
                           reason="1-device mesh: the sharded-vs-single "
                                  "ratio is timing noise, not a sharding "
                                  "signal")
                continue
            if base_name is None:
                floor, source = fraction, "absolute floor"
            elif base_name not in base or metric not in base[base_name]:
                failures.append(f"baseline {base_name}.{metric} missing "
                                "(regenerate BENCH_p2m_conv.json)")
                continue
            else:
                # Baseline-relative comparisons are only meaningful
                # between same-backend, same-interpret row pairs: refuse
                # (skip + warn) cross-backend pairs instead of comparing
                # an interpret-mode CPU number against anything else.
                s_prov = _provenance(row, smoke_meta)
                b_prov = _provenance(base[base_name], base_meta)
                if s_prov != b_prov:
                    structured(log, "bench_gate_skip",
                               level=logging.WARNING,
                               row=smoke_name, metric=metric,
                               smoke_backend=s_prov[0],
                               smoke_interpret=s_prov[1],
                               baseline_row=base_name,
                               baseline_backend=b_prov[0],
                               baseline_interpret=b_prov[1],
                               reason="cross-backend pair is not a "
                                      "regression signal")
                    continue
                floor = fraction * base[base_name][metric]
                source = (f"= {fraction} x baseline "
                          f"{base[base_name][metric]:.2f} from {base_name}")
            got = row.get(metric)
            if got is None:
                failures.append(f"{smoke_name}: metric {metric} missing")
            elif got < floor:
                failures.append(
                    f"{smoke_name}: {metric}={got:.2f} below gate "
                    f"{floor:.2f} ({source})")
            else:
                print(f"bench_gate: {smoke_name} {metric}={got:.2f} "
                      f">= {floor:.2f}  OK")

    if failures:
        print("bench_gate: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"bench_gate: OK ({len(smoke)} smoke rows checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
