# Convenience targets; `make verify` is the pre-merge gate (tier-1 tests
# + a ~10 s benchmark smoke — no TPU required, see scripts/ci.sh).

.PHONY: verify test bench bench-smoke tune-blocks

verify:
	bash scripts/ci.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench:
	python benchmarks/run.py

bench-smoke:
	python benchmarks/run.py --smoke

tune-blocks:
	python benchmarks/hillclimb.py --p2m-blocks
