"""Paper-table benchmarks: Eq. 2 bandwidth, Table 2 model costs,
Tables 4-5 / Fig. 8 EDP — analytic recomputation + timing of the
evaluators themselves."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.bandwidth import FirstLayerGeom, bandwidth_reduction
from repro.core.energy import (
    BASELINE_C_ENERGY,
    BASELINE_DELAY,
    BASELINE_NC_ENERGY,
    N_PIX_BASELINE_C,
    N_PIX_BASELINE_NC,
    N_PIX_P2M,
    P2M_DELAY,
    P2M_ENERGY,
    evaluate_model,
    total_macs,
)
from repro.models.mobilenetv2 import MNV2Config, layer_census, peak_activation_bytes


def run() -> None:
    # ---- Eq. 2-3 (bandwidth) ----
    geom = FirstLayerGeom()
    emit("eq2_bandwidth_reduction", 0.0,
         f"BR={bandwidth_reduction(geom):.2f}x (paper ~21x; Eq.2 w/ Table 1)")
    for bits in (4, 6, 8, 16, 32):
        g = FirstLayerGeom(out_bits=bits)
        emit(f"eq2_bandwidth_Nb{bits}", 0.0, f"BR={bandwidth_reduction(g):.2f}x")

    # ---- Table 2 (MAdds / peak memory) ----
    paper = {("baseline", 560): (1.93, 7.53), ("p2m", 560): (0.27, 0.30),
             ("baseline", 225): (0.31, 1.2), ("p2m", 225): (0.05, 0.049),
             ("baseline", 115): (0.09, 0.311), ("p2m", 115): (0.01, 0.013)}
    for (variant, res), (pm, pp) in paper.items():
        cfg = MNV2Config(variant=variant, image_size=res)
        madds = total_macs(layer_census(cfg)) / 1e9
        peak = peak_activation_bytes(cfg, fused_blocks=(variant == "p2m")) / 1e6
        emit(f"table2_{variant}_{res}", 0.0,
             f"MAdds={madds:.3f}G(paper {pm}) peak={peak:.3f}MB(paper {pp})")

    base = MNV2Config(variant="baseline", image_size=560)
    p2m = MNV2Config(variant="p2m", image_size=560)
    emit("table2_ratios", 0.0,
         f"madds_red={total_macs(layer_census(base))/total_macs(layer_census(p2m)):.2f}x"
         f"(paper 7.15x) peak_red="
         f"{peak_activation_bytes(base, fused_blocks=False)/peak_activation_bytes(p2m, fused_blocks=True):.1f}x"
         f"(paper 25.1x)")

    # ---- Tables 4-5 / Fig. 8 (EDP) ----
    rp = evaluate_model(layer_census(p2m), N_PIX_P2M, P2M_ENERGY, P2M_DELAY)
    rb = evaluate_model(layer_census(base), N_PIX_BASELINE_C,
                        BASELINE_C_ENERGY, BASELINE_DELAY)
    # NC baseline: standard stride-2 first layer, rest identical (paper's
    # 560→279 scenario) — approximate with the same census, NC constants.
    rn = evaluate_model(layer_census(base), N_PIX_BASELINE_NC,
                        BASELINE_NC_ENERGY, BASELINE_DELAY)
    emit("fig8_energy_uj", 0.0,
         f"p2m={rp.energy_uj:.0f} baseC={rb.energy_uj:.0f} baseNC={rn.energy_uj:.0f} "
         f"ratio={rb.energy_uj/rp.energy_uj:.2f}x (paper <=7.81x)")
    emit("fig8_delay_ms", 0.0,
         f"p2m={rp.delay_sequential_ms:.1f} base={rb.delay_sequential_ms:.1f} "
         f"ratio={rb.delay_sequential_ms/rp.delay_sequential_ms:.2f}x (paper <=2.15x)")
    emit("fig8_edp", 0.0,
         f"seq={rb.edp_sequential/rp.edp_sequential:.2f}x (paper 16.76x) "
         f"cons={rb.edp_conservative/rp.edp_conservative:.2f}x (paper ~11x)")
