"""Wall-clock benchmarks (CPU, reduced configs): P²M-MobileNetV2 train
step (the paper's workload — the §Perf measured-iteration target),
batched vision serving throughput, smoke-LM train step, and decode
throughput."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_smoke_config
from repro.configs.p2m_vww import SERVE_MAX_BATCH
from repro.data import SyntheticVWW
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, init_mnv2
from repro.optim import constant, sgd
from repro.serving import VisionEngine, VisionRequest
from repro.train import TrainState, make_train_step
from repro.train.vision import make_vww_train_step


def run() -> None:
    # ---- paper workload: P²M MNv2 train step (80×80 synthetic VWW) ----
    for variant in ("p2m", "baseline"):
        cfg = MNV2Config(variant=variant, image_size=80, width=0.25,
                         head_channels=64)
        params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
        opt = sgd(constant(0.05), momentum=0.9)
        state = {"params": params, "bn": bn, "opt": opt.init(params),
                 "step": jnp.asarray(0, jnp.int32)}
        step = jax.jit(make_vww_train_step(cfg, opt))
        batch = SyntheticVWW(image_size=80, batch=16).batch_at(0)
        t = timeit(lambda s, b: step(s, b)[0], state, batch)
        emit(f"vww_train_step_{variant}_80px", t, "batch=16 CPU")

    # ---- batched vision serving (deploy-folded P²M stem) ----
    cfg = MNV2Config(variant="p2m", image_size=80, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    imgs = SyntheticVWW(image_size=80, batch=32).batch_at(0)["images"]
    engine = VisionEngine(params, bn, cfg, max_batch=SERVE_MAX_BATCH)
    engine.submit(VisionRequest(uid=-1, image=imgs[0]))
    engine.run()  # warmup: compile the microbatch forward
    t0 = time.perf_counter()
    for uid in range(32):
        engine.submit(VisionRequest(uid=uid, image=imgs[uid]))
    engine.run()
    dt = time.perf_counter() - t0
    emit("vision_serve_p2m_80px", dt / 32 * 1e6,
         f"microbatch={SERVE_MAX_BATCH}; {32 / dt:.0f} img/s CPU")

    # ---- LM train steps (smoke configs) ----
    for arch in ("llama3.2-1b", "qwen3-moe-30b-a3b", "rwkv6-3b",
                 "recurrentgemma-9b"):
        cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
        fam = get_family(cfg)
        params, _ = fam.init(jax.random.PRNGKey(0), cfg)
        opt = sgd(constant(1e-2))
        state = TrainState(params, opt.init(params))
        step = jax.jit(make_train_step(cfg, opt))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)
        batch = {"tokens": toks, "targets": toks}
        t = timeit(lambda s, b: step(s, b)[0], state, batch)
        emit(f"lm_train_step_{arch}_smoke", t, "b=8 s=64 CPU")

    # ---- decode throughput ----
    for arch in ("llama3.2-1b", "rwkv6-3b"):
        cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
        fam = get_family(cfg)
        params, _ = fam.init(jax.random.PRNGKey(0), cfg)
        state, _ = fam.init_decode_state(cfg, 8, 128)
        dec = jax.jit(lambda s, t, p: fam.decode(params, s, t, p, cfg))
        toks = jnp.ones((8, 1), jnp.int32)
        pos = jnp.zeros((8,), jnp.int32)
        t = timeit(lambda s: dec(s, toks, pos)[0], state)
        emit(f"decode_step_{arch}_smoke", t,
             f"batch=8; {8e6 / t:.0f} tok/s CPU")
