"""Wall-clock benchmarks (CPU, reduced configs): P²M-MobileNetV2 train
step (the paper's workload — the §Perf measured-iteration target),
batched vision serving throughput (single-device and data-mesh-sharded,
gated by scripts/bench_gate.py), smoke-LM train step, and decode
throughput."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_smoke_config
from repro.configs.p2m_vww import SERVE_MAX_BATCH
from repro.data import SyntheticVWW
from repro.launch.mesh import make_debug_mesh
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, head_out_channels, init_mnv2
from repro.optim import constant, sgd
from repro.serving import VisionEngine, VisionRequest
from repro.train import TrainState, make_train_step
from repro.train.vision import make_vww_train_step


def _reset_after_warmup(engine) -> None:
    """Drop the warmup traffic from the ledger — its wall-clock is
    compile time and would dominate the emitted means."""
    engine.completed.clear()
    for k, v in engine.stats.items():
        engine.stats[k] = type(v)()


def _vision_serve_case(engine: VisionEngine, imgs, n_req: int):
    """Drive one engine through a warmed-up burst; returns
    (µs per tick, ticks/sec, latency summary)."""
    engine.submit(VisionRequest(uid=-1, image=imgs[0]))
    engine.run()  # warmup: compile the microbatch forward
    _reset_after_warmup(engine)
    tick0 = engine.tick
    t0 = time.perf_counter()
    for uid in range(n_req):
        engine.submit(VisionRequest(uid=uid, image=imgs[uid % len(imgs)]))
    engine.run()
    dt = time.perf_counter() - t0
    ticks = max(engine.tick - tick0, 1)
    return dt / ticks * 1e6, ticks / dt, engine.latency_summary()


def run_vision_serve(smoke: bool = False) -> None:
    """Batched vision serving (deploy-folded P²M stem): single-device vs
    data-mesh-sharded microbatch (DESIGN.md §8).  Rows carry the p2m_
    prefix so the smoke run lands them in BENCH_p2m_conv.smoke.json for
    `scripts/bench_gate.py`, which holds the sharded-vs-single ratio —
    the guard against the sharded path silently degrading (per-tick
    resharding, host sync per slot, a broken plan).  On a 1-device mesh
    the ratio sits near 1.0; the gate floor is generous because CI
    wall-clock swings hard."""
    size = 40 if smoke else 80
    n_req = 16 if smoke else 32
    suffix = "smoke" if smoke else f"{size}px"
    cfg = MNV2Config(variant="p2m", image_size=size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    imgs = SyntheticVWW(image_size=size, batch=n_req).batch_at(0)["images"]

    single = VisionEngine(params, bn, cfg, max_batch=SERVE_MAX_BATCH)
    us_single, tps_single, s1 = _vision_serve_case(single, imgs, n_req)
    emit(f"p2m_vision_serve_single_{suffix}", us_single,
         f"microbatch={SERVE_MAX_BATCH}; {tps_single:.0f} ticks/s; "
         f"mean_launch={s1['mean_launch_us'] / 1e3:.1f}ms",
         ticks_per_sec=tps_single,
         mean_queue_ticks=s1["mean_queue_ticks"],
         mean_launch_us=s1["mean_launch_us"])

    mesh = make_debug_mesh()
    sharded = VisionEngine(params, bn, cfg, max_batch=SERVE_MAX_BATCH,
                           mesh=mesh)
    us_sh, tps_sh, s2 = _vision_serve_case(sharded, imgs, n_req)
    n_dev = int(mesh.devices.size)
    emit(f"p2m_vision_serve_sharded_{suffix}", us_sh,
         f"{n_dev}-device data mesh; {tps_sh:.0f} ticks/s; "
         f"{us_single / us_sh:.2f}x vs single-device",
         ticks_per_sec=tps_sh, devices=n_dev,
         speedup_vs_single=us_single / us_sh,
         mean_queue_ticks=s2["mean_queue_ticks"],
         mean_launch_us=s2["mean_launch_us"])


def run_video_stream(smoke: bool = False) -> None:
    """Streaming-video detection (video/engine.py, DESIGN.md §9): the
    multi-tick StreamEngine over delta-gated synthetic streams.  Rows
    carry the p2m_ prefix so the smoke run lands them in the smoke JSON
    for `scripts/bench_gate.py`, which holds two measured floors: the
    stem-skip rate (> 0: the gate actually gates) and the measured
    bits/frame reduction vs dense readout (> 1: event readout transmits
    less than re-sending every activation map).  Both are
    machine-independent — they count frames and bits, not wall-clock."""
    from repro.video import (DetectConfig, StreamEngine, StreamRequest,
                             SyntheticVideo, init_detect_head)

    size = 40 if smoke else 80
    n_streams = 4 if smoke else 8
    n_frames = 8 if smoke else 16
    suffix = "smoke" if smoke else f"{size}px"
    cfg = MNV2Config(variant="p2m", image_size=size, width=0.25,
                     head_channels=64)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    det = init_detect_head(
        jax.random.PRNGKey(1),
        head_out_channels(cfg), DetectConfig())
    engine = StreamEngine(params, bn, cfg, det, max_streams=2)

    reqs = lambda: [
        StreamRequest(uid=uid, frames=SyntheticVideo(
            image_size=size, n_frames=n_frames, seed=uid).frames())
        for uid in range(n_streams)]
    engine.run([StreamRequest(uid=-1, frames=SyntheticVideo(
        image_size=size, n_frames=1).frames())])  # warmup: compile launch
    _reset_after_warmup(engine)
    tick0 = engine.tick
    t0 = time.perf_counter()
    done = engine.run(reqs())
    dt = time.perf_counter() - t0
    ticks = max(engine.tick - tick0, 1)
    s = engine.stream_summary()
    frame_lat_us = (sum(r.frame_latency_us for r in done) / len(done)
                    if done else 0.0)
    emit(f"p2m_video_stream_{suffix}", dt / ticks * 1e6,
         f"{n_streams} streams x {n_frames} frames, 2 slots; "
         f"{ticks / dt:.0f} ticks/s; stem-skip {s['stem_skip_rate']:.2f}; "
         f"{s['bits_per_frame']:.0f} bits/frame vs "
         f"{s['dense_bits_per_frame']} dense "
         f"({s['measured_reduction_vs_dense']:.2f}x measured)",
         ticks_per_sec=ticks / dt,
         frame_latency_us=frame_lat_us,
         stem_skip_rate=s["stem_skip_rate"],
         bits_per_frame=s["bits_per_frame"],
         dense_bits_per_frame=s["dense_bits_per_frame"],
         measured_reduction_vs_dense=s["measured_reduction_vs_dense"])


def run() -> None:
    # ---- paper workload: P²M MNv2 train step (80×80 synthetic VWW) ----
    for variant in ("p2m", "baseline"):
        cfg = MNV2Config(variant=variant, image_size=80, width=0.25,
                         head_channels=64)
        params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
        opt = sgd(constant(0.05), momentum=0.9)
        state = {"params": params, "bn": bn, "opt": opt.init(params),
                 "step": jnp.asarray(0, jnp.int32)}
        step = jax.jit(make_vww_train_step(cfg, opt))
        batch = SyntheticVWW(image_size=80, batch=16).batch_at(0)
        t = timeit(lambda s, b: step(s, b)[0], state, batch)
        emit(f"vww_train_step_{variant}_80px", t, "batch=16 CPU")

    # ---- batched vision serving (single-device + sharded microbatch) ----
    run_vision_serve(smoke=False)

    # ---- LM train steps (smoke configs) ----
    for arch in ("llama3.2-1b", "qwen3-moe-30b-a3b", "rwkv6-3b",
                 "recurrentgemma-9b"):
        cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
        fam = get_family(cfg)
        params, _ = fam.init(jax.random.PRNGKey(0), cfg)
        opt = sgd(constant(1e-2))
        state = TrainState(params, opt.init(params))
        step = jax.jit(make_train_step(cfg, opt))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)
        batch = {"tokens": toks, "targets": toks}
        t = timeit(lambda s, b: step(s, b)[0], state, batch)
        emit(f"lm_train_step_{arch}_smoke", t, "b=8 s=64 CPU")

    # ---- decode throughput ----
    for arch in ("llama3.2-1b", "rwkv6-3b"):
        cfg = get_smoke_config(arch).replace(dtype=jnp.float32)
        fam = get_family(cfg)
        params, _ = fam.init(jax.random.PRNGKey(0), cfg)
        state, _ = fam.init_decode_state(cfg, 8, 128)
        dec = jax.jit(lambda s, t, p: fam.decode(params, s, t, p, cfg))
        toks = jnp.ones((8, 1), jnp.int32)
        pos = jnp.zeros((8,), jnp.int32)
        t = timeit(lambda s: dec(s, toks, pos)[0], state)
        emit(f"decode_step_{arch}_smoke", t,
             f"batch=8; {8e6 / t:.0f} tok/s CPU")
