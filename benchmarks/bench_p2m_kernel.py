"""P²M kernel benchmark: elementwise oracle vs basis-decomposed XLA vs
Pallas (interpret) — the measurable side of the TPU adaptation
(DESIGN.md §2).  The jnp-basis/oracle speedup on CPU is the same
matmul-vs-elementwise restructuring that maps onto the MXU on TPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.adc import ADCConfig
from repro.core.pixel_model import default_pixel_model, prune_pixel_model
from repro.kernels.p2m_conv import p2m_matmul, p2m_matmul_jnp, p2m_matmul_ref

ADC = ADCConfig()

# (M, K, N): paper geometry per image = 112·112 patches × 75 × 8
CASES = [
    ("paper_1img", 112 * 112, 75, 8),
    ("paper_8img", 8 * 112 * 112, 75, 8),
    ("wide_64ch", 4096, 75, 64),
    ("big_patch", 4096, 147, 32),  # 7×7×3 kernel
]


def run() -> None:
    model = default_pixel_model()
    for name, m, k, n in CASES:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random((m, k)), jnp.float32)
        w = jnp.asarray(rng.uniform(-1, 1, (k, n)), jnp.float32)
        s = jnp.zeros((n,), jnp.float32)

        jnp_fn = jax.jit(lambda x, w, s: p2m_matmul_jnp(x, w, s, model, ADC, "quant"))
        t_basis = timeit(jnp_fn, x, w, s)
        emit(f"p2m_basis_{name}", t_basis,
             f"M={m} K={k} N={n} (dw*dx matmuls, XLA)")

        pruned = prune_pixel_model(model, 0.06)
        pr_fn = jax.jit(lambda x, w, s: p2m_matmul_jnp(x, w, s, pruned, ADC, "quant"))
        t_pr = timeit(pr_fn, x, w, s)
        emit(f"p2m_pruned4_{name}", t_pr,
             f"4-term basis (EXPERIMENTS.md SPerf A.2); {t_basis / t_pr:.2f}x vs 9-term")

        if m <= 16384:
            ref_fn = jax.jit(lambda x, w: p2m_matmul_ref(x, w, model, s, ADC,
                                                         quantize=True))
            t_ref = timeit(ref_fn, x, w, warmup=1, iters=3)
            emit(f"p2m_elementwise_{name}", t_ref,
                 f"oracle; basis_speedup={t_ref / t_basis:.1f}x")

        if m <= 16384:
            pl_fn = lambda x, w, s: p2m_matmul(x, w, s, model, ADC, "quant")
            t_pl = timeit(pl_fn, x, w, s, warmup=1, iters=3)
            emit(f"p2m_pallas_interpret_{name}", t_pl,
                 "kernel body in interpret mode (correctness path)")
