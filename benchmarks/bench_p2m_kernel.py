"""P²M kernel benchmark: elementwise oracle vs basis-decomposed XLA vs
fused implicit-im2col conv vs Pallas — the measurable side of the TPU
adaptation (DESIGN.md §2-§4).

Two families of rows:

* ``p2m_*`` — the patch-level inner product (unchanged baseline set; the
  Pallas path is jitted like the others, so it no longer re-traces per
  call).
* ``p2m_conv_*`` / ``p2m_bwd_*`` — the fused-conv story tracked across
  PRs in ``BENCH_p2m_conv.json``: fused (implicit im2col + basis premix)
  vs the patch-materializing path at paper geometry (B ∈ {1, 8},
  224×224×3, k=s=5) and an overlapping-stride case, plus the train-step
  backward microbench (closed-form premixed VJP vs re-differentiating the
  forward, which is what the old custom_vjp fallback paid).

Off-TPU the Pallas rows run the kernel body in interpret mode (Python
per grid step) — correctness-path timings, flagged ``interpret`` in the
JSON and only measured at smoke size; the XLA fused-vs-patch comparison
carries the perf signal there.
"""
from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, write_json
from repro.core.adc import ADCConfig
from repro.core.p2m_conv import extract_patches
from repro.core.pixel_model import default_pixel_model, prune_pixel_model
from repro.kernels.p2m_conv import (
    p2m_conv,
    p2m_conv_jnp,
    p2m_conv_pallas,
    p2m_matmul,
    p2m_matmul_jnp,
    p2m_matmul_ref,
)
from repro.kernels.p2m_conv.backward import epilogue_mask, p2m_backward_jnp
from repro.kernels.p2m_conv.ops import _coeff_tuple

ADC = ADCConfig()
BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_p2m_conv.json"
# Smoke-run rows land in a transient JSON so `scripts/bench_gate.py` can
# gate CI against the committed full-geometry baseline above.
BENCH_SMOKE_JSON = (Path(__file__).resolve().parent / "results"
                    / "BENCH_p2m_conv.smoke.json")

# (M, K, N): paper geometry per image = 112·112 patches × 75 × 8
CASES = [
    ("paper_1img", 112 * 112, 75, 8),
    ("paper_8img", 8 * 112 * 112, 75, 8),
    ("wide_64ch", 4096, 75, 64),
    ("big_patch", 4096, 147, 32),  # 7×7×3 kernel
]

# (name, B, H, W, C, k, s): ISSUE geometry for the fused-conv trajectory.
CONV_CASES = [
    ("paper_b1", 1, 224, 224, 3, 5, 5),
    ("paper_b8", 8, 224, 224, 3, 5, 5),
    ("overlap_s2_b1", 1, 224, 224, 3, 5, 2),
]
CONV_CASES_SMOKE = [
    ("smoke_b1", 1, 64, 64, 3, 5, 5),
    ("smoke_overlap", 1, 64, 64, 3, 5, 2),
]


def _conv_data(b, h, w_dim, c, k, n=8, seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.random((b, h, w_dim, c)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1, 1, (k * k * c, n)), jnp.float32)
    s = jnp.asarray(rng.uniform(-0.1, 0.1, (n,)), jnp.float32)
    return imgs, w, s


def _run_matmul_cases(model, *, smoke: bool) -> None:
    iters = 2 if smoke else 5
    cases = [("smoke", 2048, 75, 8)] if smoke else CASES
    for name, m, k, n in cases:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random((m, k)), jnp.float32)
        w = jnp.asarray(rng.uniform(-1, 1, (k, n)), jnp.float32)
        s = jnp.zeros((n,), jnp.float32)

        jnp_fn = jax.jit(lambda x, w, s: p2m_matmul_jnp(x, w, s, model, ADC, "quant"))
        t_basis = timeit(jnp_fn, x, w, s, iters=iters)
        emit(f"p2m_basis_{name}", t_basis,
             f"M={m} K={k} N={n} (dw*dx matmuls, XLA)")

        pruned = prune_pixel_model(model, 0.06)
        pr_fn = jax.jit(lambda x, w, s: p2m_matmul_jnp(x, w, s, pruned, ADC, "quant"))
        t_pr = timeit(pr_fn, x, w, s, iters=iters)
        emit(f"p2m_pruned4_{name}", t_pr,
             f"4-term basis (EXPERIMENTS.md §Perf A.2); {t_basis / t_pr:.2f}x vs 9-term")

        if m <= 16384 and not smoke:
            ref_fn = jax.jit(lambda x, w: p2m_matmul_ref(x, w, model, s, ADC,
                                                         quantize=True))
            t_ref = timeit(ref_fn, x, w, warmup=1, iters=3)
            emit(f"p2m_elementwise_{name}", t_ref,
                 f"oracle; basis_speedup={t_ref / t_basis:.1f}x")

        if m <= 16384:
            # Jitted like every other path — no per-call re-trace.
            pl_fn = jax.jit(
                lambda x, w, s: p2m_matmul(x, w, s, model, ADC, "quant"))
            t_pl = timeit(pl_fn, x, w, s, warmup=1, iters=min(iters, 3))
            tag = ("TPU kernel" if jax.default_backend() == "tpu"
                   else "kernel body in interpret mode (correctness path)")
            emit(f"p2m_pallas_{name}", t_pl, tag,
                 interpret=jax.default_backend() != "tpu")


def _run_conv_cases(model, *, smoke: bool) -> None:
    """Fused implicit-im2col vs patch-materializing conv, paper geometry."""
    coeffs = _coeff_tuple(model)
    on_tpu = jax.default_backend() == "tpu"
    iters = 2 if smoke else 5
    cases = CONV_CASES_SMOKE if smoke else CONV_CASES
    for name, b, h, w_dim, c, k, s in cases:
        imgs, w, sh = _conv_data(b, h, w_dim, c, k)
        ho = (h - k) // s + 1
        wo = (w_dim - k) // s + 1
        shape_info = dict(B=b, H=h, W=w_dim, C=c, k=k, s=s,
                          M=b * ho * wo, K=k * k * c, N=int(w.shape[1]))

        def patch_fn(imgs, w, sh):
            patches = extract_patches(imgs, k, s)
            xf = patches.reshape(-1, k * k * c)
            return p2m_matmul_jnp(xf, w, sh, model, ADC, "quant")

        t_patch = timeit(jax.jit(patch_fn), imgs, w, sh, iters=iters)
        emit(f"p2m_conv_patches_{name}", t_patch,
             f"extract_patches + basis matmul (HBM patch tensor)",
             **shape_info)

        fused_fn = jax.jit(lambda imgs, w, sh: p2m_conv_jnp(
            imgs, w, sh, model, ADC, "quant", k, s))
        t_fused = timeit(fused_fn, imgs, w, sh, iters=iters)
        emit(f"p2m_conv_fused_{name}", t_fused,
             f"implicit im2col + basis premix (XLA); "
             f"{t_patch / t_fused:.2f}x vs patches",
             speedup_vs_patches=t_patch / t_fused, **shape_info)

        # Pallas kernel: the real-hardware row on TPU; at smoke size only
        # in interpret mode (Python per grid step — not a perf number).
        if on_tpu or smoke:
            pl_fn = jax.jit(lambda imgs, w, sh: p2m_conv_pallas(
                imgs, w, sh, kernel=k, stride=s, coeffs=coeffs,
                mode="quant", interpret=not on_tpu))
            t_pl = timeit(pl_fn, imgs, w, sh, warmup=1, iters=min(iters, 2))
            emit(f"p2m_conv_pallas_{name}", t_pl,
                 ("fused VMEM kernel" if on_tpu else
                  "interpret mode (correctness path)"),
                 interpret=not on_tpu,
                 speedup_vs_patches=t_patch / t_pl, **shape_info)


def _run_bwd_cases(model, *, smoke: bool) -> None:
    """Train-step backward: closed-form premixed VJP (what the custom_vjp
    now runs) vs re-differentiating the jnp forward (the old fallback)."""
    coeffs = _coeff_tuple(model)
    iters = 2 if smoke else 5
    geoms = [("paper_1img", 112 * 112, 75, 8)]
    if smoke:
        geoms = [("smoke", 32 * 32, 75, 8)]
    for name, m, k, n in geoms:
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.random((m, k)), jnp.float32)
        w = jnp.asarray(rng.uniform(-1, 1, (k, n)), jnp.float32)
        s = jnp.zeros((n,), jnp.float32)
        g = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

        def old_bwd(x, w, s, g):
            _, vjp = jax.vjp(
                lambda xx, ww, ss: p2m_matmul_jnp(xx, ww, ss, model, ADC,
                                                  "relu"), x, w, s)
            return vjp(g)

        def new_bwd(x, w, s, g):
            raw = p2m_matmul_jnp(x, w, jnp.zeros_like(s), model, ADC, "raw")
            g_eff = g * epilogue_mask(raw, s, mode="relu",
                                      full_scale=ADC.full_scale)
            gx, gw = p2m_backward_jnp(g_eff, w, x, coeffs)
            return gx, gw, g_eff.sum(0)

        t_old = timeit(jax.jit(old_bwd), x, w, s, g, iters=iters)
        emit(f"p2m_bwd_jaxvjp_{name}", t_old,
             "jax.vjp through the dw*dx forward expansion (old fallback)",
             M=m, K=k, N=n)
        t_new = timeit(jax.jit(new_bwd), x, w, s, g, iters=iters)
        emit(f"p2m_bwd_closed_{name}", t_new,
             f"closed-form premixed VJP; {t_old / t_new:.2f}x vs jax.vjp",
             speedup_vs_jaxvjp=t_old / t_new, M=m, K=k, N=n)


def _bitwise(a, b) -> float:
    return float(np.array_equal(np.asarray(a), np.asarray(b)))


def _run_pipelined_parity(model, *, smoke: bool) -> None:
    """Explicit double-buffered DMA ring (DESIGN.md §3.5) vs the automatic
    grid pipeline: bitwise parity of forward + both gradients, gated at
    1.0.  The parity geometry stays small everywhere (the claim is exact,
    not a timing); on TPU the timing comparison also runs at paper size
    via the autotuner's depth axis (`hillclimb.py --p2m-blocks`)."""
    on_tpu = jax.default_backend() == "tpu"
    name = "p2m_conv_pipelined_smoke" if smoke else "p2m_conv_pipelined_full"
    b, h, w_dim, c, k, s = (1, 40, 40, 3, 5, 5) if smoke else (2, 64, 64, 3, 5, 5)
    imgs, w, sh = _conv_data(b, h, w_dim, c, k)

    def loss(depth):
        def f(imgs, w, sh):
            out = p2m_conv(imgs, w, sh, model, ADC, "relu", k, s,
                           not on_tpu, None, depth)
            return (out * out).sum()
        return jax.jit(jax.grad(f, argnums=(0, 1)))

    coeffs = _coeff_tuple(model)
    fwd = {}
    for depth in (0, 2):
        fwd[depth] = p2m_conv_pallas(imgs, w, sh, kernel=k, stride=s,
                                     coeffs=coeffs, mode="quant",
                                     pipeline_depth=depth,
                                     interpret=not on_tpu)
    dimg0, dw0 = loss(0)(imgs, w, sh)
    dimg2, dw2 = loss(2)(imgs, w, sh)
    t_pipe = timeit(loss(2), imgs, w, sh, warmup=1, iters=2)
    emit(name, t_pipe,
         "explicit DMA-ring depth=2 vs grid pipeline: bitwise fwd+grads",
         fwd_parity=_bitwise(fwd[0], fwd[2]),
         dimg_parity=_bitwise(dimg0, dimg2),
         dw_parity=_bitwise(dw0, dw2),
         pipeline_depth=2, interpret=not on_tpu,
         B=b, H=h, W=w_dim, C=c, k=k, s=s)


def _run_gated_stem(model, *, smoke: bool) -> None:
    """Fused delta-gated stem (DESIGN.md §3.6) vs the where-select
    reference on a hold=2 synthetic stream: bit-identical detections
    (gated at 1.0), in-kernel stem-FLOPs-skipped ratio vs the stream's
    hold fraction (≥ 1.0), and ticks/s both ways.  Frame counts are
    machine-independent; the ticks ratio is informational (interpret-mode
    gating on CPU measures the Python interpreter, and the row says so)."""
    from repro.models.mobilenetv2 import MNV2Config, init_mnv2
    from repro.video import (DeltaGateConfig, DetectConfig, StreamEngine,
                             StreamRequest, SyntheticVideo, init_detect_head)

    on_tpu = jax.default_backend() == "tpu"
    name = "p2m_gated_stem_smoke" if smoke else "p2m_gated_stem_full"
    cfg = MNV2Config(variant="p2m", image_size=20, width=0.25,
                     head_channels=16)
    dcfg = DetectConfig(head_channels=8, max_dets=4)
    params, bn = init_mnv2(jax.random.PRNGKey(0), cfg)
    det = init_detect_head(jax.random.PRNGKey(1), 16, dcfg)
    n_frames, hold = (6, 2) if smoke else (10, 2)
    hold_fraction = 1.0 - 1.0 / hold  # noise=0: exactly this many held

    def streams():
        return [StreamRequest(
            uid=i, frames=SyntheticVideo(image_size=cfg.image_size,
                                         n_frames=n_frames, hold=hold,
                                         seed=i).frames())
            for i in range(3)]

    def engine(**kw):
        return StreamEngine(params, bn, cfg, det, det_cfg=dcfg,
                            gate=DeltaGateConfig(threshold=0.0),
                            max_streams=2, **kw)

    import time

    def run_timed(**kw):
        eng = engine(**kw)
        t0 = time.perf_counter()
        done = eng.run(streams())
        return eng, done, time.perf_counter() - t0

    # warm the jit caches once per path so the timing is steady-state
    run_timed(stem_path="gated")
    run_timed(stem_path="where", stem_impl="pallas")
    eng_g, done_g, wall_g = run_timed(stem_path="gated")
    eng_w, done_w, wall_w = run_timed(stem_path="where", stem_impl="pallas")

    parity = 1.0
    for g, w in zip(done_g, done_w):
        for (bg, sg), (bw, sw) in zip(g.frame_outputs, w.frame_outputs):
            parity *= _bitwise(bg, bw) * _bitwise(sg, sw)
    skipped = eng_g.stream_summary()["stem_flops_skipped_ratio"]
    ticks = sum(r.frames_done for r in done_g)
    emit(name, wall_g / ticks * 1e6,
         f"fused in-kernel gate vs where-select: parity={parity:.0f}, "
         f"skipped {skipped:.2f} of stem FLOPs (hold fraction "
         f"{hold_fraction:.2f})",
         gated_stem_parity=parity,
         stem_flops_skipped_ratio=skipped,
         hold_fraction=hold_fraction,
         skip_vs_hold=skipped / hold_fraction if hold_fraction else 0.0,
         ticks_per_s_gated=ticks / wall_g,
         ticks_per_s_where=ticks / wall_w,
         speedup_vs_where=wall_w / wall_g,
         interpret=not on_tpu)


def run(smoke: bool = False) -> None:
    model = default_pixel_model()
    _run_matmul_cases(model, smoke=smoke)
    _run_conv_cases(model, smoke=smoke)
    _run_bwd_cases(model, smoke=smoke)
    _run_pipelined_parity(model, smoke=smoke)
    _run_gated_stem(model, smoke=smoke)
    write_json(BENCH_SMOKE_JSON if smoke else BENCH_JSON, prefix="p2m_")
