"""§Perf hillclimb: hypothesis → plan change → re-lower → measure terms.

Three cells (picked from the baseline roofline table):
  * qwen3-moe-30b-a3b × train_4k — worst roofline fraction (0.028) and most
    collective-bound (collective/compute ≈ 18×),
  * llama3.2-1b × train_4k — the over-sharded small-model case,
  * llama-3.2-vision-11b × train_4k — the arch that carries the paper's
    P²M frontend.

Each variant is a sharding-plan override (the model code is unchanged);
run_cell re-lowers + recompiles under tag "<cell>-<variant>" and the
resulting terms are compared against the cached baseline.  Hypotheses and
outcomes are logged to benchmarks/results/hillclimb.json and transcribed
into EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

NO_TP = {"heads": None, "kv_heads": None, "mlp": None, "vocab": None,
         "heads_act": None, "mlp_act": None, "vocab_act": None}

# round 1 results: benchmarks/results/hillclimb_round1.json
#   dp256 CONFIRMED (coll 1.27s -> 0.12s, frac 0.121 -> 0.775);
#   dp_fsdp REFUTED (unsharded vocab head psums f32 logits: coll 2.5s);
#   ep_data REFUTED (expert-data conflicts with batch-data: resharding);
#   no_fsdp NULL (identical terms: XLA had already hoisted the FSDP
#     gathers out of the layer loop — they were never the bottleneck);
#   fsdp_model_no_tp / tp_seq REFUTED (same vocab-psum trap + seq
#     resharding inflation).
# round 2 below incorporates the two lessons: (a) always keep the vocab
# head column-sharded, (b) attack the MoE dispatch volume by sharding
# *tokens* 256-way (seq over "model"), not by moving experts.
NO_ATTN_TP = {"heads": None, "kv_heads": None, "mlp": None,
              "heads_act": None, "mlp_act": None}

EXPERIMENTS = [
    ("llama3.2-1b", "train_4k", "dp256",
     "1.2B params fit replicated (2.5 GB bf16 + 10 GB fp32 opt); dropping "
     "TP removes per-layer activation psums (~50 GB/dev) leaving one grad "
     "all-reduce (~5 GB/dev f32) -> collective 1.27s -> ~0.1s, compute-bound",
     {"batch": ("data", "model"), "embed": None, "vocab": None,
      "vocab_act": None, **NO_ATTN_TP}),
    ("qwen3-moe-30b-a3b", "train_4k", "seq_model_ep_data",
     "dispatch a2a volume scales with tokens/device: sharding seq over "
     "'model' (tokens 256-way instead of 16-way) cuts it 16x (733 GB -> "
     "~60 GB/dev); experts move to 'data' (8/chip) with d_ff over 'model' "
     "so weights+opt stay 256-way sharded; GQA kv gathers for attention "
     "over sharded seq are small (kv_dim=512)",
     {"seq": "model", "expert": "data", "embed": None,
      "batch": ("pod", "data")}),
    ("qwen3-moe-30b-a3b", "train_4k", "attn_dp_cap1",
     "control for round-2: keep baseline EP, drop only attention TP "
     "(psums from attention are ~10% of the 733 GB) — expect a small win, "
     "bounding how much of the collective is attention vs dispatch",
     NO_ATTN_TP),
    ("llama-3.2-vision-11b", "train_4k", "fsdp_data_no_attn_tp",
     "round-1 failure isolated to the unsharded vocab head (33 GB f32 "
     "logit psums). Keep vocab column-sharded (no psum), drop only "
     "attention/MLP TP: per-layer activation psums (~290 GB/dev) vanish; "
     "FSDP-over-data weight gathers (~66 GB/dev incl remat) remain "
     "-> collective 6.0s -> ~1.5s, frac 0.21 -> ~0.45",
     {"batch": ("pod", "data"), "embed": "data", **NO_ATTN_TP}),
]

# round 2 results: seq_model_ep_data REFUTED (attention over model-sharded
#   seq forces replication/gathers: coll 54s); attn_dp_cap1 REFUTED
#   (removing TP idles the model axis: per-device FLOPs 8x); vision
#   fsdp_data_no_attn_tp: collective prediction CONFIRMED (6.0s -> 0.35s)
#   but same idle-axis compute blow-up (1.3s -> 15.1s). Lesson: every
#   mesh axis must carry either batch or model work.
# round 3: (a) MoE — keep the baseline compute layout but replace the
#   dispatch/combine with the shard_map local-combine path (one bf16
#   token-granular psum/layer instead of SPMD's fp32 slot-granular
#   all-reduce) + ZeRO-1 optimizer sharding so expert params need no
#   per-layer FSDP gathers; (b) vision — batch over BOTH axes (DP=256,
#   compute stays 256-way) with ZeRO-3-style weight sharding over "data".
ROUND3 = [
    ("qwen3-moe-30b-a3b", "train_4k", "shardmap_zero1",
     "SPMD places the MoE combine collective at slot granularity "
     "(fp32 (G,S*K,d) all-reduce = 733 GB/dev/step). shard_map combines "
     "locally per expert shard and psums ONCE per layer in bf16 at token "
     "granularity: k*2 = 16x less volume -> ~46 GB + attention psums; "
     "ZeRO-1 (opt over data) keeps memory at ~5 GB/dev without per-layer "
     "weight gathers",
     {"embed": None, "opt_embed": "data", "opt_mlp": "data"},
     {"moe_impl": "shard_map"}),
    ("llama-3.2-vision-11b", "train_4k", "dp256_zero3",
     "round-2 killed the psums but idled the model axis. Shard batch over "
     "BOTH axes (DP=256 -> compute back to baseline) and params over "
     "'data' (ZeRO-3, 1.4 GB/dev): collectives = hoisted weight gathers + "
     "one grad reduce-scatter; vocab head column-sharded via the weight "
     "(no logit psum)",
     {"batch": ("data", "model"), "embed": "data", **NO_ATTN_TP},
     None),
]


def p2m_block_hillclimb() -> None:
    """§Perf hillclimb for the P²M kernel block shapes (``--p2m-blocks``).

    Runs the `kernels.p2m_conv.tune` autotuner over the paper-geometry
    matmul and fused-conv signatures, then writes the per-candidate
    timings + winners to benchmarks/results/p2m_blocks.json.  On TPU this
    measures the real kernels; off-TPU it forces interpret mode on toy
    shapes — exercising the tuner machinery, not producing perf numbers
    (the JSON records which).
    """
    import jax

    from repro.core.pixel_model import default_pixel_model
    from repro.kernels.p2m_conv import tune
    from repro.kernels.p2m_conv.ops import _coeff_tuple

    coeffs = _coeff_tuple(default_pixel_model())
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        matmul_sigs = [(112 * 112, 75, 8), (8 * 112 * 112, 75, 8)]
        conv_sigs = [(1, 224, 224, 3, 8, 5, 5), (8, 224, 224, 3, 8, 5, 5),
                     (1, 224, 224, 3, 8, 5, 2)]
    else:  # interpret mode: toy shapes, machinery-only
        matmul_sigs = [(256, 75, 8)]
        conv_sigs = [(1, 20, 20, 3, 8, 5, 5)]

    for m, k, n in matmul_sigs:
        best = tune.get_matmul_blocks(m, k, n, coeffs, "quant",
                                      enable=True, interpret=not on_tpu,
                                      iters=3 if on_tpu else 1)
        print(f"p2m_matmul M={m} K={k} N={n} -> blocks {best}")
    for b, h, w, c, n, kk, s in conv_sigs:
        bh, bn, depth = tune.get_conv_blocks(b, h, w, c, n, kk, s, coeffs,
                                             "quant", enable=True,
                                             interpret=not on_tpu,
                                             iters=3 if on_tpu else 1)
        print(f"p2m_conv B={b} {h}x{w}x{c} k={kk} s={s} -> "
              f"blocks (bh={bh}, bn={bn}, pipeline_depth={depth})")

    out = Path(__file__).resolve().parent / "results" / "p2m_blocks.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    tune.cache_dump(out)
    print(f"wrote {out}")


def term_summary(rec: dict) -> dict:
    from benchmarks.roofline import analyze_record

    a = analyze_record(rec)
    if a is None:
        return {"status": rec.get("error", "failed")[:200]}
    return {k: a[k] for k in ("compute_s", "memory_s", "collective_s",
                              "dominant", "roofline_fraction")}


def main() -> None:
    import sys as _sys

    if "--p2m-blocks" in _sys.argv:
        p2m_block_hillclimb()
        return

    from repro.launch.dryrun import run_cell

    exps = [e + (None,) for e in EXPERIMENTS]
    if "--round3" in _sys.argv:
        exps = list(ROUND3)
    results = []
    for arch, shape, variant, hypothesis, overrides, cfg_over in exps:
        base = run_cell(arch, shape, False)  # cached baseline
        rec = run_cell(arch, shape, False, force=True,
                       plan_overrides=overrides, tag=f"-{variant}",
                       cfg_overrides=cfg_over)
        entry = {
            "arch": arch, "shape": shape, "variant": variant,
            "hypothesis": hypothesis,
            "baseline": term_summary(base),
            "variant_terms": term_summary(rec),
        }
        results.append(entry)
        print(json.dumps(entry, indent=1, default=str))

    name = "hillclimb_round3.json" if "--round3" in _sys.argv else "hillclimb.json"
    out = Path(__file__).resolve().parent / "results" / name
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
