"""Heavy-traffic saturation bench: replica-pool scaling through the
event-driven front door (DESIGN.md §11).

Thousands of seeded mixed LM/vision/stream requests replay through
doors backed by 1-, 2-, and 4-replica `ReplicaPool`s per modality, at
several arrival-rate multipliers.  The engines are *synthetic cost
models* — slot residency and cadence are real (`tick_cost` 4/2/1,
multi-tick slot occupancy drawn from the seeded trace), the compiled
launch is a no-op — because this bench measures the scheduler, the
pool dispatch, and the event loop, not the model math.  Every gated
metric is therefore a pure function of (trace seed, pool shape) and
replays bit-identically on any machine:

  p2m_serve_saturation_pool{1,2,4}_smoke
      saturation_throughput   completed requests per front-door tick at
                              the saturating arrival rate (max over the
                              sweep)
      speedup_vs_pool1        pool-N saturation throughput over pool-1
                              (gated: pool 2 must reach >= 1.6x)
      scaling_efficiency      speedup_vs_pool1 / N
      p50/p95/p99_queue_ticks completed-request queueing delay on the
                              shared front-door clock (engine ticks x
                              tick_cost, converted once here)
  p2m_serve_saturation_equiv_smoke
      lockstep_equivalent     1.0 iff an equal-tick_cost event-loop door
                              over 1-replica pools replays bit-identical
                              completion ledgers to the lockstep
                              reference door (gated at 1.0)

The traces come from the shared `benchmarks.traces` builder — the same
generator the chaos bench uses, with synthetic residency descriptors in
place of model inputs.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit
from benchmarks.traces import ModalityMix, build_mixed_trace
from repro.launch.serve import FrontDoor
from repro.serving import ReplicaPool
from repro.serving.scheduler import ScheduledRequest, SlotEngine, \
    tick_percentiles

#: Arrival-rate multipliers swept per pool size.  1.0 sits near the
#: 1-replica door's aggregate capacity; 4.0 saturates the 4-replica
#: door, so every pool size sees at least one overloaded replay and the
#: max-over-sweep picks its true saturation point.
RATE_MULTS = (0.5, 1.0, 2.0, 4.0)
MAX_TICKS = 200_000


# --------------------------------------------------------- synthetic load
#
# Distinct request types per modality (the door routes on type); the
# payload is just the seeded slot residency in *engine* ticks.

@dataclasses.dataclass
class _LMReq(ScheduledRequest):
    uid: int
    work: int  # engine ticks of slot residency (prefill+decode stand-in)
    done: int = 0


@dataclasses.dataclass
class _VisReq(ScheduledRequest):
    uid: int
    work: int  # always 1: a vision slot lives exactly one tick
    done: int = 0


@dataclasses.dataclass
class _StreamReq(ScheduledRequest):
    uid: int
    work: int  # one engine tick per frame
    done: int = 0


class _SynthEngine(SlotEngine):
    """Cost-model adapter: the launch is free, the *schedule* is real —
    a request occupies its slot for ``work`` engine ticks, exactly like
    an LM decode or a stream's frame loop occupies theirs."""

    def _launch(self, active):
        return len(active)  # no compute; any non-_NO_RESULT token works

    def _absorb(self, i, req, result) -> bool:
        req.done += 1
        return req.done >= req.work


class _LMSynth(_SynthEngine):
    request_type = _LMReq


class _VisSynth(_SynthEngine):
    request_type = _VisReq


class _StreamSynth(_SynthEngine):
    request_type = _StreamReq


#: Per-modality engine shapes: (engine class, slots, tick_cost,
#: max_queue per replica).  Cadences mirror the real mixed door — the
#: LM launch is the heaviest tick, a stream frame the lightest.
_SHAPES = {
    "lm": (_LMSynth, 4, 4, 8),
    "vision": (_VisSynth, 4, 2, 8),
    "stream": (_StreamSynth, 2, 1, 4),
}

#: Smoke-scale trace: counts per modality and base arrival rates
#: (requests per front-door tick at multiplier 1.0).  ~1000 requests
#: per replay; the full run scales counts 4x at the same rates.
_BASE = {
    "lm": (240, 0.5),
    "vision": (600, 2.0),
    "stream": (160, 0.4),
}


def _trace(mult: float, scale: int = 1, seed: int = 0) -> list:
    mix = [
        ModalityMix("lm", _BASE["lm"][0] * scale, rate=_BASE["lm"][1] * mult),
        ModalityMix("vision", _BASE["vision"][0] * scale,
                    rate=_BASE["vision"][1] * mult, uid_base=100_000),
        ModalityMix("stream", _BASE["stream"][0] * scale,
                    rate=_BASE["stream"][1] * mult, uid_base=200_000),
    ]
    make = {
        "lm": lambda uid, i, arrival, rng: _LMReq(
            uid=uid, work=2 + int(rng.integers(0, 5))),
        "vision": lambda uid, i, arrival, rng: _VisReq(uid=uid, work=1),
        "stream": lambda uid, i, arrival, rng: _StreamReq(
            uid=uid, work=4 + int(rng.integers(0, 5))),
    }
    return build_mixed_trace(mix, make, seed=seed, deadlines=False)


def _build_door(replicas: int, *, lockstep: bool = False,
                pooled: bool = True, costs: bool = True) -> FrontDoor:
    """A mixed door over ``replicas``-wide pools per modality.  With
    ``costs=False`` every engine declares tick_cost 1 (the equivalence
    replay needs equal cadences); ``pooled=False`` registers bare
    engines (the lockstep reference side)."""
    def make(name):
        cls, slots, cost, queue = _SHAPES[name]
        def engine():
            return cls(slots, max_queue=queue, evict="drop-newest",
                       tick_cost=cost if costs else 1)
        if not pooled:
            return engine()
        return ReplicaPool(*(engine() for _ in range(replicas)))

    return FrontDoor(lockstep=lockstep, lm=make("lm"), vision=make("vision"),
                     stream=make("stream"))


def _replay(door: FrontDoor, reqs: list) -> dict:
    t0 = time.perf_counter()
    done = door.run(reqs, max_ticks=MAX_TICKS, on_undrained="raise")
    wall_s = time.perf_counter() - t0
    # Queue delay on the shared door clock: engine ticks x tick_cost,
    # converted once here (mirrors FrontDoor._on_door_clock).
    cost = {n: door._costs[n] for n in door.engines}
    q = [r.queue_ticks * cost[name] for name, r in done]
    p50, p95, p99 = tick_percentiles(q)
    return {
        "ticks": door.tick,
        "completed": len(done),
        "throughput": len(done) / max(door.tick, 1),
        "wall_us_per_tick": wall_s / max(door.tick, 1) * 1e6,
        "p50_queue_ticks": p50, "p95_queue_ticks": p95,
        "p99_queue_ticks": p99,
    }


def _saturate(replicas: int, scale: int) -> dict:
    """Sweep arrival rates; return the replay at the saturating rate
    (max completed-per-door-tick) plus the sweep bookkeeping."""
    best = None
    for mult in RATE_MULTS:
        r = _replay(_build_door(replicas), _trace(mult, scale))
        r["rate_mult"] = mult
        if best is None or r["throughput"] > best["throughput"]:
            best = r
    return best


def _ledger(done: list) -> list:
    return sorted(
        (name, r.uid, r.submitted_tick, r.served_tick, r.finished_tick,
         r.queue_ticks, r.serve_ticks) for name, r in done)


def _lockstep_equivalent(scale: int) -> tuple[float, float]:
    """Bit-identity of the event loop against the lockstep reference:
    equal tick_costs (all 1), 1-replica pools on the event side, bare
    engines on the lockstep side, same seeded trace — identical
    completion sets and per-request ledgers, or the gate fails."""
    ref = _build_door(1, lockstep=True, pooled=False, costs=False)
    evt = _build_door(1, costs=False)
    t0 = time.perf_counter()
    a = _ledger(ref.run(_trace(1.0, scale), max_ticks=MAX_TICKS,
                        on_undrained="raise"))
    b = _ledger(evt.run(_trace(1.0, scale), max_ticks=MAX_TICKS,
                        on_undrained="raise"))
    wall_us = (time.perf_counter() - t0) * 1e6
    return (1.0 if a == b else 0.0), wall_us


def run(smoke: bool = False) -> None:
    scale = 1 if smoke else 4
    total = sum(n for n, _ in _BASE.values()) * scale
    sat = {}
    for replicas in (1, 2, 4):
        sat[replicas] = _saturate(replicas, scale)
    base = sat[1]["throughput"]
    for replicas, r in sat.items():
        speedup = r["throughput"] / base if base else 0.0
        emit(f"p2m_serve_saturation_pool{replicas}_smoke",
             r["wall_us_per_tick"],
             f"{total} reqs x{r['rate_mult']:.1f} rate, {r['ticks']} ticks; "
             f"{r['throughput']:.2f} done/tick ({speedup:.2f}x pool1); "
             f"queue p50/p95/p99 {r['p50_queue_ticks']:.0f}/"
             f"{r['p95_queue_ticks']:.0f}/{r['p99_queue_ticks']:.0f} "
             "door ticks",
             replicas=replicas,
             saturation_throughput=r["throughput"],
             saturating_rate_mult=r["rate_mult"],
             completed=r["completed"], total=total, ticks=r["ticks"],
             speedup_vs_pool1=speedup,
             scaling_efficiency=speedup / replicas,
             p50_queue_ticks=r["p50_queue_ticks"],
             p95_queue_ticks=r["p95_queue_ticks"],
             p99_queue_ticks=r["p99_queue_ticks"])
    eq, wall_us = _lockstep_equivalent(scale)
    emit("p2m_serve_saturation_equiv_smoke", wall_us,
         "event loop vs lockstep door: "
         + ("bit-identical ledgers" if eq else "LEDGERS DIVERGED"),
         lockstep_equivalent=eq)
