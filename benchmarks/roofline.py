"""Roofline analysis (deliverable g): derive compute / memory /
collective terms per (arch × shape × mesh) from the dry-run artifacts.

    compute_s   = HLO_FLOPs_per_device / peak_FLOPs        (bf16 MXU)
    memory_s    = HLO_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / ICI_link_bw

(`cost_analysis` numbers are per-partition for SPMD modules — verified
against a hand-counted sharded matmul — so dividing by per-chip peaks is
the same as global/(chips × peak).)

Also reports MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference) against
the compiled HLO FLOPs — the "useful compute" ratio that exposes remat
and attention-waste overheads — plus the dominant term and a bottleneck
note per cell.  Writes benchmarks/results/roofline.md.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun"


def analytic_memory_floor(arch: str, shape: str, mesh_shape: dict) -> float:
    """Per-device HBM bytes/step under *perfect fusion* — the napkin floor:
    params+optimizer RMW, remat-boundary activations, matmul operand/output
    traffic, vocab logits, KV-cache reads.  The HLO-derived number is the
    unfused upper bound; real TPU traffic lands between the two.
    """
    cfg = get_config(arch)
    spec = SHAPES[shape]
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    tp = mesh_shape.get("model", 1)
    dp = chips // tp
    p = cfg.param_count_estimate()
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    L = cfg.n_layers
    qd, kvd = cfg.q_dim, cfg.kv_dim
    eff_ff = ff * (cfg.top_k if cfg.family == "moe" else 1)

    if spec.kind == "train":
        tokens_dev = spec.global_batch * spec.seq_len / dp
        # params: bf16 read fwd + bwd, fp32 grad write, m/v RMW, param write
        param_traffic = p / chips * (2 + 2 + 4 + 16 + 2)
        # per-layer activation traffic (bf16): matmul ins/outs, fwd ≈
        # (attn 4 proj + flash qk/v + mlp 3), bwd+remat ≈ 3× fwd
        per_layer = 2 * (6 * d + 2 * (qd + kvd) / tp + 3 * eff_ff / tp)
        act_traffic = tokens_dev * per_layer * L * 4
        head = tokens_dev * (v / tp) * 4 * 3  # fp32 logits fwd+bwd
        return param_traffic + act_traffic + head
    if spec.kind == "prefill":
        tokens_dev = spec.global_batch * spec.seq_len / dp
        per_layer = 2 * (6 * d + 2 * (qd + kvd) / tp + 3 * eff_ff / tp)
        return p / chips * 2 + tokens_dev * per_layer * L + \
            tokens_dev * (v / tp) * 4
    # decode: every param shard read once + cache/state read + tiny writes
    cache = 0.0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        length = min(spec.seq_len, cfg.sliding_window or spec.seq_len)
        cache = 2 * L * spec.global_batch * length * kvd * 2 / chips
    elif cfg.family == "rwkv":
        cache = L * spec.global_batch * cfg.n_rwkv_heads * \
            cfg.rwkv_head_dim**2 * 4 * 2 / chips
    elif cfg.family == "rglru":
        n_attn = cfg.n_layers // len(cfg.block_pattern)
        cache = (2 * n_attn * spec.global_batch * (cfg.sliding_window or 1)
                 * kvd * 2 + cfg.n_layers * spec.global_batch
                 * (cfg.d_rnn or d) * 4 * 2) / chips
    return p / chips * 2 + cache


def analyze_record(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    chips = r["chips"]
    flops_dev = r.get("flops_per_device", 0.0)
    bytes_dev = r.get("bytes_per_device", 0.0)
    coll_dev = r.get("collectives", {}).get("total_bytes", 0)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_hlo_s = bytes_dev / HBM_BW  # unfused upper bound (CPU-compiled HLO)
    floor_bytes = analytic_memory_floor(r["arch"], r["shape"],
                                        r.get("mesh_shape", {}))
    memory_s = floor_bytes / HBM_BW  # perfect-fusion floor (TPU-realistic)
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    model_flops = r.get("model_flops", 0.0)
    hlo_flops_global = flops_dev * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    # roofline fraction: useful model compute per step over what the
    # dominant term allows at peak
    step_time = bound_s
    mfu = (model_flops / chips / PEAK_FLOPS_BF16) / step_time if step_time else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "tag": r.get("tag", ""),
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": useful,
        "roofline_fraction": mfu,
        "temp_bytes_dev": r.get("memory", {}).get("temp_size_in_bytes"),
        "arg_bytes_dev": r.get("memory", {}).get("argument_size_in_bytes"),
    }


_NOTES = {
    "compute": ("compute-bound: cut HLO FLOPs — causal-aware flash scheduling "
                "(skip fully-masked KV blocks), less remat recompute, or more "
                "chips on the model axis"),
    "memory": ("HBM-bound: raise arithmetic intensity — larger per-chip batch, "
               "fuse elementwise chains, keep activations bf16, avoid "
               "materializing padded/broadcast KV"),
    "collective": ("collective-bound: reshard to cut all-gathers (FSDP prefetch "
                   "overlap, TP only where weights are reused enough), int8 "
                   "grad compression on the DP axis"),
}


def load_all(tag: str = "") -> list[dict]:
    out = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "") != tag:
            continue
        a = analyze_record(r)
        if a:
            out.append(a)
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def write_report(rows: list[dict], path: Path) -> None:
    lines = [
        "# Roofline analysis (single-pod 16×16 = 256 chips baseline)",
        "",
        "Terms per step: compute = dot-FLOPs/chip ÷ 197 TF/s (bf16, loop-aware "
        "HLO analysis); memory(floor) = analytic perfect-fusion bytes ÷ 819 GB/s; "
        "memory(hlo) = unfused-HLO bytes ÷ 819 GB/s (upper bound — the CPU "
        "backend fuses less than TPU, real traffic lands between the bounds); "
        "collective = HLO collective operand bytes/chip ÷ 50 GB/s/link. "
        "Dominance and roofline fraction use the floor.",
        "",
        "| arch | shape | mesh | compute | mem(floor) | mem(hlo) | collective "
        "| dominant | useful(6ND/HLO) | roofline-frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in rows:
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
            f"{fmt_s(a['compute_s'])} | {fmt_s(a['memory_s'])} | "
            f"{fmt_s(a['memory_hlo_s'])} | "
            f"{fmt_s(a['collective_s'])} | **{a['dominant']}** | "
            f"{a['useful_ratio']:.2f} | {a['roofline_fraction']:.2%} | "
            f"{_NOTES[a['dominant']][:60]}… |")
    path.write_text("\n".join(lines) + "\n")


def run() -> None:
    from benchmarks.common import emit

    rows = load_all()
    pod_rows = [a for a in rows if a["mesh"] == "pod"]
    for a in pod_rows:
        emit(f"roofline_{a['arch']}_{a['shape']}", 0.0,
             f"dom={a['dominant']} comp={fmt_s(a['compute_s'])} "
             f"mem={fmt_s(a['memory_s'])} coll={fmt_s(a['collective_s'])} "
             f"frac={a['roofline_fraction']:.3f} useful={a['useful_ratio']:.2f}")
    write_report(pod_rows, RESULTS / "roofline.md")
    n_multi = sum(1 for a in rows if a["mesh"] == "multipod")
    emit("roofline_summary", 0.0,
         f"{len(pod_rows)} pod cells analyzed, {n_multi} multipod compiles ok")


if __name__ == "__main__":
    run()
