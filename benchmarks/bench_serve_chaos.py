"""Chaos-replay bench: mixed LM/vision/stream traffic through the
front door under seeded fault injection (DESIGN.md §10).

One traffic trace — LM prompts, single frames, multi-tick video streams,
each with seeded arrivals, deadlines, and priorities — replays twice
through a fresh `FrontDoor`:

  p2m_serve_chaos_off_smoke   zero-rate plan, injectors attached: the
                              fault layer is on the path but injects
                              nothing; everything must complete (the
                              gate holds completion_rate ≥ 0.999, i.e.
                              exactly 1.0 — the layer is free when off)
  p2m_serve_chaos_smoke       the SMOKE_PLAN: launch raises, NaN rows,
                              slow launches, and stuck slots at smoke
                              rates; the engines must keep serving —
                              never deadlock, contain every fault, and
                              complete at least the gated floor of the
                              non-faulted traffic

Every gated metric is tick-based, not wall-clock: fault decisions are
pure functions of (seed, tick, uid), the schedule is deterministic, so
completion / failure / deadline-miss rates replay bit-identically on any
machine — the floors in `scripts/bench_gate.py` are exact, not
statistical.

The smoke replay also runs **traced** (DESIGN.md §13): a `Tracer` rides
the front door, the replay repeats with a second fresh tracer, and the
two Perfetto exports must be byte-identical (``trace_deterministic``) —
tick-domain stamps carry no wall-clock, so the trace is as replayable as
the metrics it witnesses.  The first export lands at
``benchmarks/results/trace_smoke.json`` where the gate validates its
span schema.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.obs import Tracer, validate_trace_events
from benchmarks.traces import ModalityMix, build_mixed_trace
from repro.configs import get_smoke_config
from repro.launch.serve import FrontDoor
from repro.models.families import get_family
from repro.models.mobilenetv2 import MNV2Config, head_out_channels, init_mnv2
from repro.serving import (
    FaultInjector,
    FaultPlan,
    Request,
    ServeEngine,
    SMOKE_PLAN,
    VisionEngine,
    VisionRequest,
)
from repro.video import (
    DetectConfig,
    StreamEngine,
    StreamRequest,
    SyntheticVideo,
    init_detect_head,
)

#: Replay shape (smoke scale).  Uid ranges are disjoint per modality so
#: the injectors' poisoned_uids union indexes the whole trace.
N_LM, N_VISION, N_STREAM = 10, 12, 4
MAX_TICKS = 600


@dataclasses.dataclass
class _Models:
    """Initialized model state shared by both replays (init + compile
    once; fresh engines per replay)."""

    lm_cfg: object
    lm_params: object
    vcfg: MNV2Config
    vparams: object
    vbn: object
    det: object


def _init_models(image_size: int = 40) -> _Models:
    import jax.numpy as jnp

    lm_cfg = get_smoke_config("llama3.2-1b").replace(dtype=jnp.float32)
    lm_params, _ = get_family(lm_cfg).init(jax.random.PRNGKey(0), lm_cfg)
    vcfg = MNV2Config(variant="p2m", image_size=image_size, width=0.25,
                      head_channels=64)
    vparams, vbn = init_mnv2(jax.random.PRNGKey(1), vcfg)
    det = init_detect_head(jax.random.PRNGKey(2), head_out_channels(vcfg),
                           DetectConfig())
    return _Models(lm_cfg, lm_params, vcfg, vparams, vbn, det)


def _traffic(m: _Models, seed: int = 0) -> list:
    """The seeded mixed trace: arrivals, deadlines, priorities — built
    by the shared `benchmarks.traces` generator (the saturation bench
    replays the same shape at scale with synthetic payloads).  The mix
    and constructors reproduce the original hand-rolled trace
    bit-identically, so the gated chaos floors are untouched."""
    size = m.vcfg.image_size
    mix = [
        ModalityMix("lm", N_LM, rate=2.0, deadline_base=60,
                    deadline_jitter=20),
        ModalityMix("vision", N_VISION, rate=3.0, deadline_base=16,
                    deadline_jitter=8, uid_base=1000),
        ModalityMix("stream", N_STREAM, rate=0.5, deadline_base=50,
                    deadline_jitter=16, uid_base=2000),
    ]
    make = {
        "lm": lambda uid, i, arrival, rng: Request(
            uid=uid,
            prompt=rng.integers(0, m.lm_cfg.vocab,
                                rng.integers(4, 9)).tolist(),
            max_new_tokens=6),
        "vision": lambda uid, i, arrival, rng: VisionRequest(
            uid=uid, image=rng.random((size, size, 3)).astype(np.float32)),
        "stream": lambda uid, i, arrival, rng: StreamRequest(
            uid=uid, frames=SyntheticVideo(image_size=size, n_frames=6,
                                           seed=i).frames()),
    }
    return build_mixed_trace(mix, make, seed=seed)


def _build_door(m: _Models, plan: FaultPlan | None, tracer=None):
    """Fresh engines with the §10 knobs on; per-engine injectors get
    distinct seeds so one modality's chaos never mirrors another's."""
    def injector(k: int):
        if plan is None:
            return None
        return FaultInjector(dataclasses.replace(plan, seed=plan.seed + k))

    inj = [injector(k) for k in range(3)]
    lm = ServeEngine(m.lm_params, m.lm_cfg, max_batch=4, max_len=64,
                     max_queue=N_LM, evict="deadline", admission="deadline",
                     max_serve_ticks=32, launch_retries=1, faults=inj[0])
    vision = VisionEngine(m.vparams, m.vbn, m.vcfg, max_batch=4,
                          max_queue=N_VISION, evict="deadline",
                          admission="deadline", max_serve_ticks=8,
                          launch_retries=1, degrade_after=6, faults=inj[1])
    stream = StreamEngine(m.vparams, m.vbn, m.vcfg, m.det, max_streams=2,
                          max_queue=N_STREAM, evict="deadline",
                          admission="deadline", max_serve_ticks=32,
                          launch_retries=1, degrade_after=6, faults=inj[2])
    return FrontDoor(tracer=tracer, lm=lm, vision=vision,
                     stream=stream), inj


def _percentiles(values: list) -> dict:
    if not values:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    arr = np.asarray(values, np.float64)
    return {"p50": float(np.percentile(arr, 50)),
            "p95": float(np.percentile(arr, 95)),
            "p99": float(np.percentile(arr, 99))}


def replay(m: _Models, plan: FaultPlan | None, seed: int = 0,
           tracer=None) -> dict:
    """One chaos replay; returns the tick-based metric dict."""
    door, injectors = _build_door(m, plan, tracer=tracer)
    reqs = _traffic(m, seed)
    total = len(reqs)
    t0 = time.perf_counter()
    done = door.run(reqs, max_ticks=MAX_TICKS, on_undrained="raise")
    wall_s = time.perf_counter() - t0

    completed = [r for _, r in done]
    failed = [r for e in door.engines.values() for r in e.failed]
    shed = [r for e in door.engines.values() for r in e.evicted + e.rejected]
    poisoned = set().union(*(i.poisoned_uids for i in injectors if i))
    clean_total = [r for r in reqs if r.uid not in poisoned]
    clean_done = [r for r in completed if r.uid not in poisoned]
    misses = sum(r.deadline_missed for r in completed + failed + shed)
    q = _percentiles([r.queue_ticks for r in completed])
    s = _percentiles([r.serve_ticks for r in completed])
    return {
        "ticks": door.tick,
        "wall_us_per_tick": wall_s / max(door.tick, 1) * 1e6,
        "total": total,
        "completion_rate": len(completed) / total,
        "failure_rate": len(failed) / total,
        "shed_rate": len(shed) / total,
        "deadline_miss_rate": misses / total,
        "nonfault_completion_rate": (
            len(clean_done) / len(clean_total) if clean_total else 1.0),
        "poisoned": len(poisoned),
        "p50_queue_ticks": q["p50"], "p95_queue_ticks": q["p95"],
        "p99_queue_ticks": q["p99"],
        "p50_serve_ticks": s["p50"], "p95_serve_ticks": s["p95"],
        "p99_serve_ticks": s["p99"],
        "health": door.health(),
    }


def _emit(name: str, r: dict) -> None:
    emit(name, r["wall_us_per_tick"],
         f"{r['total']} reqs, {r['ticks']} ticks; "
         f"complete {r['completion_rate']:.2f} "
         f"(non-faulted {r['nonfault_completion_rate']:.2f}); "
         f"fail {r['failure_rate']:.2f} shed {r['shed_rate']:.2f} "
         f"miss {r['deadline_miss_rate']:.2f}; "
         f"queue p50/p95/p99 {r['p50_queue_ticks']:.0f}/"
         f"{r['p95_queue_ticks']:.0f}/{r['p99_queue_ticks']:.0f} ticks",
         **{k: v for k, v in r.items() if k != "health"})


#: Where the gated trace artifact lands (scripts/bench_gate.py
#: validates its span schema; EXPERIMENTS.md records provenance).
TRACE_PATH = (pathlib.Path(__file__).resolve().parent
              / "results" / "trace_smoke.json")


def run(smoke: bool = False) -> None:
    m = _init_models()
    # Fault layer off (zero-rate plan, injectors attached): everything
    # completes — the gate holds this at 1.0.
    _emit("p2m_serve_chaos_off_smoke", replay(m, FaultPlan()))
    # The smoke fault plan: containment + degradation under load —
    # traced twice with fresh tracers.  Tracing is schedule-neutral, so
    # the gated completion floors read the traced replay unchanged; the
    # byte-compare of the two exports pins the determinism contract
    # (DESIGN.md §13.3) on the real serving stack, faults and all.
    tr1, tr2 = Tracer(), Tracer()
    r = replay(m, SMOKE_PLAN, tracer=tr1)
    replay(m, SMOKE_PLAN, tracer=tr2)
    TRACE_PATH.parent.mkdir(parents=True, exist_ok=True)
    e1 = tr1.export(TRACE_PATH)
    e2 = tr2.export()
    problems = validate_trace_events(json.loads(e1))
    r["trace_deterministic"] = 1.0 if e1 == e2 else 0.0
    r["trace_valid"] = 1.0 if not problems else 0.0
    r["trace_events"] = len(tr1.trace_events())
    if problems:
        print(f"bench_serve_chaos: trace schema problems: {problems[:5]}")
    _emit("p2m_serve_chaos_smoke", r)
