"""Chunked-RWKV6 WKV kernel + stateful LM-session serving bench.

Two row families (DESIGN.md §12):

  p2m_rwkv_wkv_smoke      the chunked WKV stack against the naive
                          per-token scan: forward / final-state / all-six
                          -gradients parity as exact 0-or-1 metrics (the
                          gate holds each at 1.0 — parity either survives
                          fp32 tolerance or the kernel is wrong), plus
                          informational wall-clock for the XLA twin, the
                          Pallas kernel, and the naive scan.

  p2m_lm_session_smoke    seeded multi-turn conversations replayed
                          through the event-driven `FrontDoor` into a
                          `SessionEngine` (slot-resident WKV state across
                          turns).  Every gated metric counts ticks and
                          tokens, never wall-clock: completion_rate,
                          deterministic_replay (two fresh replays must
                          agree bit-for-bit on outputs AND tick counts),
                          and prefill_tick_speedup (tick count of the
                          token-by-token prefill engine over the fused
                          chunked-WKV prefill engine on identical
                          traffic) — exact machine-independent floors in
                          `scripts/bench_gate.py`.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_smoke_config
from repro.kernels.rwkv_wkv import ops as wkv_ops
from repro.launch.serve import FrontDoor
from repro.models import rwkv6
from repro.models.families import get_family
from repro.serving import SessionEngine, SessionRequest

#: Kernel parity / timing shape (B, S, H, D) — off the chunk quantum on
#: purpose, with a non-zero initial state.
KSHAPE = (2, 45, 3, 16)
#: Session replay shape.
N_SESSIONS, N_TURNS, MAX_NEW = 6, 3, 5
MAX_TICKS = 2000


def _kernel_inputs(key, b, s, h, d):
    ks = jax.random.split(key, 6)
    return (jax.random.normal(ks[0], (b, s, h, d), jnp.float32),
            jax.random.normal(ks[1], (b, s, h, d), jnp.float32),
            jax.random.normal(ks[2], (b, s, h, d), jnp.float32),
            -jax.random.uniform(ks[3], (b, s, h, d), jnp.float32,
                                1e-4, 4.0),
            jax.random.normal(ks[4], (h, d), jnp.float32) * 0.3,
            jax.random.normal(ks[5], (b, h, d, d), jnp.float32))


def _parity(a, b, rtol=1e-4, atol=1e-4) -> float:
    """Exact 0/1 gateable metric: fp32-tolerance allclose."""
    return float(np.allclose(np.asarray(a), np.asarray(b),
                             rtol=rtol, atol=atol))


def run_kernel(smoke: bool = False) -> None:
    b, s, h, d = KSHAPE
    args = _kernel_inputs(jax.random.PRNGKey(0), b, s, h, d)
    y_ref, s_ref = rwkv6.wkv_naive(*args)

    def loss(fn):
        return lambda *a: fn(*a)[0].sum() + fn(*a)[1].sum()

    g_ref = jax.grad(loss(rwkv6.wkv_naive),
                     argnums=tuple(range(6)))(*args)

    metrics: dict[str, float] = {"shape": f"{b}x{s}x{h}x{d}"}
    for impl in ("xla", "pallas"):
        fn = jax.jit(functools.partial(wkv_ops.wkv, impl=impl))
        y, sf = fn(*args)
        g = jax.jit(jax.grad(loss(functools.partial(wkv_ops.wkv,
                                                    impl=impl)),
                             argnums=tuple(range(6))))(*args)
        metrics[f"{impl}_fwd_parity"] = _parity(y, y_ref)
        metrics[f"{impl}_state_parity"] = _parity(sf, s_ref)
        metrics[f"{impl}_grad_parity"] = float(all(
            _parity(a, r, rtol=2e-3, atol=2e-4)
            for a, r in zip(g, g_ref)))
        metrics[f"{impl}_us"] = timeit(fn, *args)
    naive_us = timeit(jax.jit(rwkv6.wkv_naive), *args)
    metrics["naive_us"] = naive_us
    metrics["xla_speedup_vs_naive"] = naive_us / metrics["xla_us"]

    emit("p2m_rwkv_wkv_smoke", metrics["xla_us"],
         f"chunked vs naive B{b} S{s} H{h} D{d}: "
         f"xla fwd/state/grad parity "
         f"{metrics['xla_fwd_parity']:.0f}/"
         f"{metrics['xla_state_parity']:.0f}/"
         f"{metrics['xla_grad_parity']:.0f}, "
         f"pallas {metrics['pallas_fwd_parity']:.0f}/"
         f"{metrics['pallas_state_parity']:.0f}/"
         f"{metrics['pallas_grad_parity']:.0f}; "
         f"naive {naive_us:.0f}us",
         **metrics)


def _conversations(cfg, seed: int = 0) -> list[list[list[int]]]:
    rng = np.random.default_rng(seed)
    return [[rng.integers(0, cfg.vocab, rng.integers(5, 11)).tolist()
             for _ in range(N_TURNS)] for _ in range(N_SESSIONS)]


def _replay(params, cfg, convs, prefill_chunk: int):
    """One session replay through the front door; returns
    (per-session outputs, ticks, wall seconds)."""
    eng = SessionEngine(params, cfg, max_batch=2, max_len=256,
                        prefill_chunk=prefill_chunk)
    door = FrontDoor(chat=eng)
    reqs = [SessionRequest(uid=i, turns=[list(t) for t in ts],
                           max_new_tokens=MAX_NEW)
            for i, ts in enumerate(convs)]
    t0 = time.perf_counter()
    done = door.run(reqs, max_ticks=MAX_TICKS, on_undrained="raise")
    wall_s = time.perf_counter() - t0
    outs = {r.uid: r.outputs for _, r in done}
    return outs, eng.tick, wall_s, len(done)


def run_sessions(smoke: bool = False) -> None:
    cfg = get_smoke_config("rwkv6-3b").replace(dtype=jnp.float32)
    params, _ = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    convs = _conversations(cfg)

    outs_a, ticks_a, wall_a, done_a = _replay(params, cfg, convs, 4)
    outs_b, ticks_b, wall_b, done_b = _replay(params, cfg, convs, 4)
    outs_tok, ticks_tok, _, _ = _replay(params, cfg, convs, 1)

    completion = done_a / len(convs)
    deterministic = float(outs_a == outs_b and ticks_a == ticks_b)
    token_parity = float(outs_a == outs_tok)
    speedup = ticks_tok / max(ticks_a, 1)
    toks = sum(len(o) for outs in outs_a.values() for o in outs)

    emit("p2m_lm_session_smoke", wall_a / max(ticks_a, 1) * 1e6,
         f"{len(convs)} sessions x {N_TURNS} turns, {toks} toks; "
         f"complete {completion:.2f}, deterministic {deterministic:.0f}, "
         f"chunked prefill {ticks_a} ticks vs tokenwise {ticks_tok} "
         f"({speedup:.2f}x)",
         sessions=len(convs), turns=N_TURNS,
         completion_rate=completion,
         deterministic_replay=deterministic,
         tokenwise_parity=token_parity,
         prefill_tick_speedup=speedup,
         ticks=ticks_a, tokenwise_ticks=ticks_tok)


def run(smoke: bool = False) -> None:
    run_kernel(smoke=smoke)
    run_sessions(smoke=smoke)
