"""Benchmark harness — one module per paper table/figure + kernel,
train/serve wall-clock, and the roofline report from the dry-run.

Prints ``name,us_per_call,derived`` CSV rows (0 µs ⇒ analytic row).

``--smoke`` runs only the P²M kernel micro-cases at reduced shapes and
iteration counts (~10 s) — the CI guard (`make verify`) that catches
kernel regressions without a TPU or a full bench sweep.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    from benchmarks import (
        bench_paper_tables,
        bench_fig7_quant,
        bench_p2m_kernel,
        bench_rwkv_wkv,
        bench_serve_chaos,
        bench_serve_saturation,
        bench_train_serve,
        roofline,
    )

    if smoke:
        # Serving rows first: bench_p2m_kernel.run writes the smoke JSON
        # (prefix p2m_) that scripts/bench_gate.py reads; the sharded
        # vision-serving, video-stream, chaos-replay, pool-saturation,
        # WKV-parity, and LM-session gates ride in it.
        bench_train_serve.run_vision_serve(smoke=True)
        bench_train_serve.run_video_stream(smoke=True)
        bench_serve_chaos.run(smoke=True)
        bench_serve_saturation.run(smoke=True)
        bench_rwkv_wkv.run(smoke=True)
        bench_p2m_kernel.run(smoke=True)
        return
    bench_paper_tables.run()
    bench_fig7_quant.run()
    bench_p2m_kernel.run()
    bench_rwkv_wkv.run()
    bench_train_serve.run()
    bench_train_serve.run_video_stream()
    bench_serve_chaos.run()
    bench_serve_saturation.run()
    roofline.run()


if __name__ == "__main__":
    main()
