"""Shared benchmark utilities: wall-clock timing + CSV/JSON emission."""
from __future__ import annotations

import json
import platform
import time
from pathlib import Path
from typing import Callable

import jax

ROWS: list[dict] = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "", **extra) -> None:
    """Record one benchmark row (CSV to stdout, dict retained for JSON).

    ``extra`` keyword fields (shapes, speedups, flags) land in the JSON
    written by :func:`write_json` but are not printed, keeping the CSV
    contract for existing consumers.

    Every row carries provenance: ``backend`` (jax backend the numbers
    were produced on), ``platform`` (host OS/arch), and ``interpret``
    (True when the timed kernel ran in Pallas interpret mode — such a
    number measures the Python interpreter, and `scripts/bench_gate.py`
    refuses to compare it across backend/interpret boundaries).  Callers
    may override any of the three, e.g. ``interpret=True`` on
    interpret-mode kernel rows.
    """
    row = {"name": name, "us_per_call": us_per_call, "derived": derived,
           "backend": jax.default_backend(), "platform": platform.platform(),
           "interpret": False}
    row.update(extra)
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str | Path, prefix: str | None = None) -> Path:
    """Dump recorded rows (optionally only names starting with ``prefix``)
    plus run metadata, so perf trajectories are diffable across PRs."""
    rows = [r for r in ROWS if prefix is None or r["name"].startswith(prefix)]
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
            "platform": platform.platform(),
        },
        "rows": rows,
    }
    path = Path(path)
    path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return path
