"""Fig. 7 benchmark: (a) output-bit-precision sweep, (b) channel/kernel
sweep — deviation + bandwidth trade-off curves from the deployable P²M
layer (the accuracy version of this sweep is `examples/train_vww_p2m.py
--sweep`, which trains; this harness stays seconds-fast)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.bandwidth import FirstLayerGeom, bandwidth_reduction
from repro.core.bn_fold import deploy_params
from repro.core.p2m_conv import (
    P2MConvConfig,
    apply_p2m_conv_deploy,
    init_p2m_conv,
    init_p2m_state,
)
from repro.core.quant import QuantSpec, quantize_deploy


def run() -> None:
    key = jax.random.PRNGKey(0)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (4, 80, 80, 3))

    # (a) output bit sweep {4,6,8,16,32}: deviation vs fp reference
    cfg = P2MConvConfig()
    params = init_p2m_conv(key, cfg)
    state = init_p2m_state(cfg)
    dep = deploy_params(params, state, cfg)
    ref = apply_p2m_conv_deploy(dep, imgs, cfg, quantize=False, use_pallas=False)
    for bits in (32, 16, 8, 6, 4):
        cfgq = P2MConvConfig(n_bits=min(bits, 16))  # counter ≤ 16 bits
        depq = quantize_deploy(dep, QuantSpec(w_bits=min(bits, 8),
                                              out_bits=min(bits, 16)))
        out = apply_p2m_conv_deploy(depq, imgs, cfgq, quantize=(bits < 32),
                                    use_pallas=False)
        dev = float(jnp.abs(out - ref).mean())
        emit(f"fig7a_Nb{bits}", 0.0,
             f"mean|Δ|={dev:.5f} BR={bandwidth_reduction(FirstLayerGeom(out_bits=min(bits,16))):.1f}x")

    # (b) channels × kernel/stride sweep: bandwidth vs capacity proxy
    for c_o in (4, 8, 16, 32):
        for k in (3, 5, 7):
            g = FirstLayerGeom(kernel=k, stride=k, out_channels=c_o)
            cfg_b = P2MConvConfig(kernel=k, stride=k, out_channels=c_o)
            weights = init_p2m_conv(jax.random.PRNGKey(2), cfg_b)["theta"]
            emit(f"fig7b_c{c_o}_k{k}", 0.0,
                 f"BR={bandwidth_reduction(g):.1f}x out={g.out_spatial}^2x{c_o} "
                 f"w_per_pixel={c_o}")
