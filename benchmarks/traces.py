"""Seeded mixed-traffic trace builder shared by the serving benches.

One generator for the mixed LM/vision/stream replay traces that
`bench_serve_chaos.py` (fault injection) and `bench_serve_saturation.py`
(replica-pool scaling) both drive through the front door — the request
counts, arrival rates, deadline windows, and seed are parameters; the
payload constructors are supplied by the caller (real model inputs for
the chaos replay, synthetic slot-residency descriptors for the
saturation sweep).

Determinism contract: all stochastic choices draw from one
`np.random.default_rng(seed)` in a fixed order — per request: payload
draws first (inside the caller's constructor), then the deadline
jitter, then the priority — so a trace is a pure function of
``(specs, make, seed)`` and replays bit-identically on any machine.
The arrival pattern is ``arrival_tick = floor(i / rate)`` with ``rate``
in requests per front-door tick (``rate=0.5`` ⇒ one arrival every
other tick), matching the hand-rolled patterns the benches previously
kept separately.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModalityMix:
    """One modality's share of a mixed trace.

    ``uid_base`` keeps uid ranges disjoint across modalities so
    injector ``poisoned_uids`` sets and completion ledgers index the
    whole trace unambiguously.  ``deadline_tick = arrival +
    deadline_base + U[0, deadline_jitter)`` when the builder runs with
    ``deadlines=True``; priorities draw uniformly from [0, 3).
    """

    name: str
    n: int
    rate: float  # arrivals per front-door tick
    deadline_base: int = 0
    deadline_jitter: int = 1
    uid_base: int = 0


def build_mixed_trace(mix: Sequence[ModalityMix],
                      make: dict[str, Callable],
                      seed: int = 0,
                      deadlines: bool = True) -> list:
    """Build the seeded trace: for each modality (in ``mix`` order) and
    local index ``i``, call ``make[name](uid, i, arrival, rng)`` to
    construct the request (payload draws come off the shared ``rng``),
    then stamp ``arrival_tick`` and — with ``deadlines`` — the seeded
    deadline and priority.  Returns the flat request list in
    construction order (the `drive` replay sorts by arrival itself)."""
    rng = np.random.default_rng(seed)
    reqs: list = []
    for m in mix:
        for i in range(m.n):
            arrival = int(i // m.rate)
            req = make[m.name](m.uid_base + i, i, arrival, rng)
            req.arrival_tick = arrival
            if deadlines:
                req.deadline_tick = (arrival + m.deadline_base
                                     + int(rng.integers(0, m.deadline_jitter)))
                req.priority = int(rng.integers(0, 3))
            reqs.append(req)
    return reqs
